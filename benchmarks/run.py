"""Benchmark driver (deliverable d): one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines plus per-benchmark detail CSVs
under benchmarks/results/.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig4,scoring
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time
from pathlib import Path

RESULTS_DIR = Path(__file__).parent / "results"


def _write_rows(name: str, rows: list[dict]):
    RESULTS_DIR.mkdir(exist_ok=True)
    if not rows:
        return
    keys = sorted({k for r in rows for k in r})
    with open(RESULTS_DIR / f"{name}.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


BENCHES = {}


def bench(name):
    def deco(fn):
        BENCHES[name] = fn
        return fn
    return deco


@bench("fig2_convergence")
def _fig2():
    from benchmarks.paper_figures import fig2_convergence
    return fig2_convergence()


@bench("fig3_table1_test_error")
def _fig3():
    from benchmarks.paper_figures import fig3_table1_test_error
    return fig3_table1_test_error()


@bench("fig4_variance")
def _fig4():
    from benchmarks.paper_figures import fig4_variance
    return fig4_variance()


@bench("b1_staleness")
def _b1():
    from benchmarks.paper_figures import b1_staleness
    return b1_staleness()


@bench("b3_smoothing")
def _b3():
    from benchmarks.paper_figures import b3_smoothing
    return b3_smoothing()


@bench("scoring_throughput")
def _scoring():
    from benchmarks.scoring_throughput import scoring_throughput
    return scoring_throughput()


@bench("strategy_ablation")
def _ablation():
    from benchmarks.strategy_ablation import strategy_ablation
    return strategy_ablation()


@bench("asgd_comparison")
def _asgd():
    from benchmarks.asgd_comparison import asgd_comparison
    return asgd_comparison()


@bench("roofline")
def _roofline():
    from benchmarks.roofline import run
    return run()


@bench("sharded_scaling")
def _sharded_scaling():
    from benchmarks.sharded_scaling import sharded_scaling
    return sharded_scaling()


@bench("transformer_scaling")
def _transformer_scaling():
    from benchmarks.sharded_scaling import transformer_scaling
    return transformer_scaling()


@bench("async_overlap")
def _async_overlap():
    from benchmarks.async_overlap import async_overlap
    return async_overlap()


@bench("streaming_io")
def _streaming_io():
    from benchmarks.streaming_io import streaming_io
    return streaming_io()


@bench("sampling_scale")
def _sampling_scale():
    from benchmarks.sampling_scale import sampling_scale
    return sampling_scale()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--bench-json", default="",
                    help="also write the summaries to this path (CI "
                    "uploads it as the BENCH_* artifact)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any selected benchmark errored "
                    "or the --only filter matched nothing (CI gate; "
                    "default keeps the harness running)")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    print("name,us_per_call,derived")
    all_summaries = {}
    errors = []
    for name, fn in BENCHES.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            rows, summary = fn()
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{e!r}")
            errors.append(name)
            continue
        dt_us = (time.time() - t0) * 1e6
        _write_rows(name, rows)
        all_summaries[name] = summary
        derived = ";".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in list(summary.items())[:6])
        print(f"{name},{dt_us:.0f},{derived}")
    RESULTS_DIR.mkdir(exist_ok=True)
    with open(RESULTS_DIR / "summaries.json", "w") as f:
        json.dump(all_summaries, f, indent=2, default=str)
    if args.bench_json:
        with open(args.bench_json, "w") as f:
            json.dump(all_summaries, f, indent=2, default=str)
    if args.strict and (errors or not all_summaries):
        print(f"STRICT: {len(errors)} errored, "
              f"{len(all_summaries)} succeeded", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

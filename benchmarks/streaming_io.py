"""Streaming-I/O benchmark: resident vs streamed step time, and the
two-level gather's host-fetch hit rate as a function of window size.

Runs in-process on a single device (the streaming overheads being measured
— per-step host fetches, device_put of misses and windows, the idx host
sync — are per-host, not per-device).  Two sweeps:

  * resident vs streamed relaxed step time at a fixed window, the price of
    keeping the dataset host-resident (on CPU, where "host" and "device"
    share memory, this *overstates* the gap: a real accelerator overlaps
    the host fetch with compute and pays PCIe only for misses);
  * window-size sweep: hit rate and streamed step time as the window grows
    from 1 chunk to the whole shard — the knob the ROADMAP's
    bigger-than-memory datasets trade against.

Standalone:

  PYTHONPATH=src python -m benchmarks.streaming_io

Harness entry (`python -m benchmarks.run --only streaming_io --bench-json
BENCH.json`) emits the same rows as BENCH JSON.
"""
from __future__ import annotations

import argparse
import json
import time


def _build(n: int, dim: int, sb: int):
    import jax
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier
    from repro.models.mlp import per_example_loss as mlp_pel
    from repro.optim import sgd

    cfg = MLPConfig(input_dim=dim, hidden=(256, 256), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=dim)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.02)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size=sb, mode="relaxed",
                       is_cfg=ISConfig(smoothing=1.0), score_shards=8)
    pel = lambda p, b: mlp_pel(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    return pel, scorer, opt, tcfg, params, train


def streaming_io(n: int = 8192, dim: int = 96, sb: int = 512,
                 chunk_size: int = 256, windows=(1, 2, 4, 8, 16),
                 steps: int = 12):
    """Benchmark-harness entry: (rows, summary)."""
    import jax
    from repro.core.issgd import init_train_state, make_train_step
    from repro.data.streaming import make_streamed_issgd

    pel, scorer, opt, tcfg, params, train = _build(n, dim, sb)
    data = train.arrays

    def timed(fn, state):
        state, m = fn(state, data)              # compile + warm
        jax.block_until_ready((state, m))
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = fn(state, data)
        jax.block_until_ready((state, m))
        return (time.perf_counter() - t0) / steps * 1e3

    step = jax.jit(make_train_step(pel, scorer, opt, tcfg, n))
    resident_ms = timed(step, init_train_state(params, opt, n))

    rows = []
    for wc in windows:
        if wc > n // chunk_size:
            continue
        # one driver per window and one measurement loop: a StreamedISSGD
        # instance is per-run (its host cursor tracks state.step), and the
        # post-warmup steps give both the step time and the steady rate
        drv = make_streamed_issgd(pel, scorer, opt, tcfg, data,
                                  chunk_size=chunk_size, window_chunks=wc)
        state = init_train_state(params, opt, n)
        state, m = drv.step(state)              # compile + first prefetch
        jax.block_until_ready((state, m))
        drv.plane.reset_stats()
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = drv.step(state)
        jax.block_until_ready((state, m))
        ms = (time.perf_counter() - t0) / steps * 1e3
        s = drv.plane.stats
        rows.append({
            "window_chunks": wc,
            "window_rows": wc * chunk_size,
            "window_frac": wc * chunk_size / n,
            "streamed_step_ms": ms,
            "resident_step_ms": resident_ms,
            "overhead": ms / resident_ms,
            "hit_rate": s.hit_rate,
            "host_rows_per_step": (s.misses + s.streamed_rows) / steps,
        })

    summary = {"resident_step_ms": resident_ms,
               "chunk_size": chunk_size, "examples": n}
    for r in rows:
        wc = r["window_chunks"]
        summary[f"streamed_ms/w{wc}"] = r["streamed_step_ms"]
        summary[f"hit_rate/w{wc}"] = r["hit_rate"]
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--examples", type=int, default=8192)
    ap.add_argument("--score-batch", type=int, default=512)
    ap.add_argument("--chunk-size", type=int, default=256)
    ap.add_argument("--windows", default="1,2,4,8,16")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rows, summary = streaming_io(
        n=args.examples, sb=args.score_batch, chunk_size=args.chunk_size,
        windows=tuple(int(x) for x in args.windows.split(",")),
        steps=args.steps)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=2)


if __name__ == "__main__":
    main()

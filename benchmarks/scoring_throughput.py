"""Scoring-cost benchmark: the paper's enabling trick (Prop. 1) vs naive
per-example gradients, plus the ghost extension's two algorithms.

Reported as µs/example on this host (CPU) — the *relative* cost is the
claim being validated: Prop.-1 style scoring is orders cheaper than
vmap-of-grad and scales to batch sizes where naive scoring OOMs."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.scorer import make_mlp_scorer
from repro.kernels import ops, ref
from repro.models.mlp import MLPConfig, init_mlp_classifier


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def scoring_throughput():
    rows, summary = [], {}
    cfg = MLPConfig(input_dim=512, hidden=(1024, 1024), num_classes=10)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    b = 256
    batch = {"x": jax.random.normal(jax.random.key(1), (b, cfg.input_dim)),
             "y": jax.random.randint(jax.random.key(2), (b,), 0, 10)}
    for strat in ["loss", "logit_grad", "ghost", "full"]:
        fn = jax.jit(make_mlp_scorer(cfg, strat))
        dt = _time(fn, params, batch)
        rows.append({"strategy": strat, "us_per_example": dt / b * 1e6})
        summary[f"{strat}/us_per_example"] = dt / b * 1e6

    # ghost-extension algorithm selection (gram kernel vs direct einsum)
    for s, din, dout, tag in [(128, 512, 512, "gram_favorable"),
                              (512, 128, 128, "direct_favorable")]:
        x = jax.random.normal(jax.random.key(3), (8, s, din))
        d = jax.random.normal(jax.random.key(4), (8, s, dout))
        t_gram = _time(jax.jit(lambda a, b_: ops.ghost_norm(a, b_, force="gram")), x, d)
        t_dir = _time(jax.jit(lambda a, b_: ops.ghost_norm(a, b_, force="direct")), x, d)
        rows.append({"strategy": f"ghost_{tag}",
                     "gram_ms": t_gram * 1e3, "direct_ms": t_dir * 1e3})
        summary[f"{tag}/gram_over_direct"] = t_gram / max(t_dir, 1e-9)
    return rows, summary

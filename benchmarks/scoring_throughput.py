"""Scoring-cost benchmark: the paper's enabling trick (Prop. 1) vs naive
per-example gradients, plus the ghost extension's two algorithms and the
fused-vs-separate kernel variants this repo adds on top:

* mlp: multi-tap `per_example_sqnorm_multi` (one grid sweep over every
  rank-1 tap of the ghost walk) vs T separate single-tap launches.
* transformer: the `with_scores` flash-backward epilogue (scores emitted
  from the dQ/dK/dV accumulators already in VMEM) vs the separate-pass
  probe that re-reads the materialized gradients from HBM.

Reported as µs/example on this host (CPU; Pallas interpret mode) — the
*relative* cost is the claim being validated: Prop.-1 style scoring is
orders cheaper than vmap-of-grad, and the fused variants avoid a second
pass over the same operands.  CI records the summary keys
``mlp/{fused,separate}_us_per_example`` and
``transformer/{fused,separate}_us_per_example`` in the --bench-json
artifact (see benchmarks/run.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core.scorer import make_lm_scorer, make_mlp_scorer
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.mlp import MLPConfig, init_mlp_classifier
from repro.models.transformer import init_transformer


def _mlp_strategies(rows, summary):
    cfg = MLPConfig(input_dim=512, hidden=(1024, 1024), num_classes=10)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    b = 256
    batch = {"x": jax.random.normal(jax.random.key(1), (b, cfg.input_dim)),
             "y": jax.random.randint(jax.random.key(2), (b,), 0, 10)}
    for strat in ["loss", "logit_grad", "ghost", "full"]:
        fn = jax.jit(make_mlp_scorer(cfg, strat))
        dt = time_fn(fn, params, batch)
        rows.append({"strategy": strat, "us_per_example": dt / b * 1e6})
        summary[f"{strat}/us_per_example"] = dt / b * 1e6


def _mlp_fused_vs_separate(rows, summary):
    """Multi-tap sweep vs per-tap launches on an MLP-shaped ghost walk."""
    b = 256
    dims = [(512, 1024), (1024, 1024), (1024, 10)]  # the mlp tap shapes
    keys = jax.random.split(jax.random.key(5), 2 * len(dims))
    xs = tuple(jax.random.normal(keys[2 * i], (b, din))
               for i, (din, _) in enumerate(dims))
    ds = tuple(jax.random.normal(keys[2 * i + 1], (b, dout))
               for i, (_, dout) in enumerate(dims))

    fused = jax.jit(lambda xs_, ds_: ops.per_example_sqnorm_multi(xs_, ds_))

    def _separate(xs_, ds_):
        res = ops.per_example_sqnorm(xs_[0], ds_[0])
        for x, d in zip(xs_[1:], ds_[1:]):
            res = res + ops.per_example_sqnorm(x, d)
        return res
    separate = jax.jit(_separate)

    t_f = time_fn(fused, xs, ds)
    t_s = time_fn(separate, xs, ds)
    rows.append({"strategy": "mlp_multi_tap",
                 "fused_us_per_example": t_f / b * 1e6,
                 "separate_us_per_example": t_s / b * 1e6})
    summary["mlp/fused_us_per_example"] = t_f / b * 1e6
    summary["mlp/separate_us_per_example"] = t_s / b * 1e6
    summary["mlp/fused_over_separate"] = t_f / max(t_s, 1e-9)


def _transformer_fused_vs_separate(rows, summary):
    """Ghost scorer with the flash `with_scores` epilogue vs the
    separate-pass score probe, end to end on a tiny transformer."""
    cfg = ModelConfig(name="bench_t", arch_type="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=256, dtype="float32", remat=False)
    params = init_transformer(jax.random.key(6), cfg)
    b, s = 8, 128
    batch = {"tokens": jax.random.randint(jax.random.key(7), (b, s),
                                          0, cfg.vocab_size)}
    t_f = time_fn(make_lm_scorer(cfg, "ghost", attn_impl="flash",
                                 attn_scores="fused"), params, batch)
    t_s = time_fn(make_lm_scorer(cfg, "ghost", attn_impl="flash",
                                 attn_scores="separate"), params, batch)
    rows.append({"strategy": "transformer_attn_scores",
                 "fused_us_per_example": t_f / b * 1e6,
                 "separate_us_per_example": t_s / b * 1e6})
    summary["transformer/fused_us_per_example"] = t_f / b * 1e6
    summary["transformer/separate_us_per_example"] = t_s / b * 1e6
    summary["transformer/fused_over_separate"] = t_f / max(t_s, 1e-9)


def _ghost_algorithms(rows, summary):
    # ghost-extension algorithm selection (gram kernel vs direct einsum)
    for s, din, dout, tag in [(128, 512, 512, "gram_favorable"),
                              (512, 128, 128, "direct_favorable")]:
        x = jax.random.normal(jax.random.key(3), (8, s, din))
        d = jax.random.normal(jax.random.key(4), (8, s, dout))
        t_gram = time_fn(
            jax.jit(lambda a, b_: ops.ghost_norm(a, b_, force="gram")), x, d)
        t_dir = time_fn(
            jax.jit(lambda a, b_: ops.ghost_norm(a, b_, force="direct")), x, d)
        rows.append({"strategy": f"ghost_{tag}",
                     "gram_ms": t_gram * 1e3, "direct_ms": t_dir * 1e3})
        summary[f"{tag}/gram_over_direct"] = t_gram / max(t_dir, 1e-9)


def scoring_throughput():
    rows, summary = [], {}
    _mlp_strategies(rows, summary)
    _mlp_fused_vs_separate(rows, summary)
    _transformer_fused_vs_separate(rows, summary)
    _ghost_algorithms(rows, summary)
    return rows, summary

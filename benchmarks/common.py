"""Shared benchmark scaffolding: a reduced permutation-invariant-SVHN setup
mirroring the paper's §5 experiments at CPU scale (same algorithm, smaller
MLP/data so each figure runs in ~a minute)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_mlp_scorer
from repro.core.strategies import make_proposal
from repro.data import make_svhn_like
from repro.models.mlp import MLPConfig, accuracy, init_mlp_classifier
from repro.models.mlp import per_example_loss as mlp_pel
from repro.optim import sgd

CFG = MLPConfig(name="mlp_svhn_bench", input_dim=96, hidden=(256, 256),
                num_classes=10)
N_TRAIN = 8192


def time_fn(fn, *args, reps: int = 3) -> float:
    """Mean seconds per call of ``fn(*args)`` over ``reps`` timed calls.

    One warmup call (jit compile + execute) is fully awaited via
    ``jax.block_until_ready``, which handles tuple/pytree returns — the
    shared replacement for per-benchmark timers that warmed up by calling
    the function twice and only awaited the first tuple element."""
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def setup(seed: int = 0):
    train, test = make_svhn_like(jax.random.key(seed), n=N_TRAIN,
                                 dim=CFG.input_dim)
    params = init_mlp_classifier(jax.random.key(seed + 1), CFG)
    return CFG, train, test, params


def run_training(params, train, *, mode: str, steps: int, lr: float,
                 smoothing: float, strategy: str = "ghost",
                 batch: int = 64, score_batch: int = 512,
                 refresh_every: int = 8, staleness_threshold: int = 0,
                 seed: int = 0, record_every: int = 5, mix=None,
                 timings: dict | None = None):
    """Run `steps` of the single-device ISSGD loop; returns (state, hist,
    elapsed_s).  `strategy` takes any zoo name (core/strategies.py), with
    `mix` as the bandit_mixed coefficients.  Pass a dict as `timings` to
    get compile_s and steady-state us_per_step (step 0 excluded) filled
    in — the wall-clock the ablation tables report.
    """
    opt = sgd(lr)
    tcfg = ISSGDConfig(
        batch_size=batch, score_batch_size=score_batch,
        refresh_every=refresh_every, mode=mode,
        is_cfg=ISConfig(smoothing=smoothing,
                        staleness_threshold=staleness_threshold))
    fused = None
    if mode == "fused":
        from repro.models.mlp import per_example_loss_and_score
        fused = lambda p, b: per_example_loss_and_score(p, b, CFG)
    step = jax.jit(make_train_step(
        lambda p, b: mlp_pel(p, b, CFG),
        make_proposal(make_mlp_scorer, CFG, strategy, mix=mix),
        opt, tcfg, train.size, fused_score=fused))
    st = init_train_state(params, opt, train.size, seed=seed)
    hist = []
    t0 = time.time()
    t_warm = t0
    for i in range(steps):
        st, m = step(st, train.arrays)
        if i == 0:
            # retire compile + first execute; steady-state timing starts here
            jax.block_until_ready(st.params)
            t_warm = time.time()
        if i % record_every == 0 or i == steps - 1:
            # ONE host sync for everything this record carries — per-metric
            # float() calls would each block the dispatch queue separately,
            # serializing the timed loop once per field
            vals = jax.device_get((m.loss, m.trace_ideal, m.trace_stale,
                                   m.trace_unif, m.ess_frac))
            hist.append({
                "step": i, "loss": float(vals[0]),
                "trace_ideal": float(vals[1]),
                "trace_stale": float(vals[2]),
                "trace_unif": float(vals[3]),
                "ess": float(vals[4]),
            })
    jax.block_until_ready(st.params)
    t_end = time.time()
    if timings is not None:
        timings["compile_s"] = t_warm - t0
        if steps > 1:
            timings["us_per_step"] = (t_end - t_warm) / (steps - 1) * 1e6
    return st, hist, t_end - t0


def median_runs(fn, runs: int = 5):
    """Run fn(seed) -> list-of-dicts `runs` times; median each key/step
    (the paper reports medians over 50 runs; we use fewer for CPU)."""
    all_h = [fn(s) for s in range(runs)]
    steps = [r["step"] for r in all_h[0]]
    out = []
    for i, s in enumerate(steps):
        rec = {"step": s}
        for k in all_h[0][0]:
            if k == "step":
                continue
            rec[k] = float(np.median([h[i][k] for h in all_h]))
        out.append(rec)
    return out

"""One function per paper table/figure (deliverable d).

fig2  — train loss: ISSGD vs regular SGD, two hyperparameter settings
fig3/table1 — test prediction error for both methods
fig4  — √Tr(Σ(q)) for q ∈ {IDEAL, STALE, UNIF} during ISSGD
b1    — staleness-threshold sweep (appendix B.1)
b3    — smoothing-constant sweep (appendix B.3)

Each returns (rows, summary) where rows are CSV-able dicts.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import CFG, median_runs, run_training, setup
from repro.models.mlp import accuracy

# the paper's two settings, rescaled to the bench problem:
SETTINGS = [
    {"name": "hiLR_hiSmooth", "lr": 0.05, "smoothing": 10.0},
    {"name": "loLR_loSmooth", "lr": 0.01, "smoothing": 1.0},
]
STEPS = 400
RUNS = 3


def fig2_convergence():
    """ISSGD minimizes the train loss faster than SGD (paper fig. 2)."""
    rows, summary = [], {}
    for s in SETTINGS:
        for mode in ["relaxed", "uniform"]:
            def one(seed):
                cfg, train, test, params = setup(seed)
                _, hist, _ = run_training(
                    params, train, mode=mode, steps=STEPS, lr=s["lr"],
                    smoothing=s["smoothing"], seed=seed)
                return hist
            med = median_runs(one, RUNS)
            label = f"{s['name']}/{'issgd' if mode == 'relaxed' else 'sgd'}"
            for r in med:
                rows.append({"setting": label, **r})
            # steps to reach half the initial loss (speed metric)
            l0 = med[0]["loss"]
            half = next((r["step"] for r in med if r["loss"] < 0.5 * l0),
                        STEPS)
            summary[f"{label}/final_loss"] = med[-1]["loss"]
            summary[f"{label}/steps_to_half_loss"] = half
    return rows, summary


def fig3_table1_test_error():
    """Final test error for ISSGD vs SGD (paper fig. 3 / table 1)."""
    rows, summary = [], {}
    for s in SETTINGS:
        for mode in ["relaxed", "uniform"]:
            errs = []
            for seed in range(RUNS):
                cfg, train, test, params = setup(seed)
                st, _, _ = run_training(
                    params, train, mode=mode, steps=STEPS, lr=s["lr"],
                    smoothing=s["smoothing"], seed=seed)
                errs.append(1.0 - float(accuracy(st.params, test.arrays, cfg)))
            label = f"{s['name']}/{'issgd' if mode == 'relaxed' else 'sgd'}"
            med = float(np.median(errs))
            rows.append({"setting": label, "test_error": med})
            summary[f"{label}/test_error"] = med
    return rows, summary


def fig4_variance():
    """√Tr(Σ) ordering IDEAL ≤ STALE ≤ UNIF during ISSGD (paper fig. 4)."""
    rows, summary = [], {}
    for s in SETTINGS:
        def one(seed):
            cfg, train, test, params = setup(seed)
            _, hist, _ = run_training(
                params, train, mode="relaxed", steps=STEPS, lr=s["lr"],
                smoothing=s["smoothing"], seed=seed)
            return hist
        med = median_runs(one, RUNS)
        for r in med:
            rows.append({"setting": s["name"], **r})
        tail = med[len(med) // 2:]
        for k in ["trace_ideal", "trace_stale", "trace_unif"]:
            summary[f"{s['name']}/{k}"] = float(
                np.mean([r[k] for r in tail]))
        summary[f"{s['name']}/variance_reduction"] = (
            summary[f"{s['name']}/trace_unif"]
            / max(summary[f"{s['name']}/trace_stale"], 1e-9))
    return rows, summary


def b1_staleness():
    """Staleness-threshold sweep (B.1): ISSGD is robust to stale weights."""
    rows, summary = [], {}
    for thresh in [0, 4, 16, 64]:
        def one(seed):
            cfg, train, test, params = setup(seed)
            _, hist, _ = run_training(
                params, train, mode="relaxed", steps=300, lr=0.01,
                smoothing=1.0, staleness_threshold=thresh, seed=seed)
            return hist
        med = median_runs(one, RUNS)
        tail = med[len(med) // 2:]
        rows.append({
            "threshold": thresh,
            "final_loss": med[-1]["loss"],
            "trace_stale": float(np.mean([r["trace_stale"] for r in tail])),
        })
        summary[f"thresh{thresh}/final_loss"] = med[-1]["loss"]
    return rows, summary


def b3_smoothing():
    """Smoothing sweep (B.3): c → ∞ recovers SGD's variance."""
    rows, summary = [], {}
    for c in [0.1, 1.0, 10.0, 100.0, 1e6]:
        def one(seed):
            cfg, train, test, params = setup(seed)
            _, hist, _ = run_training(
                params, train, mode="relaxed", steps=200, lr=0.01,
                smoothing=c, seed=seed)
            return hist
        med = median_runs(one, RUNS)
        tail = med[len(med) // 2:]
        stale = float(np.mean([r["trace_stale"] for r in tail]))
        unif = float(np.mean([r["trace_unif"] for r in tail]))
        rows.append({"smoothing": c, "trace_stale": stale,
                     "trace_unif": unif, "ratio": stale / max(unif, 1e-9),
                     "final_loss": med[-1]["loss"]})
        summary[f"c{c}/ratio_stale_over_unif"] = stale / max(unif, 1e-9)
    return rows, summary

"""Beyond-paper ablation: which scoring strategy pays?

The paper uses exact grad-norm weights (Prop. 1).  We compare the
strategies the framework offers — exact ghost, the forward-only logit-grad
proxy, raw loss values, and uniform — on equal step budgets, reporting
final loss, test error, and the achieved √Tr(Σ) reduction.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CFG, run_training, setup
from repro.models.mlp import accuracy

STEPS = 300
RUNS = 3


def strategy_ablation():
    rows, summary = [], {}
    for strat, mode in [("ghost", "relaxed"), ("logit_grad", "relaxed"),
                        ("loss", "relaxed"), ("uniform", "uniform")]:
        losses, errs, reductions = [], [], []
        for seed in range(RUNS):
            cfg, train, test, params = setup(seed)
            st, hist, _ = run_training(
                params, train, mode=mode, steps=STEPS, lr=0.02,
                smoothing=1.0, strategy=strat if mode == "relaxed" else "ghost",
                seed=seed)
            losses.append(hist[-1]["loss"])
            errs.append(1.0 - float(accuracy(st.params, test.arrays, cfg)))
            tail = hist[len(hist) // 2:]
            stale = np.mean([r["trace_stale"] for r in tail])
            unif = np.mean([r["trace_unif"] for r in tail])
            reductions.append(unif / max(stale, 1e-9))
        label = strat if mode == "relaxed" else "uniform"
        row = {"strategy": label,
               "final_loss": float(np.median(losses)),
               "test_error": float(np.median(errs)),
               "variance_reduction": float(np.median(reductions))}
        rows.append(row)
        summary[f"{label}/var_reduction"] = row["variance_reduction"]
        summary[f"{label}/test_error"] = row["test_error"]
    return rows, summary

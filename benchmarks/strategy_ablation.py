"""Beyond-paper ablation: which proposal strategy pays?

The paper uses exact grad-norm weights (Prop. 1).  We compare the full
proposal zoo (core/strategies.py) — exact ghost, the forward-only
logit-grad proxy, raw loss values, the K&F sqrt(2L) upper bound, and the
bandit-mixed loss+logit_grad blend — against a true uniform baseline,
on equal step budgets, reporting final loss, test error, steady-state
wall-clock µs/step, and (IS legs only) the achieved √Tr(Σ) reduction.

The uniform leg runs mode="uniform" with the ``null`` zero scorer: the
scoring pass keeps its cadence (parity with the IS legs) but compiles
to a trivial program, so plain SGD is no longer billed the ghost
backward the old harness built and never sampled from — and
``variance_reduction``, meaningless under uniform sampling, is reported
only where the proposal actually drives the draw.

The bandit_mixed leg threads one BanditMixer across the seeds: each
run's achieved variance reduction is the bandit reward, so λ moves
toward whichever component (loss vs logit_grad) is paying — a small,
deterministic demonstration of the online-mixture recipe.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_training, setup
from repro.core.strategies import BanditMixer
from repro.models.mlp import accuracy

STEPS = 300
RUNS = 3

#: (strategy, mode) legs on equal step budgets; "uniform" pairs the
#: uniform sampler with the null scorer (see module docstring).
LEGS = (("ghost", "relaxed"), ("logit_grad", "relaxed"),
        ("loss", "relaxed"), ("upper_bound", "relaxed"),
        ("bandit_mixed", "relaxed"), ("uniform", "uniform"))


def strategy_ablation():
    rows, summary = [], {}
    mixer = BanditMixer(("loss", "logit_grad"))
    for strat, mode in LEGS:
        losses, errs, reductions, uss = [], [], [], []
        for seed in range(RUNS):
            cfg, train, test, params = setup(seed)
            timings: dict = {}
            st, hist, _ = run_training(
                params, train, mode=mode, steps=STEPS, lr=0.02,
                smoothing=1.0,
                strategy="null" if mode == "uniform" else strat,
                mix=mixer.mix() if strat == "bandit_mixed" else None,
                seed=seed, timings=timings)
            losses.append(hist[-1]["loss"])
            errs.append(1.0 - float(accuracy(st.params, test.arrays, cfg)))
            uss.append(timings["us_per_step"])
            if mode == "relaxed":
                tail = hist[len(hist) // 2:]
                stale = np.mean([r["trace_stale"] for r in tail])
                unif = np.mean([r["trace_unif"] for r in tail])
                red = float(unif / max(stale, 1e-9))
                reductions.append(red)
                if strat == "bandit_mixed":
                    mixer.update(red)   # one bandit round per seed
        row = {"strategy": strat,
               "final_loss": float(np.median(losses)),
               "test_error": float(np.median(errs)),
               "us_per_step": float(np.median(uss)),
               "variance_reduction":
                   float(np.median(reductions)) if reductions else None}
        rows.append(row)
        summary[f"{strat}/test_error"] = row["test_error"]
        summary[f"{strat}/us_per_step"] = row["us_per_step"]
        if reductions:
            summary[f"{strat}/var_reduction"] = row["variance_reduction"]
    return rows, summary

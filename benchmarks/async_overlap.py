"""Async-overlap benchmark: sync (fused) vs async (double-buffered) step
time across mesh sizes.

Each mesh size runs in a fresh subprocess because the XLA host-device count
is fixed at first backend init.  The child times (a) the fused step of
core/distributed.py, where the scoring fan-out serializes with the master
update, and (b) the async pipeline of core/async_pipeline.py, where the two
are dispatched as independent computations through the double-buffered
WeightStore (swap every K steps).

On CPU the forced host devices share the same cores and XLA executes the
two dispatched programs back to back, so the recorded numbers bound the
*overhead* of the split (extra dispatch + the swap copy) rather than
demonstrating the overlap win — the curves become real on a pod (ROADMAP
caveat).  Standalone:

  PYTHONPATH=src python -m benchmarks.async_overlap --mesh 1,2,4,8

Harness entry (`python -m benchmarks.run --only async_overlap
--bench-json BENCH.json`) emits the same rows as BENCH JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
    import json, time
    import jax
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core import distributed as dist
    from repro.core.async_pipeline import AsyncPipeline, init_async_state
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier
    from repro.models.mlp import per_example_loss as mlp_pel
    from repro.optim import sgd

    ND = {nd}
    STEPS = {steps}
    SWAP = {swap}
    cfg = MLPConfig(input_dim={dim}, hidden=(256, 256), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n={n}, dim=cfg.input_dim)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.02)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size={sb},
                       mode="relaxed", is_cfg=ISConfig(smoothing=1.0),
                       score_shards={w})
    mesh = jax.make_mesh((ND,), ("data",))
    pel = lambda p, b: mlp_pel(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    data = dist.shard_dataset(train.arrays, mesh)

    # --- sync: the fused step (scoring serializes with the update) -------
    step, tcfg = dist.make_sharded_train_step(
        pel, scorer, opt, tcfg, train.size, mesh, train.arrays)
    step = jax.jit(step)
    state = dist.shard_train_state(
        init_train_state(params, opt, train.size), mesh)
    s2 = step(state, data)                     # compile + warm
    jax.block_until_ready(s2)
    t0 = time.perf_counter()
    for _ in range(STEPS):
        state, _m = step(state, data)
    jax.block_until_ready((state, _m))
    sync_ms = (time.perf_counter() - t0) / STEPS * 1e3

    # --- async: independently dispatched fan-out + master, swap every K --
    # monitor_traces=True keeps the program doing the same work as the
    # fused step (fig-4 trace psums included), so sync vs async is
    # apples-to-apples; the no-monitor build (zero-collective scoring) is
    # reported separately.
    def time_async(monitor):
        s_step, m_step, _ = dist.make_sharded_async_steps(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays,
            monitor_traces=monitor)
        pipe = AsyncPipeline(s_step, m_step, SWAP)
        astate = dist.shard_train_state(
            init_async_state(params, opt, train.size), mesh)
        astate, _m = pipe.step(astate, data)   # compile + warm
        jax.block_until_ready((astate, _m))
        t0 = time.perf_counter()
        for _ in range(STEPS):
            astate, _m = pipe.step(astate, data)
        jax.block_until_ready((astate, _m))
        return (time.perf_counter() - t0) / STEPS * 1e3

    async_ms = time_async(True)
    async_nomon_ms = time_async(False)

    print(json.dumps({{
        "devices": ND,
        "swap_every": SWAP,
        "sync_step_ms": sync_ms,
        "async_step_ms": async_ms,
        "async_nomon_step_ms": async_nomon_ms,
        "overlap_gain": sync_ms / async_ms,
    }}))
"""


def _run_child(nd: int, *, n: int, dim: int, sb: int, w: int, steps: int,
               swap: int) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={nd}",
               PYTHONPATH=os.path.join(repo, "src"))
    code = textwrap.dedent(_CHILD).format(nd=nd, n=n, dim=dim, sb=sb, w=w,
                                          steps=steps, swap=swap)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"devices={nd} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def async_overlap(device_counts=(1, 2, 4, 8), n: int = 4096, dim: int = 96,
                  sb: int = 512, steps: int = 10, swap: int = 1):
    """Benchmark-harness entry: (rows, summary)."""
    w = max(device_counts)  # same logical decomposition at every size
    rows = []
    for nd in device_counts:
        rows.append(_run_child(nd, n=n, dim=dim, sb=sb, w=w, steps=steps,
                               swap=swap))
    summary = {}
    for r in rows:
        d = r["devices"]
        summary[f"sync_ms/{d}dev"] = r["sync_step_ms"]
        summary[f"async_ms/{d}dev"] = r["async_step_ms"]
        summary[f"async_nomon_ms/{d}dev"] = r["async_nomon_step_ms"]
        summary[f"overlap_gain/{d}dev"] = r["overlap_gain"]
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1,2,4,8",
                    help="comma-separated device counts")
    ap.add_argument("--examples", type=int, default=4096)
    ap.add_argument("--score-batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--swap-every", type=int, default=1)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.mesh.split(","))
    rows, summary = async_overlap(counts, n=args.examples,
                                  sb=args.score_batch, steps=args.steps,
                                  swap=args.swap_every)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=2)


if __name__ == "__main__":
    main()

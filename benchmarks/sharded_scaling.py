"""Sharded-ISSGD scaling: scoring throughput and step time vs mesh shape.

Sweeps a dp×mp grid: pure data-parallel points scale the scoring fan-out
(the paper's workers), model-parallel points tensor-shard params +
optimizer state over a trailing `model` axis (activation gathers + score
psums buy per-device parameter memory).  Each mesh shape runs in a fresh
subprocess because the XLA host-device count is fixed at first backend
init.  The child times (a) the standalone scoring fan-out and (b) the
full sharded train step, on the shared benchmark MLP setup.

On CPU the forced host devices share the same cores, so absolute speedups
are not the claim — the recorded numbers pin down the *overhead* of the
sharded path (collective cost per step) and become real scaling curves on
a pod.  Standalone:

  PYTHONPATH=src python -m benchmarks.sharded_scaling --devices 1,2,4
  PYTHONPATH=src python -m benchmarks.sharded_scaling --devices 1,2 --mp 1,2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
    import json, time
    import jax
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core import distributed as dist
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.launch.mesh import make_debug_mesh
    from repro.models.mlp import MLPConfig, init_mlp_classifier, mlp_specs
    from repro.models.mlp import per_example_loss as mlp_pel
    from repro.optim import sgd

    DP, MP = {dp}, {mp}
    STEPS = {steps}
    cfg = MLPConfig(input_dim={dim}, hidden=(256, 256), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n={n}, dim=cfg.input_dim)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.02)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size={sb},
                       mode="relaxed", is_cfg=ISConfig(smoothing=1.0),
                       score_shards={w})
    mesh = make_debug_mesh(DP, model=MP)
    maxes = ("model",) if MP > 1 else ()
    pel = lambda p, b: mlp_pel(p, b, cfg, model_axes=maxes)
    scorer = make_mlp_scorer(cfg, "ghost", model_axes=maxes)
    pk = (dict(param_specs=mlp_specs(cfg), params_template=params)
          if MP > 1 else dict())
    step, tcfg = dist.make_sharded_train_step(
        pel, scorer, opt, tcfg, train.size, mesh, train.arrays, **pk)
    step = jax.jit(step)
    score = jax.jit(dist.make_sharded_score_step(
        scorer, tcfg, train.size, mesh, train.arrays, optimizer=opt, **pk))
    state = dist.shard_train_state(
        init_train_state(params, opt, train.size), mesh,
        param_specs=pk.get("param_specs"))
    data = dist.shard_dataset(train.arrays, mesh)

    def timed(fn, s):
        s2 = fn(s, data)                       # compile + warm
        jax.block_until_ready(s2)
        t0 = time.perf_counter()
        for _ in range(STEPS):
            s = fn(s, data)
        jax.block_until_ready(s)
        return (time.perf_counter() - t0) / STEPS, s

    dt_score, state = timed(score, state)
    dt_step, state = timed(lambda s, d: step(s, d)[0], state)
    pbytes = sum(x.addressable_shards[0].data.nbytes
                 for x in jax.tree.leaves(state.params))
    print(json.dumps({{
        "devices": DP * MP,
        "dp": DP, "mp": MP,
        "score_ms": dt_score * 1e3,
        "score_examples_per_s": {sb} / dt_score,
        "step_ms": dt_step * 1e3,
        "param_bytes_per_device": pbytes,
    }}))
"""


def _run_child(dp: int, mp: int, *, n: int, dim: int, sb: int, w: int,
               steps: int) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    nd = dp * mp
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={nd}",
               PYTHONPATH=os.path.join(repo, "src"))
    code = textwrap.dedent(_CHILD).format(dp=dp, mp=mp, n=n, dim=dim, sb=sb,
                                          w=w, steps=steps)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"dp={dp} mp={mp} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def sharded_scaling(device_counts=(1, 2, 4), n: int = 4096, dim: int = 96,
                    sb: int = 512, steps: int = 10, mp_counts=(1,)):
    """Benchmark-harness entry: (rows, summary) over the dp×mp grid."""
    w = max(device_counts)  # same logical decomposition at every size
    rows = []
    for mp in mp_counts:
        for dp in device_counts:
            rows.append(_run_child(dp, mp, n=n, dim=dim, sb=sb, w=w,
                                   steps=steps))
    def _tag(r):
        return (f"{r['dp']}dev" if r["mp"] == 1
                else f"{r['dp']}x{r['mp']}dev")

    summary = {}
    base = min(rows, key=lambda r: (r["mp"], r["dp"]))
    for r in rows:
        tag = _tag(r)
        summary[f"step_ms/{tag}"] = r["step_ms"]
        summary[f"score_throughput/{tag}"] = r["score_examples_per_s"]
        summary[f"speedup_vs_{_tag(base)}/{tag}"] = (
            base["step_ms"] / r["step_ms"])
        if r["mp"] > 1:
            summary[f"param_bytes_per_device/{tag}"] = (
                r["param_bytes_per_device"])
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4",
                    help="comma-separated data-parallel sizes")
    ap.add_argument("--mp", default="1",
                    help="comma-separated model-parallel sizes (grid with "
                    "--devices; total devices per point = dp*mp)")
    ap.add_argument("--examples", type=int, default=4096)
    ap.add_argument("--score-batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.devices.split(","))
    mps = tuple(int(x) for x in args.mp.split(","))
    rows, summary = sharded_scaling(counts, n=args.examples,
                                    sb=args.score_batch, steps=args.steps,
                                    mp_counts=mps)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=2)


if __name__ == "__main__":
    main()

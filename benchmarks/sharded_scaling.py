"""Sharded-ISSGD scaling: scoring throughput and step time vs device count.

Each device count runs in a fresh subprocess because the XLA host-device
count is fixed at first backend init.  The child times (a) the standalone
scoring fan-out (zero-collective, the paper's workers) and (b) the full
sharded train step, on the shared benchmark MLP setup.

On CPU the forced host devices share the same cores, so absolute speedups
are not the claim — the recorded numbers pin down the *overhead* of the
sharded path (collective cost per step) and become real scaling curves on
a pod.  Standalone:

  PYTHONPATH=src python -m benchmarks.sharded_scaling --devices 1,2,4
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
    import json, time
    import jax
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core import distributed as dist
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier
    from repro.models.mlp import per_example_loss as mlp_pel
    from repro.optim import sgd

    ND = {nd}
    STEPS = {steps}
    cfg = MLPConfig(input_dim={dim}, hidden=(256, 256), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n={n}, dim=cfg.input_dim)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.02)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size={sb},
                       mode="relaxed", is_cfg=ISConfig(smoothing=1.0),
                       score_shards={w})
    mesh = jax.make_mesh((ND,), ("data",))
    pel = lambda p, b: mlp_pel(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    step, tcfg = dist.make_sharded_train_step(
        pel, scorer, opt, tcfg, train.size, mesh, train.arrays)
    step = jax.jit(step)
    score = jax.jit(dist.make_sharded_score_step(
        scorer, tcfg, train.size, mesh, train.arrays))
    state = dist.shard_train_state(
        init_train_state(params, opt, train.size), mesh)
    data = dist.shard_dataset(train.arrays, mesh)

    def timed(fn, s):
        s2 = fn(s, data)                       # compile + warm
        jax.block_until_ready(s2)
        t0 = time.perf_counter()
        for _ in range(STEPS):
            s = fn(s, data)
        jax.block_until_ready(s)
        return (time.perf_counter() - t0) / STEPS, s

    dt_score, state = timed(score, state)
    dt_step, state = timed(lambda s, d: step(s, d)[0], state)
    print(json.dumps({{
        "devices": ND,
        "score_ms": dt_score * 1e3,
        "score_examples_per_s": {sb} / dt_score,
        "step_ms": dt_step * 1e3,
    }}))
"""


def _run_child(nd: int, *, n: int, dim: int, sb: int, w: int,
               steps: int) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={nd}",
               PYTHONPATH=os.path.join(repo, "src"))
    code = textwrap.dedent(_CHILD).format(nd=nd, n=n, dim=dim, sb=sb, w=w,
                                          steps=steps)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"devices={nd} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def sharded_scaling(device_counts=(1, 2, 4), n: int = 4096, dim: int = 96,
                    sb: int = 512, steps: int = 10):
    """Benchmark-harness entry: (rows, summary)."""
    w = max(device_counts)  # same logical decomposition at every size
    rows = []
    for nd in device_counts:
        rows.append(_run_child(nd, n=n, dim=dim, sb=sb, w=w, steps=steps))
    summary = {}
    base = min(rows, key=lambda r: r["devices"])
    for r in rows:
        d = r["devices"]
        summary[f"step_ms/{d}dev"] = r["step_ms"]
        summary[f"score_throughput/{d}dev"] = r["score_examples_per_s"]
        summary[f"speedup_vs_{base['devices']}dev/{d}dev"] = (
            base["step_ms"] / r["step_ms"])
    return rows, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4")
    ap.add_argument("--examples", type=int, default=4096)
    ap.add_argument("--score-batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.devices.split(","))
    rows, summary = sharded_scaling(counts, n=args.examples,
                                    sb=args.score_batch, steps=args.steps)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=2)


if __name__ == "__main__":
    main()

"""Sharded-ISSGD scaling: scoring throughput and step time vs mesh shape.

Sweeps a dp×mp grid: pure data-parallel points scale the scoring fan-out
(the paper's workers), model-parallel points tensor-shard params +
optimizer state over a trailing `model` axis (activation gathers + score
psums buy per-device parameter memory).  Each mesh shape runs in a fresh
subprocess because the XLA host-device count is fixed at first backend
init.  The child times (a) the standalone scoring fan-out and (b) the
full sharded train step, on the shared benchmark MLP setup.

On CPU the forced host devices share the same cores, so absolute speedups
are not the claim — the recorded numbers pin down the *overhead* of the
sharded path (collective cost per step) and become real scaling curves on
a pod.  Standalone:

  PYTHONPATH=src python -m benchmarks.sharded_scaling --devices 1,2,4
  PYTHONPATH=src python -m benchmarks.sharded_scaling --devices 1,2 --mp 1,2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
    import json, time
    import jax
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core import distributed as dist
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.launch.mesh import make_debug_mesh
    from repro.models.mlp import MLPConfig, init_mlp_classifier, mlp_specs
    from repro.models.mlp import per_example_loss as mlp_pel
    from repro.optim import sgd

    DP, MP = {dp}, {mp}
    STEPS = {steps}
    cfg = MLPConfig(input_dim={dim}, hidden=(256, 256), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n={n}, dim=cfg.input_dim)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.02)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size={sb},
                       mode="relaxed", is_cfg=ISConfig(smoothing=1.0),
                       score_shards={w})
    mesh = make_debug_mesh(DP, model=MP)
    maxes = ("model",) if MP > 1 else ()
    pel = lambda p, b: mlp_pel(p, b, cfg, model_axes=maxes)
    scorer = make_mlp_scorer(cfg, "ghost", model_axes=maxes)
    pk = (dict(param_specs=mlp_specs(cfg), params_template=params)
          if MP > 1 else dict())
    step, tcfg = dist.make_sharded_train_step(
        pel, scorer, opt, tcfg, train.size, mesh, train.arrays, **pk)
    step = jax.jit(step)
    score = jax.jit(dist.make_sharded_score_step(
        scorer, tcfg, train.size, mesh, train.arrays, optimizer=opt, **pk))
    state = dist.shard_train_state(
        init_train_state(params, opt, train.size), mesh,
        param_specs=pk.get("param_specs"))
    data = dist.shard_dataset(train.arrays, mesh)

    def timed(fn, s):
        s2 = fn(s, data)                       # compile + warm
        jax.block_until_ready(s2)
        t0 = time.perf_counter()
        for _ in range(STEPS):
            s = fn(s, data)
        jax.block_until_ready(s)
        return (time.perf_counter() - t0) / STEPS, s

    dt_score, state = timed(score, state)
    dt_step, state = timed(lambda s, d: step(s, d)[0], state)
    pbytes = sum(x.addressable_shards[0].data.nbytes
                 for x in jax.tree.leaves(state.params))
    print(json.dumps({{
        "devices": DP * MP,
        "dp": DP, "mp": MP,
        "score_ms": dt_score * 1e3,
        "score_examples_per_s": {sb} / dt_score,
        "step_ms": dt_step * 1e3,
        "param_bytes_per_device": pbytes,
    }}))
"""


_TCHILD = """
    import json, time
    import jax, jax.numpy as jnp
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core import distributed as dist
    from repro.core.scorer import make_lm_scorer
    from repro.data import make_token_dataset
    from repro.launch.mesh import make_debug_mesh
    from repro.models.config import ModelConfig
    from repro.models.transformer import init_transformer, transformer_specs
    from repro.models.transformer import per_example_loss as lm_pel
    from repro.optim import sgd

    DP, MP = {dp}, {mp}
    STEPS = {steps}
    SEQ = 32
    cfg = ModelConfig(name="bench", arch_type="dense", num_layers=2,
                      d_model=128, num_heads=8, num_kv_heads=2, d_ff=256,
                      vocab_size=256, dtype="float32", remat=False)
    train = make_token_dataset(jax.random.key(0), n={n}, seq=SEQ + 1,
                               vocab=cfg.vocab_size)
    params = init_transformer(jax.random.key(1), cfg)
    opt = sgd(0.02)
    mesh = make_debug_mesh(DP, model=MP)
    maxes = ("model",) if MP > 1 else ()
    pk = (dict(param_specs=transformer_specs(cfg), params_template=params)
          if MP > 1 else dict())

    def build(seq_shard):
        pel = lambda p, b: lm_pel(p, cfg, b, model_axes=maxes,
                                  seq_shard=seq_shard)[0]
        scorer = make_lm_scorer(cfg, "ghost", model_axes=maxes,
                                seq_shard=seq_shard)
        tcfg = ISSGDConfig(batch_size=16, score_batch_size={sb},
                           mode="relaxed", is_cfg=ISConfig(smoothing=1.0),
                           score_shards={w})
        step, tcfg = dist.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays, **pk)
        return jax.jit(step)

    state0 = dist.shard_train_state(
        init_train_state(params, opt, train.size), mesh,
        param_specs=pk.get("param_specs"))
    data = dist.shard_dataset(train.arrays, mesh)

    def timed(fn, s):
        s2, _ = fn(s, data)                    # compile + warm
        jax.block_until_ready(s2)
        t0 = time.perf_counter()
        for _ in range(STEPS):
            s, _ = fn(s, data)
        jax.block_until_ready(s)
        return (time.perf_counter() - t0) / STEPS, s

    dt_sp, state = timed(build(True), state0)
    dt_nosp, state = timed(build(False), state)
    pbytes = sum(x.addressable_shards[0].data.nbytes
                 for x in jax.tree.leaves(state.params))
    # per-device norm-segment activation bytes (analytic: the RMSNorm
    # input slice per sub-layer), with/without sequence parallelism
    rows_dev = {sb} // DP
    norm_full = rows_dev * SEQ * cfg.d_model * 4
    norm_sp = norm_full // MP if MP > 1 and SEQ % MP == 0 else norm_full
    print(json.dumps({{
        "devices": DP * MP,
        "dp": DP, "mp": MP, "arch": "transformer",
        "step_ms": dt_sp * 1e3,
        "step_ms_no_seq_parallel": dt_nosp * 1e3,
        "param_bytes_per_device": pbytes,
        "norm_segment_bytes_per_device": norm_sp,
        "norm_segment_bytes_no_seq_parallel": norm_full,
    }}))
"""


def _run_child(dp: int, mp: int, *, n: int, dim: int, sb: int, w: int,
               steps: int, arch: str = "mlp") -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    nd = dp * mp
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={nd}",
               PYTHONPATH=os.path.join(repo, "src"))
    template = _TCHILD if arch == "transformer" else _CHILD
    code = textwrap.dedent(template).format(dp=dp, mp=mp, n=n, dim=dim,
                                            sb=sb, w=w, steps=steps)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"dp={dp} mp={mp} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def sharded_scaling(device_counts=(1, 2, 4), n: int = 4096, dim: int = 96,
                    sb: int = 512, steps: int = 10, mp_counts=(1,),
                    arch: str = "mlp"):
    """Benchmark-harness entry: (rows, summary) over the dp×mp grid.

    ``arch="transformer"`` swaps in the dense-transformer child (ghost
    scoring through the model-axis-aware forward) and reports the
    sequence-parallel step time next to the replicated-norm one, plus
    the per-device norm-segment activation bytes both ways."""
    w = max(device_counts)  # same logical decomposition at every size
    rows = []
    for mp in mp_counts:
        for dp in device_counts:
            rows.append(_run_child(dp, mp, n=n, dim=dim, sb=sb, w=w,
                                   steps=steps, arch=arch))
    def _tag(r):
        return (f"{r['dp']}dev" if r["mp"] == 1
                else f"{r['dp']}x{r['mp']}dev")

    summary = {}
    base = min(rows, key=lambda r: (r["mp"], r["dp"]))
    for r in rows:
        tag = _tag(r)
        summary[f"step_ms/{tag}"] = r["step_ms"]
        if "score_examples_per_s" in r:
            summary[f"score_throughput/{tag}"] = r["score_examples_per_s"]
        summary[f"speedup_vs_{_tag(base)}/{tag}"] = (
            base["step_ms"] / r["step_ms"])
        if "step_ms_no_seq_parallel" in r:
            summary[f"step_ms_no_seq_parallel/{tag}"] = (
                r["step_ms_no_seq_parallel"])
            summary[f"norm_segment_bytes/{tag}"] = (
                r["norm_segment_bytes_per_device"])
            summary[f"norm_segment_bytes_no_sp/{tag}"] = (
                r["norm_segment_bytes_no_seq_parallel"])
        if r["mp"] > 1:
            summary[f"param_bytes_per_device/{tag}"] = (
                r["param_bytes_per_device"])
    return rows, summary


def transformer_scaling(device_counts=(1, 2), mp_counts=(1, 2),
                        sb: int = 128, steps: int = 5):
    """Registry entry for the transformer sweep (see benchmarks/run.py)."""
    return sharded_scaling(device_counts, n=1024, sb=sb, steps=steps,
                           mp_counts=mp_counts, arch="transformer")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4",
                    help="comma-separated data-parallel sizes")
    ap.add_argument("--mp", default="1",
                    help="comma-separated model-parallel sizes (grid with "
                    "--devices; total devices per point = dp*mp)")
    ap.add_argument("--arch", default="mlp",
                    choices=["mlp", "transformer"],
                    help="benchmark model: the paper MLP, or the dense "
                    "transformer through the model-axis-aware forward "
                    "(reports seq-parallel vs replicated-norm step time "
                    "and per-device norm-segment activation bytes)")
    ap.add_argument("--examples", type=int, default=None,
                    help="dataset rows (default: 4096 mlp / 1024 "
                    "transformer — token rows are ~33x larger)")
    ap.add_argument("--score-batch", type=int, default=None,
                    help="rows rescored per step (default: 512 mlp / "
                    "128 transformer)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.devices.split(","))
    mps = tuple(int(x) for x in args.mp.split(","))
    if args.examples is None:
        args.examples = 1024 if args.arch == "transformer" else 4096
    if args.score_batch is None:
        args.score_batch = 128 if args.arch == "transformer" else 512
    rows, summary = sharded_scaling(counts, n=args.examples,
                                    sb=args.score_batch, steps=args.steps,
                                    mp_counts=mps, arch=args.arch)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=2)


if __name__ == "__main__":
    main()

"""Roofline analysis (deliverable g): read the dry-run JSONs and derive the
three terms per (arch × shape) on the single-pod mesh.

  compute_s    = flops_per_device / PEAK_FLOPS_BF16
  memory_s     = io_bytes_per_device × 2 / HBM_BW   (writes ≈ reads proxy)
  collective_s = collective_bytes_per_device / ICI_BW

Dominant term = the bottleneck; MODEL_FLOPS = 6·N_active·D (train) or
2·N_active per generated token (decode), and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = Path(__file__).parent / "dryrun_results"


def model_flops_per_device(rec: dict) -> float:
    """Analytic useful FLOPs per device for the step that was lowered."""
    n_act = rec["active_params"]
    chips = rec["chips"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        # train fwd+bwd (6·N·D) + the ISSGD scoring forward pass (2·N·D)
        return (6.0 * n_act * tokens + 2.0 * n_act * tokens) / chips
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * rec["global_batch"] / chips


def load(mesh: str = "pod1") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_rows(mesh: str = "pod1") -> list[dict]:
    rows = []
    for r in load(mesh):
        comp = r["flops_per_device"] / PEAK_FLOPS_BF16
        memt = 2.0 * r["io_bytes_per_device"] / HBM_BW
        coll = r["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": comp, "memory": memt, "collective": coll}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_device(r)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": comp, "memory_s": memt, "collective_s": coll,
            "dominant": dom,
            "model_flops_dev": mf,
            "useful_ratio": mf / max(r["flops_per_device"], 1e-9),
            "step_s_bound": max(terms.values()),
        })
    return rows


def run():
    rows = roofline_rows()
    summary = {}
    for r in rows:
        summary[f"{r['arch']}/{r['shape']}/dominant"] = r["dominant"]
    return rows, summary


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOP ratio |\n|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows, _ = run()
    print(markdown_table(rows))

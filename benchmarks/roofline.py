"""Roofline analysis (deliverable g): read the dry-run JSONs and derive the
three terms per (arch × shape) on the single-pod mesh.

  compute_s    = flops_per_device / PEAK_FLOPS_BF16
  memory_s     = io_bytes_per_device × 2 / HBM_BW   (writes ≈ reads proxy)
  collective_s = collective_bytes_per_device / ICI_BW

Dominant term = the bottleneck; MODEL_FLOPS = 6·N_active·D (train) or
2·N_active per generated token (decode), and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.

A second, fully analytic section (`scoring_traffic_rows`, no dry-run
JSONs needed) prices the fused-vs-separate per-example scoring variants:
the separate attention-score pass re-reads the materialized dQ/dK/dV from
HBM, while the `with_scores` epilogue reuses the accumulators already in
VMEM; likewise the multi-tap sq-norm sweep reads each ghost tap once
instead of once per launch-pair.  Scoring is pure traffic (one multiply
per element read), so bytes/HBM_BW is the whole story.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = Path(__file__).parent / "dryrun_results"


def model_flops_per_device(rec: dict) -> float:
    """Analytic useful FLOPs per device for the step that was lowered."""
    n_act = rec["active_params"]
    chips = rec["chips"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        # train fwd+bwd (6·N·D) + the ISSGD scoring forward pass (2·N·D)
        return (6.0 * n_act * tokens + 2.0 * n_act * tokens) / chips
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * rec["global_batch"] / chips


def load(mesh: str = "pod1") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_rows(mesh: str = "pod1") -> list[dict]:
    rows = []
    for r in load(mesh):
        comp = r["flops_per_device"] / PEAK_FLOPS_BF16
        memt = 2.0 * r["io_bytes_per_device"] / HBM_BW
        coll = r["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": comp, "memory": memt, "collective": coll}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_device(r)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": comp, "memory_s": memt, "collective_s": coll,
            "dominant": dom,
            "model_flops_dev": mf,
            "useful_ratio": mf / max(r["flops_per_device"], 1e-9),
            "step_s_bound": max(terms.values()),
        })
    return rows


def scoring_traffic_rows() -> list[dict]:
    """Analytic HBM-traffic rows for the fused vs. separate scoring
    kernels (f32 operands; no dry-run JSONs required).

    attn_scores: separate = 3·B·S·H·hd·4 bytes of gradient re-reads plus
    the (B,) write; fused = the (B,) write only (the epilogue squares the
    dQ/dK/dV accumulators before they leave VMEM).  sqnorm_multi:
    separate = T single-tap launches each re-reading its (x, d) pair —
    same total tap bytes, but T kernel dispatches and T partial-result
    round-trips; fused = one sweep reading every tap once."""
    rows = []
    f32 = 4
    for bsz, s, h, hd in [(64, 2048, 16, 128), (256, 8192, 32, 128)]:
        grad_bytes = 3.0 * bsz * s * h * hd * f32
        sep = grad_bytes + bsz * f32
        fus = float(bsz * f32)
        rows.append({
            "arch": "attn_scores", "shape": f"b{bsz}_s{s}_h{h}_hd{hd}",
            "separate_bytes": sep, "fused_bytes": fus,
            "separate_s": sep / HBM_BW, "fused_s": fus / HBM_BW,
            "traffic_saving": 1.0 - fus / sep,
        })
    for bsz, taps, din, dout in [(4096, 4, 4096, 4096),
                                 (8192, 12, 8192, 2048)]:
        tap_bytes = float(taps) * bsz * (din + dout) * f32
        sep = tap_bytes + taps * bsz * f32       # T partial (B,) writes
        fus = tap_bytes + taps * bsz * f32 + bsz * f32
        rows.append({
            "arch": "sqnorm_multi", "shape": f"b{bsz}_t{taps}_{din}x{dout}",
            "separate_bytes": sep, "fused_bytes": fus,
            "separate_s": sep / HBM_BW, "fused_s": fus / HBM_BW,
            "launches_separate": taps, "launches_fused": 1,
            "traffic_saving": 1.0 - fus / sep,
        })
    return rows


def run():
    rows = roofline_rows()
    summary = {}
    for r in rows:
        summary[f"{r['arch']}/{r['shape']}/dominant"] = r["dominant"]
    traffic = scoring_traffic_rows()
    rows = rows + traffic
    for r in traffic:
        summary[f"{r['arch']}/{r['shape']}/traffic_saving"] = (
            r["traffic_saving"])
    return rows, summary


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOP ratio |\n|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def scoring_markdown_table(rows: list[dict]) -> str:
    """Render the fused-vs-separate scoring-traffic rows (README table)."""
    hdr = ("| kernel | shape | separate bytes | fused bytes | "
           "traffic saved |\n|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['separate_bytes']:.3g} | "
            f"{r['fused_bytes']:.3g} | {100 * r['traffic_saving']:.1f}% |")
    return "\n".join(lines)


if __name__ == "__main__":
    dr = roofline_rows()
    if dr:
        print(markdown_table(dr))
    print(scoring_markdown_table(scoring_traffic_rows()))

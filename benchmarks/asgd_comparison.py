"""The paper's §6 question, answered: how does ISSGD compare with ASGD,
and do they compose?

Four systems on equal step budgets (same model/data/lr):
  sgd          synchronous uniform SGD (delay 0)
  asgd         uniform minibatches, stale gradients (delay 4)
  issgd        the paper's method (fresh master, fused scoring)
  asgd+issgd   the §6 "peers" design: stale gradients AND shared
               importance weights (this repo's make_asgd_step mode=issgd)
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import CFG, run_training, setup
from repro.core.asgd import ASGDConfig, init_asgd_state, make_asgd_step
from repro.core.importance import ISConfig
from repro.models.mlp import (accuracy, per_example_loss,
                              per_example_loss_and_score)
from repro.optim import sgd

STEPS = 300
RUNS = 3
DELAY = 4


def _run_asgd(mode: str, seed: int):
    cfg, train, test, params = setup(seed)
    opt = sgd(0.02)
    acfg = ASGDConfig(batch_size=64, delay=DELAY, mode=mode,
                      is_cfg=ISConfig(smoothing=1.0))
    step = jax.jit(make_asgd_step(
        lambda p, b: per_example_loss(p, b, cfg), opt, acfg, train.size,
        fused_score=lambda p, b: per_example_loss_and_score(p, b, cfg)))
    st = init_asgd_state(params, opt, acfg, train.size, seed=seed)
    last = None
    for _ in range(STEPS):
        st, last = step(st, train.arrays)
    err = 1.0 - float(accuracy(st.params, test.arrays, cfg))
    return float(last.loss), err, float(last.delay_gap)


def asgd_comparison():
    rows, summary = [], {}
    # synchronous baselines via the ISSGD runtime
    for mode, label in [("uniform", "sgd"), ("fused", "issgd")]:
        losses, errs = [], []
        for seed in range(RUNS):
            cfg, train, test, params = setup(seed)
            st, hist, _ = run_training(params, train, mode=mode, steps=STEPS,
                                       lr=0.02, smoothing=1.0, seed=seed)
            losses.append(hist[-1]["loss"])
            errs.append(1.0 - float(accuracy(st.params, test.arrays, cfg)))
        rows.append({"system": label, "final_loss": float(np.median(losses)),
                     "test_error": float(np.median(errs)), "delay": 0})
        summary[f"{label}/final_loss"] = rows[-1]["final_loss"]
    # asynchronous systems
    for mode, label in [("uniform", "asgd"), ("issgd", "asgd+issgd")]:
        out = [_run_asgd(mode, s) for s in range(RUNS)]
        rows.append({"system": label,
                     "final_loss": float(np.median([o[0] for o in out])),
                     "test_error": float(np.median([o[1] for o in out])),
                     "delay": DELAY,
                     "delay_gap": float(np.median([o[2] for o in out]))})
        summary[f"{label}/final_loss"] = rows[-1]["final_loss"]
        summary[f"{label}/test_error"] = rows[-1]["test_error"]
    return rows, summary

"""Sampling-structures scale sweep (ISSUE 10): score-write cost, draw
cost, and quantization distortion vs table size N.

Three questions, one table-size sweep:

  * score-write cost — after a score batch touches B chunks, the dense
    path re-reduces all N rows for stage-1 while the mass index refreshes
    only the B touched leaves + their O(log C) ancestor paths
    (``refresh_chunks``).  The sweep fits log-log slopes: dense must be
    ~1 (linear), the index refresh clearly sub-linear in N.
  * draw cost — ``indexed_sample`` (O(log C) descent + one-chunk
    stage-2) vs the dense two-stage draw's full block-CDF build.
  * distortion — measured TV between the f32 proposal and its bf16/int8
    twins, against the analytic ``quantization_tv_bound`` (the same
    inequality the chi²/TV battery asserts at test scale).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.importance import ISConfig
from repro.core.mass_index import (block_masses, build_index, indexed_sample,
                                   refresh_chunks)
from repro.core.sampler import sample_indices
from repro.core.weight_store import (WeightStore, quantization_tv_bound,
                                     quantize_weights, read_proposal)

CHUNK = 1024          # streaming-plane chunk size
TOUCHED = 8           # chunks written per simulated score batch
DRAWS = 256
SIZES = (2 ** 14, 2 ** 16, 2 ** 18, 2 ** 20)


def _tv(p: jax.Array, q: jax.Array) -> float:
    p = p / jnp.sum(p)
    q = q / jnp.sum(q)
    return float(0.5 * jnp.sum(jnp.abs(p - q)))


def _distortion(table: jax.Array, cfg: ISConfig, step: int = 1) -> dict:
    zeros = jnp.zeros((table.shape[0],), jnp.int32)
    f32 = WeightStore(weights=table, scored_at=zeros)
    bf16 = WeightStore(weights=table.astype(jnp.bfloat16), scored_at=zeros)
    codes, qscale = quantize_weights(table, CHUNK)
    int8 = WeightStore(weights=codes, scored_at=zeros, qscale=qscale)
    p = read_proposal(f32, step, cfg)
    out = {}
    for name, store in (("bf16", bf16), ("int8", int8)):
        out[f"tv_{name}"] = _tv(p, read_proposal(store, step, cfg))
        out[f"tv_bound_{name}"] = float(
            quantization_tv_bound(f32, step, cfg, CHUNK, name))
    return out


def sampling_scale():
    cfg = ISConfig()
    rows = []
    for n in SIZES:
        key = jax.random.key(n)
        table = jax.random.uniform(key, (n,), jnp.float32) + 1e-3
        c = n // CHUNK
        index = build_index(table, CHUNK)
        chunk_ids = jnp.arange(TOUCHED, dtype=jnp.int32) * (c // TOUCHED)

        dense_rebuild = jax.jit(partial(block_masses, num_blocks=c))
        tree_refresh = jax.jit(partial(refresh_chunks, chunk_size=CHUNK))
        dense_draw = jax.jit(partial(sample_indices, num_samples=DRAWS,
                                     num_shards=c))
        tree_draw = jax.jit(partial(indexed_sample, chunk_size=CHUNK,
                                    num_samples=DRAWS))

        t_dense = time_fn(dense_rebuild, table)
        t_refresh = time_fn(lambda: tree_refresh(index, table,
                                                 chunk_ids=chunk_ids))
        t_dense_draw = time_fn(dense_draw, key, table)
        t_tree_draw = time_fn(lambda: tree_draw(key, table, index))

        row = {"n": n, "chunks": c,
               "dense_rebuild_us": t_dense * 1e6,
               "tree_refresh_us": t_refresh * 1e6,
               "dense_draw_us": t_dense_draw * 1e6,
               "tree_draw_us": t_tree_draw * 1e6}
        row.update(_distortion(table, cfg))
        rows.append(row)

    logn = np.log([r["n"] for r in rows])
    slope = lambda k: float(np.polyfit(
        logn, np.log([r[k] for r in rows]), 1)[0])
    last = rows[-1]
    summary = {
        "dense_rebuild_slope": slope("dense_rebuild_us"),
        "tree_refresh_slope": slope("tree_refresh_us"),
        "write_speedup_at_max_n":
            last["dense_rebuild_us"] / last["tree_refresh_us"],
        "tv_bf16_under_bound":
            all(r["tv_bf16"] <= r["tv_bound_bf16"] for r in rows),
        "tv_int8_under_bound":
            all(r["tv_int8"] <= r["tv_bound_int8"] for r in rows),
    }
    return rows, summary


if __name__ == "__main__":
    rows, summary = sampling_scale()
    for r in rows:
        print(r)
    print(summary)

"""Inference layer: cache-backed decode engine + continuous batching.

``engine`` owns the cache layout (period-major, ring-buffered sliding
windows) and the prefill/decode_step/generate loop; ``batcher`` schedules
multi-tenant requests onto cache slots; ``sharded_decode`` is the
model-parallel decode attention. Serving reuses the training forward's
mixers, so train/serve parity is tested rather than assumed
(tests/test_async.py, tests/test_batcher.py)."""
from repro.serving.engine import (ServeState, init_serve_state, prefill,
                                  decode_step, generate)
from repro.serving.sharded_decode import sharded_decode_attention

__all__ = ["ServeState", "init_serve_state", "prefill", "decode_step",
           "generate", "sharded_decode_attention"]

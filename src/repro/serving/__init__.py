"""Inference layer: cache-backed decode engine + continuous batching.

``engine`` owns the cache layout (period-major, ring-buffered sliding
windows) and the prefill/decode_step/generate loop; ``batcher`` schedules
multi-tenant requests onto cache slots; ``sharded_decode`` is the
model-parallel decode attention plus the mesh-serving builders; ``loop``
closes the train/serve loop (published-snapshot decode ticks, traffic
ingest back into the example store). Serving reuses the training
forward's mixers, so train/serve parity is tested rather than assumed
(tests/test_async.py, tests/test_batcher.py, tests/test_serving_loop.py)."""
from repro.serving.engine import (ServeState, init_serve_state, prefill,
                                  decode_step, generate)
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.loop import (ServeLoop, TrafficIngest,
                                make_synthetic_traffic)
from repro.serving.sharded_decode import (decode_cache_pspecs,
                                          make_mesh_serving,
                                          sharded_decode_attention)

__all__ = ["ServeState", "init_serve_state", "prefill", "decode_step",
           "generate", "sharded_decode_attention", "ContinuousBatcher",
           "Request", "ServeLoop", "TrafficIngest", "make_synthetic_traffic",
           "decode_cache_pspecs", "make_mesh_serving"]

from repro.serving.engine import (ServeState, init_serve_state, prefill,
                                  decode_step, generate)
from repro.serving.sharded_decode import sharded_decode_attention

__all__ = ["ServeState", "init_serve_state", "prefill", "decode_step",
           "generate", "sharded_decode_attention"]

"""Continuous batching: slot-based request scheduling over the decode engine.

Production serving rarely sees aligned request batches; this layer keeps a
fixed pool of `num_slots` cache slots, prefills arriving requests into
free slots (one dynamic_update_slice per cache buffer), decodes all active
slots in lock-step, and evicts on EOS/max-tokens.  Per-slot `lengths`
already drive the attention masking, so slots at different positions
coexist in one batched decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.serving.engine import (ServeState, decode_step, init_serve_state,
                                  prefill)


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens plus stop conditions."""
    uid: int
    prompt: jax.Array            # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1             # -1 = never


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    generated: list = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Drive a params+config pair as a multi-tenant decode server."""

    def __init__(self, params, cfg: ModelConfig, num_slots: int,
                 max_len: int, decode_kernel: str = "ref",
                 sample: Optional[Callable] = None):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.state = init_serve_state(cfg, batch=num_slots, max_len=max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self._next_tok = jnp.zeros((num_slots,), jnp.int32)
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        self._decode = jax.jit(
            lambda p, t, s: decode_step(p, cfg, t, s,
                                        decode_kernel=decode_kernel))
        self._prefill = jax.jit(
            lambda p, t: prefill(p, cfg, t, max_len=max_len))
        self.finished: dict[int, list[int]] = {}

    # ------------------------------------------------------------- admission
    def try_insert(self, req: Request) -> bool:
        """Prefill `req` into a free slot. Returns False if none free."""
        slot_id = next((i for i, s in enumerate(self.slots) if s.free), None)
        if slot_id is None:
            return False
        logits, st1 = self._prefill(self.params, req.prompt[None])
        # splice the single-sequence caches/length into the batch state
        caches = dict(self.state.caches)
        for name, buf in caches.items():
            caches[name] = buf.at[:, slot_id].set(
                st1.caches[name][:, 0].astype(buf.dtype))
        lengths = self.state.lengths.at[slot_id].set(st1.lengths[0])
        self.state = ServeState(caches=caches, lengths=lengths)
        tok = self.sample(logits)[0].astype(jnp.int32)
        self._next_tok = self._next_tok.at[slot_id].set(tok)
        self.slots[slot_id] = _Slot(request=req, generated=[int(tok)])
        return True

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One lock-step decode over all slots. Returns #active slots."""
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        logits, self.state = self._decode(self.params, self._next_tok,
                                          self.state)
        toks = self.sample(logits).astype(jnp.int32)
        self._next_tok = toks
        for i in active:
            slot = self.slots[i]
            tok = int(toks[i])
            slot.generated.append(tok)
            done = (len(slot.generated) >= slot.request.max_new_tokens or
                    tok == slot.request.eos_id)
            if done:
                self.finished[slot.request.uid] = slot.generated
                self.slots[i] = _Slot()
                # freeze the freed slot (its cache entries are dead weight
                # until the next insert overwrites them)
                self.state = self.state._replace(
                    lengths=self.state.lengths.at[i].set(0))
        return len([s for s in self.slots if not s.free])

    def run(self, requests: list[Request], max_steps: int = 10_000) -> dict:
        """Serve a request list to completion (greedy admission)."""
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.try_insert(pending[0]):
                pending.pop(0)
            if self.step() == 0 and not pending:
                break
        return self.finished

"""Continuous batching: slot-based request scheduling over the decode engine.

Production serving rarely sees aligned request batches; this layer keeps a
fixed pool of `num_slots` cache slots, prefills arriving requests into
free slots (one dynamic_update_slice per cache buffer), decodes all active
slots in lock-step, and evicts on EOS/max-tokens.  Per-slot `lengths`
already drive the attention masking, so slots at different positions
coexist in one batched decode step.

Compilation discipline: prompts are right-padded to power-of-two buckets
(`min_bucket` floor) and prefilled with a traced `true_len`, so the
prefill compiles once per *bucket*, not once per distinct prompt length —
pinned by `prefill_traces`.  Decode passes an explicit `active` mask so
evicted slots advance neither their lengths nor their caches (the
freed-slot freeze), and a request is finished before its next token would
write past `max_len` when the model has no sliding window (the "reject"
half of ring-or-reject; ring models keep going).

With ``mesh=`` the batcher drives `sharded_decode.make_mesh_serving`
instead of the single-device engine: params stay tensor-sharded on the
training `(data..., model)` mesh (pass the matching ``param_pspecs``) and
the caches live sharded via `decode_cache_pspecs`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.serving.engine import (ServeState, decode_step, init_serve_state,
                                  prefill)


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens plus stop conditions."""
    uid: int
    prompt: jax.Array            # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1             # -1 = never


@dataclasses.dataclass
class _Slot:
    """Per-slot bookkeeping: the resident request and its tokens so far."""
    request: Optional[Request] = None
    generated: list = dataclasses.field(default_factory=list)
    prompt_len: int = 0

    @property
    def free(self) -> bool:
        """Whether this slot can admit a new request."""
        return self.request is None


def _bucket(n: int, min_bucket: int) -> int:
    """Smallest power of two ≥ max(n, min_bucket)."""
    b = max(min_bucket, 1)
    while b < n:
        b *= 2
    return b


class ContinuousBatcher:
    """Drive a params+config pair as a multi-tenant decode server."""

    def __init__(self, params, cfg: ModelConfig, num_slots: int,
                 max_len: int, decode_kernel: str = "ref",
                 sample: Optional[Callable] = None,
                 prefill_buckets: bool = True, min_bucket: int = 8,
                 mesh=None, param_pspecs=None):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.state = init_serve_state(cfg, batch=num_slots, max_len=max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self._next_tok = jnp.zeros((num_slots,), jnp.int32)
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        self.prefill_buckets = prefill_buckets
        self.min_bucket = min_bucket
        self.prefill_traces = 0

        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.serving.sharded_decode import (decode_cache_pspecs,
                                                      make_mesh_serving)
            cspecs = decode_cache_pspecs(cfg, mesh)
            self.state = ServeState(
                caches={k: jax.device_put(v, NamedSharding(mesh, cspecs[k]))
                        for k, v in self.state.caches.items()},
                lengths=jax.device_put(self.state.lengths,
                                       NamedSharding(mesh, P())))
            pre, dec = make_mesh_serving(cfg, mesh, max_len,
                                         param_pspecs=param_pspecs,
                                         decode_kernel=decode_kernel)
        else:
            def pre(p, t, tl):
                return prefill(p, cfg, t, max_len, true_len=tl)

            def dec(p, t, s, a):
                return decode_step(p, cfg, t, s, decode_kernel=decode_kernel,
                                   active=a)

        def _counted_pre(p, t, tl):
            self.prefill_traces += 1
            return pre(p, t, tl)

        self._prefill = jax.jit(_counted_pre)
        self._decode = jax.jit(dec)
        self.finished: dict[int, list[int]] = {}
        self.completed: list[tuple[Request, list[int]]] = []

    def _active_mask(self) -> jax.Array:
        """(num_slots,) bool: which slots currently hold a request."""
        return jnp.asarray([not s.free for s in self.slots])

    # ------------------------------------------------------------- admission
    def try_insert(self, req: Request) -> bool:
        """Prefill `req` into a free slot. Returns False if none free."""
        slot_id = next((i for i, s in enumerate(self.slots) if s.free), None)
        if slot_id is None:
            return False
        prompt = jnp.asarray(req.prompt, jnp.int32)
        s = int(prompt.shape[0])
        b = _bucket(s, self.min_bucket) if self.prefill_buckets else s
        padded = jnp.pad(prompt, (0, b - s))
        logits, st1 = self._prefill(self.params, padded[None],
                                    jnp.asarray(s, jnp.int32))
        # splice the single-sequence caches/length into the batch state
        caches = dict(self.state.caches)
        for name, buf in caches.items():
            caches[name] = buf.at[:, slot_id].set(
                st1.caches[name][:, 0].astype(buf.dtype))
        lengths = self.state.lengths.at[slot_id].set(st1.lengths[0])
        self.state = ServeState(caches=caches, lengths=lengths)
        tok = self.sample(logits)[0].astype(jnp.int32)
        self._next_tok = self._next_tok.at[slot_id].set(tok)
        self.slots[slot_id] = _Slot(request=req, generated=[int(tok)],
                                    prompt_len=s)
        return True

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One lock-step decode over all slots. Returns #active slots."""
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        logits, self.state = self._decode(self.params, self._next_tok,
                                          self.state, self._active_mask())
        toks = self.sample(logits).astype(jnp.int32)
        self._next_tok = toks
        for i in active:
            slot = self.slots[i]
            tok = int(toks[i])
            slot.generated.append(tok)
            total = slot.prompt_len + len(slot.generated)
            done = (len(slot.generated) >= slot.request.max_new_tokens or
                    tok == slot.request.eos_id or
                    # reject: a full-attention cache must not wrap its ring
                    (self.cfg.sliding_window <= 0 and total >= self.max_len))
            if done:
                self.finished[slot.request.uid] = slot.generated
                self.completed.append((slot.request, list(slot.generated)))
                self.slots[i] = _Slot()
                # freeze the freed slot (its cache entries are dead weight
                # until the next insert overwrites them; the active mask
                # keeps decode from touching them meanwhile)
                self.state = self.state._replace(
                    lengths=self.state.lengths.at[i].set(0))
        return len([s for s in self.slots if not s.free])

    def drain_completed(self) -> list[tuple[Request, list[int]]]:
        """Return and clear finished (request, generated) pairs in order."""
        out, self.completed = self.completed, []
        return out

    def run(self, requests: list[Request], max_steps: int = 10_000) -> dict:
        """Serve a request list to completion (greedy admission)."""
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.try_insert(pending[0]):
                pending.pop(0)
            if self.step() == 0 and not pending:
                break
        return self.finished

"""Sequence-sharded decode attention (long-context serving, DESIGN §5).

For long_500k-class caches the KV sequence dim is sharded across the data
axes.  Each shard computes flash-decode partial statistics (m, ℓ, o) over
its local KV block; the exact global softmax is recovered with one psum
per statistic (log-sum-exp merge):

    m* = max_shards m_i                 (psum of exp-shifted works too; we
    ℓ* = Σ_i ℓ_i · exp(m_i − m*)         use pmax + two psums)
    o* = Σ_i o_i · ℓ_i·exp(m_i − m*) / ℓ*

This is flash-decoding's split-K reduction expressed as jax collectives —
communication is 2 scalars + one hd-vector per (batch, head), independent
of sequence length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_NEG = -1e30


def _partial_stats(q, k, v, valid, scale):
    """Local flash-decode partials. q:(B,H,hd) k,v:(B,W_loc,Hkv,hd),
    valid:(B,W_loc) bool. Returns m:(B,H), l:(B,H), o:(B,H,hd)."""
    bsz, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = (q.astype(jnp.float32) * scale).reshape(bsz, hkv, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = jnp.max(s, axis=-1)                                  # (B,g,r)
    p = jnp.exp(s - m[..., None])
    p = p * (s > _NEG / 2).astype(jnp.float32)               # all-masked → 0
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v.astype(jnp.float32))
    return (m.reshape(bsz, h), l.reshape(bsz, h),
            o.reshape(bsz, h, hd))


def sharded_decode_attention(
    q: jax.Array,        # (B, H, hd)      replicated over the seq shards
    k: jax.Array,        # (B, W, Hkv, hd) W sharded over `axes`
    v: jax.Array,
    lengths: jax.Array,  # (B,) global valid prefix
    mesh: Mesh,
    axes: tuple[str, ...] = ("data",),
    scale: float | None = None,
) -> jax.Array:
    """Exact decode attention over a sequence-sharded KV cache."""
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    w = k.shape[1]

    def body(q, k_loc, v_loc, lengths):
        # global position of each local slot
        from repro.core.collectives import axis_info
        shard_id, _ = axis_info(axes)
        w_loc = k_loc.shape[1]
        pos = shard_id * w_loc + jnp.arange(w_loc)
        valid = pos[None, :] < lengths[:, None]

        m, l, o = _partial_stats(q, k_loc, v_loc, valid, scale)
        m_star = jax.lax.pmax(m, axes[0]) if len(axes) == 1 else \
            functools.reduce(lambda a, ax: jax.lax.pmax(a, ax), axes, m)
        corr = jnp.exp(m - m_star)
        l_corr = l * corr
        o_corr = o * corr[..., None]
        for ax in axes:
            l_corr = jax.lax.psum(l_corr, ax)
            o_corr = jax.lax.psum(o_corr, ax)
        return (o_corr / jnp.maximum(l_corr[..., None], 1e-20)).astype(q.dtype)

    from repro.dist import shard_map
    from repro.dist.sharding import dim_spec
    kv_spec = P(None, dim_spec(axes), None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), kv_spec, kv_spec, P()),
        out_specs=P(),
    )(q, k, v, lengths)


# ------------------------------------------------- model-axis (mp) serving
def decode_cache_pspecs(cfg, mesh: Mesh) -> dict:
    """PartitionSpecs for every decode cache on a `(data..., model)` mesh.

    GQA k/v caches shard their KV-head axis and mamba states their
    channel axis over the model axes — matching the whole-head / block
    tensor sharding of the params; MLA latent/rope caches are replicated
    (head-independent).  The batch (slot) axis is replicated everywhere.
    Raises when the model-parallel degree does not divide the sharded
    dimension of a present layer type."""
    from repro.dist.sharding import dim_spec, model_axes
    maxes = model_axes(mesh)
    m = 1
    for ax in maxes:
        m *= mesh.shape[ax]
    ms = dim_spec(maxes)
    out: dict = {}
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.mixer == "attn":
            if cfg.attention == "mla":
                if cfg.num_heads % m:
                    raise ValueError(
                        f"model-parallel degree {m} must divide num_heads "
                        f"({cfg.num_heads}) for MLA decode")
                out[f"l{i}.attn.latent"] = P()
                out[f"l{i}.attn.rope"] = P()
            else:
                if cfg.num_kv_heads % m or cfg.num_heads % m:
                    raise ValueError(
                        f"model-parallel degree {m} must divide num_heads "
                        f"({cfg.num_heads}) and num_kv_heads "
                        f"({cfg.num_kv_heads}) for GQA decode")
                out[f"l{i}.attn.k"] = P(None, None, None, ms, None)
                out[f"l{i}.attn.v"] = P(None, None, None, ms, None)
        else:
            if cfg.resolved_d_inner % m:
                raise ValueError(
                    f"model-parallel degree {m} must divide d_inner "
                    f"({cfg.resolved_d_inner}) for mamba decode")
            out[f"l{i}.mamba.conv"] = P(None, None, None, ms)
            out[f"l{i}.mamba.h"] = P(None, None, ms, None)
    return out


def make_mesh_serving(cfg, mesh: Mesh, max_len: int,
                      param_pspecs=None, decode_kernel: str = "ref"):
    """Build (prefill_fn, decode_fn) running on the training mesh.

    Both are shard_map-wrapped (unjitted — the batcher jits them) over
    the full `(data..., model)` mesh: params enter with ``param_pspecs``
    (None = replicated), caches with `decode_cache_pspecs`, and the
    engine bodies run with ``model_axes`` so the per-layer math is
    head/channel-local with psum'd row-parallel outputs.  Token and slot
    axes are replicated, so every data shard computes the same logits —
    serving rides along on whatever mesh training owns.

    prefill_fn(params, tokens (B,S), true_len ()) -> (last_logits, state)
    decode_fn(params, tokens (B,), state, active (B,)) -> (logits, state)
    """
    from repro.dist import shard_map
    from repro.dist.sharding import model_axes
    from repro.serving.engine import ServeState, decode_step, prefill

    maxes = model_axes(mesh)
    cspecs = decode_cache_pspecs(cfg, mesh)
    state_specs = ServeState(caches=cspecs, lengths=P())
    pspec = param_pspecs if param_pspecs is not None else P()

    def _pre(p, t, tl):
        return prefill(p, cfg, t, max_len, true_len=tl, model_axes=maxes)

    def _dec(p, t, s, a):
        return decode_step(p, cfg, t, s, decode_kernel=decode_kernel,
                           active=a, model_axes=maxes)

    pre = shard_map(_pre, mesh=mesh, in_specs=(pspec, P(), P()),
                    out_specs=(P(), state_specs))
    dec = shard_map(_dec, mesh=mesh, in_specs=(pspec, P(), state_specs, P()),
                    out_specs=(P(), state_specs))
    return pre, dec

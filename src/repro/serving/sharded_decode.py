"""Sequence-sharded decode attention (long-context serving, DESIGN §5).

For long_500k-class caches the KV sequence dim is sharded across the data
axes.  Each shard computes flash-decode partial statistics (m, ℓ, o) over
its local KV block; the exact global softmax is recovered with one psum
per statistic (log-sum-exp merge):

    m* = max_shards m_i                 (psum of exp-shifted works too; we
    ℓ* = Σ_i ℓ_i · exp(m_i − m*)         use pmax + two psums)
    o* = Σ_i o_i · ℓ_i·exp(m_i − m*) / ℓ*

This is flash-decoding's split-K reduction expressed as jax collectives —
communication is 2 scalars + one hd-vector per (batch, head), independent
of sequence length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_NEG = -1e30


def _partial_stats(q, k, v, valid, scale):
    """Local flash-decode partials. q:(B,H,hd) k,v:(B,W_loc,Hkv,hd),
    valid:(B,W_loc) bool. Returns m:(B,H), l:(B,H), o:(B,H,hd)."""
    bsz, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = (q.astype(jnp.float32) * scale).reshape(bsz, hkv, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = jnp.max(s, axis=-1)                                  # (B,g,r)
    p = jnp.exp(s - m[..., None])
    p = p * (s > _NEG / 2).astype(jnp.float32)               # all-masked → 0
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v.astype(jnp.float32))
    return (m.reshape(bsz, h), l.reshape(bsz, h),
            o.reshape(bsz, h, hd))


def sharded_decode_attention(
    q: jax.Array,        # (B, H, hd)      replicated over the seq shards
    k: jax.Array,        # (B, W, Hkv, hd) W sharded over `axes`
    v: jax.Array,
    lengths: jax.Array,  # (B,) global valid prefix
    mesh: Mesh,
    axes: tuple[str, ...] = ("data",),
    scale: float | None = None,
) -> jax.Array:
    """Exact decode attention over a sequence-sharded KV cache."""
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    w = k.shape[1]

    def body(q, k_loc, v_loc, lengths):
        # global position of each local slot
        from repro.core.collectives import axis_info
        shard_id, _ = axis_info(axes)
        w_loc = k_loc.shape[1]
        pos = shard_id * w_loc + jnp.arange(w_loc)
        valid = pos[None, :] < lengths[:, None]

        m, l, o = _partial_stats(q, k_loc, v_loc, valid, scale)
        m_star = jax.lax.pmax(m, axes[0]) if len(axes) == 1 else \
            functools.reduce(lambda a, ax: jax.lax.pmax(a, ax), axes, m)
        corr = jnp.exp(m - m_star)
        l_corr = l * corr
        o_corr = o * corr[..., None]
        for ax in axes:
            l_corr = jax.lax.psum(l_corr, ax)
            o_corr = jax.lax.psum(o_corr, ax)
        return (o_corr / jnp.maximum(l_corr[..., None], 1e-20)).astype(q.dtype)

    from repro.dist import shard_map
    from repro.dist.sharding import dim_spec
    kv_spec = P(None, dim_spec(axes), None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), kv_spec, kv_spec, P()),
        out_specs=P(),
    )(q, k, v, lengths)

"""Batched serving engine: prefill + one-token decode over layer caches.

Cache layout (everything carries a leading period axis P so the decode step
scans over periods exactly like training does):

  GQA   k/v     (P, B, W, Hkv, hd)   W = sliding window (ring) or max_len
  MLA   latent  (P, B, W, kv_lora)   the *compressed* cache (absorbed decode)
        rope    (P, B, W, qk_rope)
  Mamba conv    (P, B, conv_w-1, d_inner)   constant-size recurrent state
        h       (P, B, d_inner, d_state)

Sliding-window caches are ring buffers: slot = position mod W.  RoPE is
applied at write time with absolute positions, so ring reordering is
harmless (softmax is permutation-invariant; validity is tracked by
`lengths` alone because a full ring holds exactly the last W tokens).

`decode_kernel="pallas"` routes GQA cache attention through the
flash-decode Pallas kernel; "ref" uses the jnp oracle (CPU / dry-run).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import embed, mlp, rmsnorm, rope, unembed
from repro.models.transformer import forward


class ServeState(NamedTuple):
    """Decode-loop carry: per-layer caches + per-row absolute positions."""
    caches: dict[str, jax.Array]   # name -> (P, ...) cache arrays
    lengths: jax.Array             # (B,) absolute tokens processed


def _window(cfg: ModelConfig, max_len: int) -> int:
    return min(cfg.sliding_window, max_len) if cfg.sliding_window > 0 else max_len


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStructs of every cache buffer (used by init and dry-run)."""
    p = cfg.num_periods
    w = _window(cfg, max_len)
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    out: dict[str, jax.ShapeDtypeStruct] = {}
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.mixer == "attn":
            if cfg.attention == "mla":
                out[f"l{i}.attn.latent"] = jax.ShapeDtypeStruct(
                    (p, batch, max_len, cfg.kv_lora_rank), dtype)
                out[f"l{i}.attn.rope"] = jax.ShapeDtypeStruct(
                    (p, batch, max_len, cfg.qk_rope_dim), dtype)
            else:
                kv = (p, batch, w, cfg.num_kv_heads, hd)
                out[f"l{i}.attn.k"] = jax.ShapeDtypeStruct(kv, dtype)
                out[f"l{i}.attn.v"] = jax.ShapeDtypeStruct(kv, dtype)
        else:
            di = cfg.resolved_d_inner
            out[f"l{i}.mamba.conv"] = jax.ShapeDtypeStruct(
                (p, batch, cfg.conv_width - 1, di), dtype)
            out[f"l{i}.mamba.h"] = jax.ShapeDtypeStruct(
                (p, batch, di, cfg.ssm_state), jnp.float32)
    return out


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> ServeState:
    """Allocate zeroed caches (see `cache_shapes`) and zero lengths."""
    caches = {k: jnp.zeros(v.shape, v.dtype)
              for k, v in cache_shapes(cfg, batch, max_len).items()}
    return ServeState(caches=caches, lengths=jnp.zeros((batch,), jnp.int32))


# ------------------------------------------------------------------ decode
def _gqa_decode(lp, hn, cfg: ModelConfig, k_cache, v_cache, pos, window,
                decode_kernel: str):
    """hn: (B,D); caches (B,W,Hkv,hd); pos: (B,) absolute position."""
    bsz = hn.shape[0]
    hd = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads

    q = (hn @ lp["wq"]).reshape(bsz, h, hd)
    k_new = (hn @ lp["wk"]).reshape(bsz, hkv, hd)
    v_new = (hn @ lp["wv"]).reshape(bsz, hkv, hd)
    q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    slot = pos % window
    barange = jnp.arange(bsz)
    k_cache = k_cache.at[barange, slot].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[barange, slot].set(v_new.astype(v_cache.dtype))
    lengths = jnp.minimum(pos + 1, window)

    if decode_kernel == "pallas":
        o = ops.decode_attention(q, k_cache, v_cache, lengths)
    else:
        o = ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    out = o.reshape(bsz, h * hd) @ lp["wo"]
    return out, k_cache, v_cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array,
                state: ServeState, decode_kernel: str = "ref",
                max_len: Optional[int] = None):
    """One new token per sequence. tokens: (B,) → (logits (B,V), state)."""
    specs = cfg.layer_specs()
    caches = state.caches
    pos = state.lengths                          # (B,)
    bsz = tokens.shape[0]
    any_cache = next(iter(caches.values()))
    # window is static: recover it from the cache buffers themselves
    h = embed(params["embed"], tokens[:, None], cfg)[:, 0]   # (B,D)

    def period_body(h, per):
        pp, pc = per
        new_pc = dict(pc)
        for i, spec in enumerate(specs):
            lp = pp[f"l{i}"]
            hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
            if spec.mixer == "attn":
                if cfg.attention == "mla":
                    out, latent_new, rope_new = attn_mod.mla_decode(
                        lp["mixer"], hn, cfg,
                        pc[f"l{i}.attn.latent"], pc[f"l{i}.attn.rope"],
                        pos, pos + 1)
                    slot = pos
                    ar = jnp.arange(bsz)
                    new_pc[f"l{i}.attn.latent"] = pc[f"l{i}.attn.latent"].at[
                        ar, slot].set(latent_new.astype(any_cache.dtype))
                    new_pc[f"l{i}.attn.rope"] = pc[f"l{i}.attn.rope"].at[
                        ar, slot].set(rope_new.astype(any_cache.dtype))
                else:
                    w = pc[f"l{i}.attn.k"].shape[1]
                    out, kc, vc = _gqa_decode(
                        lp["mixer"], hn, cfg, pc[f"l{i}.attn.k"],
                        pc[f"l{i}.attn.v"], pos, w, decode_kernel)
                    new_pc[f"l{i}.attn.k"] = kc
                    new_pc[f"l{i}.attn.v"] = vc
            else:
                mstate = ssm_mod.MambaState(conv=pc[f"l{i}.mamba.conv"],
                                            h=pc[f"l{i}.mamba.h"])
                out, mstate = ssm_mod.mamba_decode(lp["mixer"], hn, cfg, mstate)
                new_pc[f"l{i}.mamba.conv"] = mstate.conv
                new_pc[f"l{i}.mamba.h"] = mstate.h
            h = h + out
            if cfg.d_ff > 0:
                hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
                if spec.ff == "moe":
                    ff = moe_mod.moe(lp["ff"], hn[:, None], cfg,
                                     dropless=True).y[:, 0]
                else:
                    ff = mlp(lp["ff"], hn[:, None], cfg)[:, 0]
                h = h + ff
        return h, new_pc

    h, new_caches = jax.lax.scan(period_body, h, (params["layers"], caches))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg)
    return logits, ServeState(caches=new_caches, lengths=state.lengths + 1)


# ----------------------------------------------------------------- prefill
def prefill(params, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            embeds: Optional[jax.Array] = None, attn_impl: str = "ref"):
    """Process the prompt and build decode caches.

    tokens: (B, S_prompt).  Returns (last_logits (B,V), ServeState).
    attn_impl="pallas" routes prefill attention through the flash kernel.
    """
    bsz, s = tokens.shape
    logits, aux = forward(params, cfg, tokens, embeds=embeds,
                          collect_cache=True, attn_impl=attn_impl)
    n_front = embeds.shape[1] if embeds is not None else 0
    s_total = s + n_front
    w = _window(cfg, max_len)
    shapes = cache_shapes(cfg, bsz, max_len)
    caches = {}
    for name, sds in shapes.items():
        got = aux.cache[name]                   # (P, B, S_total, ...) or state
        buf = jnp.zeros(sds.shape, sds.dtype)
        if ".mamba." in name:
            caches[name] = got.astype(sds.dtype)
            continue
        cap = sds.shape[2]                      # W or max_len
        if s_total <= cap:
            buf = jax.lax.dynamic_update_slice_in_dim(
                buf, got.astype(sds.dtype), 0, axis=2)
        else:  # ring placement of the last `cap` positions
            tail = got[:, :, -cap:]
            positions = (jnp.arange(s_total - cap, s_total)) % cap
            buf = buf.at[:, :, positions].set(tail.astype(sds.dtype))
        caches[name] = buf
    st = ServeState(caches=caches,
                    lengths=jnp.full((bsz,), s_total, jnp.int32))
    return logits[:, -1], st


def generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
             max_len: int, decode_kernel: str = "ref",
             embeds: Optional[jax.Array] = None):
    """Greedy generation. Returns (B, steps) sampled tokens."""
    logits, st = prefill(params, cfg, prompt, max_len, embeds=embeds)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        toks.append(tok)
        logits, st = decode_step(params, cfg, tok, st,
                                 decode_kernel=decode_kernel)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(toks, axis=1)

"""Batched serving engine: prefill + one-token decode over layer caches.

Cache layout (everything carries a leading period axis P so the decode step
scans over periods exactly like training does):

  GQA   k/v     (P, B, W, Hkv, hd)   W = sliding window (ring) or max_len
  MLA   latent  (P, B, W, kv_lora)   the *compressed* cache (absorbed decode)
        rope    (P, B, W, qk_rope)
  Mamba conv    (P, B, conv_w-1, d_inner)   constant-size recurrent state
        h       (P, B, d_inner, d_state)

Every attention cache is a ring buffer: slot = position mod W.  RoPE is
applied at write time with absolute positions, so ring reordering is
harmless (softmax is permutation-invariant; validity is tracked by
`lengths` alone because a full ring holds exactly the last W tokens).
This holds for the MLA latent cache too — the absorbed-decode logits are
a sum over cache slots, so slot order never matters.  For full-attention
configs a wrapped ring silently forgets the oldest context; the batcher
enforces the "reject" half of ring-or-reject by finishing a request
before its total length would exceed `max_len` (see
serving/batcher.ContinuousBatcher).

`decode_kernel="pallas"` routes GQA cache attention through the
flash-decode Pallas kernel; "ref" uses the jnp oracle (CPU / dry-run).

Model parallelism: `decode_step`/`prefill` accept ``model_axes`` for use
inside shard_map on a `(data..., model)` mesh — the same whole-head /
channel-block tensor sharding as training (each sub-layer detects its own
shardedness from local parameter shapes via `attn_shard_info` /
`mla_shard_info` / `mamba_shard_info`).  GQA k/v caches shard their Hkv
axis and mamba states their channel axis; the MLA latent/rope caches are
replicated (they are head-independent).  `sharded_decode.make_mesh_serving`
builds the shard_map wrappers with the matching cache PartitionSpecs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import embed, mlp, rmsnorm, rope, unembed
from repro.models.transformer import forward


class ServeState(NamedTuple):
    """Decode-loop carry: per-layer caches + per-row absolute positions."""
    caches: dict[str, jax.Array]   # name -> (P, ...) cache arrays
    lengths: jax.Array             # (B,) absolute tokens processed


def _window(cfg: ModelConfig, max_len: int) -> int:
    return min(cfg.sliding_window, max_len) if cfg.sliding_window > 0 else max_len


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStructs of every cache buffer (used by init and dry-run)."""
    p = cfg.num_periods
    w = _window(cfg, max_len)
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    out: dict[str, jax.ShapeDtypeStruct] = {}
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.mixer == "attn":
            if cfg.attention == "mla":
                # same ring-or-reject sizing as GQA: a configured sliding
                # window bounds the cache, full attention gets max_len
                out[f"l{i}.attn.latent"] = jax.ShapeDtypeStruct(
                    (p, batch, w, cfg.kv_lora_rank), dtype)
                out[f"l{i}.attn.rope"] = jax.ShapeDtypeStruct(
                    (p, batch, w, cfg.qk_rope_dim), dtype)
            else:
                kv = (p, batch, w, cfg.num_kv_heads, hd)
                out[f"l{i}.attn.k"] = jax.ShapeDtypeStruct(kv, dtype)
                out[f"l{i}.attn.v"] = jax.ShapeDtypeStruct(kv, dtype)
        else:
            di = cfg.resolved_d_inner
            out[f"l{i}.mamba.conv"] = jax.ShapeDtypeStruct(
                (p, batch, cfg.conv_width - 1, di), dtype)
            out[f"l{i}.mamba.h"] = jax.ShapeDtypeStruct(
                (p, batch, di, cfg.ssm_state), jnp.float32)
    return out


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> ServeState:
    """Allocate zeroed caches (see `cache_shapes`) and zero lengths."""
    caches = {k: jnp.zeros(v.shape, v.dtype)
              for k, v in cache_shapes(cfg, batch, max_len).items()}
    return ServeState(caches=caches, lengths=jnp.zeros((batch,), jnp.int32))


# ------------------------------------------------------------------ decode
def _gqa_decode(lp, hn, cfg: ModelConfig, k_cache, v_cache, pos, window,
                decode_kernel: str, active: Optional[jax.Array] = None,
                model_axes: tuple[str, ...] = ()):
    """hn: (B,D); caches (B,W,Hkv,hd); pos: (B,) absolute position.

    ``active`` (B,) bool masks the cache write for evicted batcher slots
    (None = all rows live, the exact seed dataflow).  With ``model_axes``
    the projections are whole-head sharded (local Hkv caches) and the
    row-parallel wo output is psum-reduced."""
    from repro.core.collectives import psum_forward
    bsz = hn.shape[0]
    hd = cfg.resolved_head_dim
    sharded, h, hkv = (attn_mod.attn_shard_info(lp, cfg) if model_axes
                       else (False, cfg.num_heads, cfg.num_kv_heads))

    q = (hn @ lp["wq"]).reshape(bsz, h, hd)
    k_new = (hn @ lp["wk"]).reshape(bsz, hkv, hd)
    v_new = (hn @ lp["wv"]).reshape(bsz, hkv, hd)
    q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    slot = pos % window
    barange = jnp.arange(bsz)
    k_w = k_new.astype(k_cache.dtype)
    v_w = v_new.astype(v_cache.dtype)
    if active is not None:
        keep = active[:, None, None]
        k_w = jnp.where(keep, k_w, k_cache[barange, slot])
        v_w = jnp.where(keep, v_w, v_cache[barange, slot])
    k_cache = k_cache.at[barange, slot].set(k_w)
    v_cache = v_cache.at[barange, slot].set(v_w)
    lengths = jnp.minimum(pos + 1, window)

    if decode_kernel == "pallas":
        o = ops.decode_attention(q, k_cache, v_cache, lengths)
    else:
        o = ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    out = o.reshape(bsz, h * hd) @ lp["wo"]
    if sharded:
        out = psum_forward(out, model_axes)
    return out, k_cache, v_cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array,
                state: ServeState, decode_kernel: str = "ref",
                active: Optional[jax.Array] = None,
                model_axes: tuple[str, ...] = ()):
    """One new token per sequence. tokens: (B,) → (logits (B,V), state).

    ``active`` (B,) bool gates rows the batcher has evicted: inactive
    rows advance neither their length nor any cache buffer (their logits
    are garbage and discarded by the caller).  With ``active=None`` every
    row is live and the dataflow is bitwise the unmasked one.  Every
    cache write casts to its *own target buffer's* dtype, so hybrid
    stacks with mixed-precision caches (e.g. an f32 mamba `h` next to a
    low-precision MLA latent) round-trip each buffer correctly regardless
    of dict ordering."""
    specs = cfg.layer_specs()
    caches = state.caches
    pos = state.lengths                          # (B,)
    bsz = tokens.shape[0]
    # window is static: recover it from the cache buffers themselves
    h = embed(params["embed"], tokens[:, None], cfg,
              model_axes=model_axes)[:, 0]       # (B,D)

    def period_body(h, per):
        pp, pc = per
        new_pc = dict(pc)
        for i, spec in enumerate(specs):
            lp = pp[f"l{i}"]
            hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
            if spec.mixer == "attn":
                if cfg.attention == "mla":
                    lat = pc[f"l{i}.attn.latent"]
                    rp = pc[f"l{i}.attn.rope"]
                    # ring discipline, same as GQA: slot = pos mod W and a
                    # full ring is entirely valid (absolute-position RoPE
                    # at write time keeps reordering harmless)
                    w_mla = lat.shape[1]
                    slot = pos % w_mla
                    valid = jnp.minimum(pos + 1, w_mla)
                    out, latent_new, rope_new = attn_mod.mla_decode(
                        lp["mixer"], hn, cfg, lat, rp, pos, valid,
                        slot=slot, model_axes=model_axes)
                    ar = jnp.arange(bsz)
                    lat_w = latent_new.astype(lat.dtype)
                    rp_w = rope_new.astype(rp.dtype)
                    if active is not None:
                        lat_w = jnp.where(active[:, None], lat_w,
                                          lat[ar, slot])
                        rp_w = jnp.where(active[:, None], rp_w,
                                         rp[ar, slot])
                    new_pc[f"l{i}.attn.latent"] = lat.at[ar, slot].set(lat_w)
                    new_pc[f"l{i}.attn.rope"] = rp.at[ar, slot].set(rp_w)
                else:
                    w = pc[f"l{i}.attn.k"].shape[1]
                    out, kc, vc = _gqa_decode(
                        lp["mixer"], hn, cfg, pc[f"l{i}.attn.k"],
                        pc[f"l{i}.attn.v"], pos, w, decode_kernel,
                        active=active, model_axes=model_axes)
                    new_pc[f"l{i}.attn.k"] = kc
                    new_pc[f"l{i}.attn.v"] = vc
            else:
                mstate = ssm_mod.MambaState(conv=pc[f"l{i}.mamba.conv"],
                                            h=pc[f"l{i}.mamba.h"])
                out, mstate = ssm_mod.mamba_decode(lp["mixer"], hn, cfg,
                                                   mstate,
                                                   model_axes=model_axes)
                conv_w, h_w = mstate.conv, mstate.h
                if active is not None:
                    keep = active[:, None, None]
                    conv_w = jnp.where(keep, conv_w, pc[f"l{i}.mamba.conv"])
                    h_w = jnp.where(keep, h_w, pc[f"l{i}.mamba.h"])
                new_pc[f"l{i}.mamba.conv"] = conv_w
                new_pc[f"l{i}.mamba.h"] = h_w
            h = h + out
            if cfg.d_ff > 0:
                hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
                if spec.ff == "moe":
                    ff = moe_mod.moe(lp["ff"], hn[:, None], cfg,
                                     dropless=True,
                                     model_axes=model_axes).y[:, 0]
                else:
                    ff = mlp(lp["ff"], hn[:, None], cfg,
                             model_axes=model_axes)[:, 0]
                h = h + ff
        return h, new_pc

    h, new_caches = jax.lax.scan(period_body, h, (params["layers"], caches))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg, model_axes=model_axes)
    new_lengths = (state.lengths + 1 if active is None
                   else jnp.where(active, state.lengths + 1, state.lengths))
    return logits, ServeState(caches=new_caches, lengths=new_lengths)


# ----------------------------------------------------------------- prefill
def prefill(params, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            embeds: Optional[jax.Array] = None, attn_impl: str = "ref",
            true_len: Optional[jax.Array] = None,
            model_axes: tuple[str, ...] = ()):
    """Process the prompt and build decode caches.

    tokens: (B, S_prompt).  Returns (last_logits (B,V), ServeState).
    attn_impl="pallas" routes prefill attention through the flash kernel.

    ``true_len`` (a traced int32 scalar) enables *bucketed* prefill: the
    prompt arrives right-padded to a fixed bucket length S and only the
    first ``true_len`` tokens are real — so the batcher compiles one
    prefill per bucket, not one per distinct prompt length.  Correctness
    under right padding: causal attention never lets a real query see a
    padded key (pad positions are strictly later), and the mamba scan is
    made exact by zeroing Δ at pad positions (h_t = exp(Δ·A)h_{t-1} +
    Δ·B·x is the identity at Δ=0), with the conv window gathered at the
    true tail.  Cache placement resolves, per slot s of a cap-W buffer,
    the source position ``s + W·⌊(true_len−1−s)/W⌋`` — which is both the
    plain copy (true_len ≤ W) and the ring layout (true_len > W) the
    decode step's ``slot = pos mod W`` continues from.  One caveat:
    capacity-routed MoE prefill sees the pad tokens compete for expert
    capacity, so padded MoE routing can differ from the unpadded run
    (decode always routes dropless).

    ``model_axes`` threads the tensor-sharded forward for use inside
    shard_map (see `sharded_decode.make_mesh_serving`).
    """
    bsz, s = tokens.shape
    pad_mask = None
    if true_len is not None:
        if embeds is not None:
            raise ValueError("true_len (bucketed prefill) does not compose "
                             "with frontend embeds")
        true_len = jnp.asarray(true_len, jnp.int32)
        pad_mask = jnp.broadcast_to(jnp.arange(s)[None] < true_len, (bsz, s))
    logits, aux = forward(params, cfg, tokens, embeds=embeds,
                          collect_cache=True, attn_impl=attn_impl,
                          model_axes=model_axes, pad_mask=pad_mask)
    n_front = embeds.shape[1] if embeds is not None else 0
    s_total = s + n_front
    shapes = cache_shapes(cfg, bsz, max_len)
    caches = {}
    for name, sds in shapes.items():
        got = aux.cache[name]                   # (P, B, S_total, ...) or state
        if ".mamba." in name:
            caches[name] = got.astype(sds.dtype)
            continue
        cap = sds.shape[2]                      # W or max_len
        # trailing dims come from the collected cache itself so the same
        # code serves local (model-sharded) head/channel blocks
        buf = jnp.zeros(sds.shape[:3] + got.shape[3:], sds.dtype)
        if true_len is None:
            if s_total <= cap:
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, got.astype(sds.dtype), 0, axis=2)
            else:  # ring placement of the last `cap` positions
                tail = got[:, :, -cap:]
                positions = (jnp.arange(s_total - cap, s_total)) % cap
                buf = buf.at[:, :, positions].set(tail.astype(sds.dtype))
        else:
            sidx = jnp.arange(cap)
            src = sidx + cap * ((true_len - 1 - sidx) // cap)
            take = jnp.take(got, jnp.clip(src, 0, got.shape[2] - 1), axis=2)
            vmask = (src >= 0).reshape((1, 1, cap) + (1,) * (got.ndim - 3))
            buf = jnp.where(vmask, take.astype(sds.dtype), buf)
        caches[name] = buf
    if true_len is None:
        lengths = jnp.full((bsz,), s_total, jnp.int32)
        last = logits[:, -1]
    else:
        lengths = jnp.full((bsz,), true_len, jnp.int32)
        last = jnp.take(logits, true_len - 1, axis=1)
    return last, ServeState(caches=caches, lengths=lengths)


def generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
             max_len: int, decode_kernel: str = "ref",
             embeds: Optional[jax.Array] = None):
    """Greedy generation. Returns (B, steps) sampled tokens."""
    logits, st = prefill(params, cfg, prompt, max_len, embeds=embeds)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        toks.append(tok)
        logits, st = decode_step(params, cfg, tok, st,
                                 decode_kernel=decode_kernel)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(toks, axis=1)

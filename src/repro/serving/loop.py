"""The train/serve loop: decode on the training mesh, traffic back into
the store.

This is the paper's deployment story made concrete.  Three actors:

  * **ServeLoop** — a serve tick hooked between the scoring and master
    dispatches of each train step (`AsyncPipeline`/`StreamedISSGD`
    ``serve_tick``).  It decodes through a `ContinuousBatcher` against a
    `PublishedParams` snapshot — the model-weights analogue of the
    proposal's ``read_buf``: serving reads only published snapshots, so
    under publish cadence K it is at most K train steps stale, and the
    PR 2 swap invariant ("async ≡ relaxed with an L-step-staler
    proposal") extends verbatim to decode (pinned in
    tests/test_async.py::test_serve_snapshot_equals_explicit_stale_checkpoint).
  * **TrafficIngest** — finished requests (prompt + generated tokens)
    become store rows: written host-side into *pre-reserved* capacity
    chunks of the `ChunkedExampleStore` (reserved before any sharded
    placement, so chunk ownership never remaps), then flipped live in
    the WeightStore (`mark_live`: scored_at EMPTY → -1).  From there the
    round-robin scoring fan-out stamps and weights them like any other
    data, and they enter the two-stage proposal — live traffic reshaping
    the sampling distribution.
  * **make_synthetic_traffic** — the stand-in for "millions of users": a
    seeded request generator for smokes and tests.
"""
from __future__ import annotations

import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.weight_store import (BufferedWeightStore, mark_live,
                                     mark_live_buffered, publish_params)
from repro.serving.batcher import ContinuousBatcher, Request


class TrafficIngest:
    """Turn finished requests into store rows at a reserved-capacity
    watermark.

    Rows are ``prompt + generated`` token sequences zero-padded (or
    truncated) to ``seq_len``, written host-side via
    `ChunkedExampleStore.write_rows` into the index range
    ``[start_row, start_row + capacity_rows)``.  ``flush`` returns the
    global indices just written so the caller can `mark_live` them in the
    WeightStore; traffic past capacity is counted in ``dropped``."""

    def __init__(self, store, seq_len: int, start_row: int,
                 capacity_rows: int, label_key: Optional[str] = None):
        self.store = store
        self.seq_len = int(seq_len)
        self.start_row = int(start_row)
        self.capacity_rows = int(capacity_rows)
        self.label_key = label_key
        self.ingested = 0
        self.dropped = 0
        self._pending: list[np.ndarray] = []

    def add(self, prompt, generated) -> None:
        """Queue one finished request (prompt tokens + generated tokens)."""
        toks = np.concatenate([np.asarray(prompt).reshape(-1),
                               np.asarray(generated).reshape(-1)])
        row = np.zeros((self.seq_len,),
                       dtype=self.store.dtype(self._tokens_key()))
        toks = toks[:self.seq_len]
        row[:toks.size] = toks
        self._pending.append(row)

    def _tokens_key(self) -> str:
        keys = self.store.keys
        if "tokens" in keys:
            return "tokens"
        if len(keys) == 1:
            return keys[0]
        raise ValueError(f"cannot pick a token key from {keys}; expected a "
                         "'tokens' array in the store schema")

    def flush(self) -> np.ndarray:
        """Write queued rows at the watermark; return their global indices
        (empty when nothing fit).  LM stores carry next-token labels, so a
        ``label_key`` array gets the shifted row."""
        if not self._pending:
            return np.zeros((0,), np.int64)
        room = max(0, self.capacity_rows - self.ingested)
        rows, overflow = self._pending[:room], self._pending[room:]
        self._pending = []
        self.dropped += len(overflow)
        if not rows:
            return np.zeros((0,), np.int64)
        idx = self.start_row + self.ingested + np.arange(len(rows))
        tok = np.stack(rows)
        payload = {self._tokens_key(): tok}
        if self.label_key is not None and self.label_key in self.store.keys:
            lab = np.zeros_like(tok)
            lab[:, :-1] = tok[:, 1:]
            payload[self.label_key] = lab.astype(self.store.dtype(self.label_key))
        for k in self.store.keys:
            if k not in payload:
                payload[k] = np.zeros((tok.shape[0],) + self.store.row_shape(k),
                                      dtype=self.store.dtype(k))
        self.store.write_rows(idx, payload)
        self.ingested += len(rows)
        return idx


def make_synthetic_traffic(vocab: int, prompt_len: int, rate: int = 1,
                           max_new_tokens: int = 8, seed: int = 0) -> Callable:
    """A seeded request source: ``traffic(tick) -> [Request, ...]`` with
    ``rate`` random-token prompts per tick — the smoke/test stand-in for
    live user traffic."""
    rng = np.random.default_rng(seed)
    uids = itertools.count()

    def traffic(tick: int) -> list[Request]:
        return [Request(uid=next(uids),
                        prompt=rng.integers(0, vocab, size=(prompt_len,),
                                            dtype=np.int32),
                        max_new_tokens=max_new_tokens)
                for _ in range(rate)]

    return traffic


class ServeLoop:
    """Drive a ContinuousBatcher as a serve tick inside the train loop.

    ``on_train_step(state)`` (hook it as the pipeline's ``serve_tick``)
    refreshes the batcher's `PublishedParams` snapshot every
    ``publish_every`` ticks, admits new traffic, and runs ``decode_steps``
    lock-step decodes.  ``ingest_into(state)`` — called between steps,
    once the training dispatches of the tick have retired — drains
    finished requests into the store via `TrafficIngest` and flips their
    WeightStore rows live (on ``write_buf`` for a BufferedWeightStore, so
    the rows reach the master only through `publish`, preserving the
    swap-cadence staleness discipline).

    ``telemetry`` (telemetry.Telemetry) emits the serving counters at the
    telemetry cadence in ticks — serve.ingested / serve.dropped /
    serve.finished / serve.publishes / serve.pending — plus a
    serve.ingest_watermark counter on every nonzero flush (the reserved-
    capacity fill level)."""

    def __init__(self, batcher: ContinuousBatcher, ingest: TrafficIngest,
                 traffic: Callable, publish_every: int = 1,
                 serve_every: int = 1, decode_steps: int = 1,
                 telemetry=None):
        if publish_every < 1 or serve_every < 1:
            raise ValueError("publish_every and serve_every must be >= 1")
        self.batcher = batcher
        self.ingest = ingest
        self.traffic = traffic
        self.publish_every = int(publish_every)
        self.serve_every = int(serve_every)
        self.decode_steps = int(decode_steps)
        self.published = None          # PublishedParams snapshot
        self.pending: list[Request] = []
        self._tick = 0
        if telemetry is None:
            from repro.telemetry import Telemetry
            telemetry = Telemetry.null()
        self.telemetry = telemetry
        self.publishes = 0             # param snapshots taken
        self.finished = 0              # requests drained complete

    def on_train_step(self, state) -> None:
        """The serve tick: snapshot params on cadence, admit, decode."""
        t = self._tick
        self._tick += 1
        if t % self.serve_every:
            return
        if self.published is None or (t // self.serve_every) % self.publish_every == 0:
            self.published = publish_params(state.params, state.step)
            self.batcher.params = self.published.params
            self.publishes += 1
        self.pending.extend(self.traffic(t))
        while self.pending and self.batcher.try_insert(self.pending[0]):
            self.pending.pop(0)
        for _ in range(self.decode_steps):
            self.batcher.step()
        tel = self.telemetry
        if tel.due(t):
            tel.counter("serve.ingested", self.ingest.ingested, step=t)
            tel.counter("serve.dropped", self.ingest.dropped, step=t)
            tel.counter("serve.finished", self.finished, step=t)
            tel.counter("serve.publishes", self.publishes, step=t)
            tel.counter("serve.pending", len(self.pending), step=t)

    def ingest_into(self, state):
        """Drain finished requests into the example store + WeightStore;
        returns the state with newly live rows (same state when no
        traffic finished)."""
        for req, generated in self.batcher.drain_completed():
            self.ingest.add(req.prompt, generated)
            self.finished += 1
        idx = self.ingest.flush()
        if idx.size == 0:
            return state
        # the fill level of the reserved capacity range, after this flush
        self.telemetry.counter("serve.ingest_watermark", self.ingest.ingested,
                               step=self._tick)
        store = state.store
        if isinstance(store, BufferedWeightStore):
            store = mark_live_buffered(store, idx)
        else:
            store = mark_live(store, idx)
        return state._replace(store=store)

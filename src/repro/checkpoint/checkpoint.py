"""Checkpointing: flat-key npz of any pytree (params, optimizer state, the
ISSGD weight store) with step bookkeeping and atomic writes.

On a pod each host would save its addressable shards; here the host
gathers (CPU container).  The weight-store state is part of the
checkpoint — including the double-buffered ``BufferedWeightStore`` of the
async pipeline (``read_buf``/``write_buf``/``synced_at`` are plain
NamedTuple fields) — so a restored ISSGD run resumes with its importance
weights and their staleness timestamps intact: the "database" survives
restarts, like the paper's Redis instance would.

PRNG keys are serialized via their raw ``key_data`` (uint32) with the key
impl recorded in the manifest, so a restored run continues the *same*
random stream — together with the step counter this makes a streamed /
async resume bitwise identical to the uninterrupted run (the streaming
cursor is pure state: the round-robin scoring slice and the swap cadence
are functions of ``step``, and the device window rebuilds cold without
affecting values).  Old checkpoints without key data restore keys from
the template (the previous reseed-on-restore behavior).  bf16 arrays are
stored as uint16 views with a dtype manifest.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_PRNG_TAG = "prngkey:"


def _is_prng_key(x) -> bool:
    try:
        return jnp.issubdtype(x.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


class _KeyLeaf:
    """A PRNG key flattened to (raw uint32 data, impl name)."""

    def __init__(self, key):
        self.data = np.asarray(jax.random.key_data(key))
        try:
            self.impl = str(jax.random.key_impl(key))
        except Exception:
            warnings.warn("jax.random.key_impl failed; stamping the "
                          "checkpointed PRNG key as threefry2x32 — restore "
                          "on a matching jax version to keep the stream")
            self.impl = "threefry2x32"


def _wrap_key(data: np.ndarray, impl: str, template):
    try:
        return jax.random.wrap_key_data(jnp.asarray(data, jnp.uint32),
                                        impl=impl)
    except Exception:
        # unknown impl string on this jax version — the resume is NOT
        # bitwise from here (the key restarts from the template's value)
        warnings.warn(f"cannot rebuild a PRNG key with impl={impl!r} on "
                      "this jax version; keeping the template key — the "
                      "restored random stream will diverge from the "
                      "checkpointed run")
        return template


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        key = prefix.rstrip("/")
        out[key] = _KeyLeaf(tree) if _is_prng_key(tree) else np.asarray(tree)
    return out


def _unflatten_into(template: Any, flat: dict, prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template))
    key = prefix.rstrip("/")
    if key not in flat:
        return template  # anything missing keeps its current value
    if _is_prng_key(template):
        v = flat[key]
        if isinstance(v, tuple) and v[0] == _PRNG_TAG:
            return _wrap_key(v[1], v[2], template)
        return template  # pre-key-serialization checkpoint: keep the reseed
    return jnp.asarray(flat[key]).astype(getattr(template, "dtype", None))


def save_checkpoint(path: str | Path, tree: Any, step: int) -> Path:
    """Atomic save: write to a tmp file then rename."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    manifest, stored = {}, {}
    for k, v in _flatten(tree).items():
        if isinstance(v, _KeyLeaf):
            stored[k] = v.data
            manifest[k] = _PRNG_TAG + v.impl
        elif v.dtype == jnp.bfloat16:
            stored[k] = v.view(np.uint16)
            manifest[k] = "bfloat16"
        else:
            stored[k] = v
    tmp = tempfile.mktemp(dir=path.parent, suffix=".npz")
    np.savez(tmp, __step__=np.int64(step),
             __manifest__=np.frombuffer(
                 json.dumps(manifest).encode(), dtype=np.uint8),
             **stored)
    os.replace(tmp, path)
    return path


def restore_checkpoint(path: str | Path, template: Any) -> tuple[Any, int]:
    """Restore into the structure of `template`. Returns (tree, step)."""
    with np.load(path, allow_pickle=False) as z:
        step = int(z["__step__"])
        manifest = json.loads(bytes(z["__manifest__"].tobytes()).decode())
        flat = {}
        for k in z.files:
            if k.startswith("__"):
                continue
            v = z[k]
            tag = manifest.get(k, "")
            if tag == "bfloat16":
                v = v.view(jnp.bfloat16)
            elif tag.startswith(_PRNG_TAG):
                v = (_PRNG_TAG, v, tag[len(_PRNG_TAG):])
            flat[k] = v
    return _unflatten_into(template, flat), step

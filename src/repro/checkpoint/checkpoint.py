"""Checkpointing: flat-key npz of any pytree (params, optimizer state, the
ISSGD weight store) with step bookkeeping and atomic writes.

On a pod each host would save its addressable shards; here the host
gathers (CPU container).  The weight-store state is part of the
checkpoint — including the double-buffered ``BufferedWeightStore`` of the
async pipeline (``read_buf``/``write_buf``/``synced_at`` are plain
NamedTuple fields) — so a restored ISSGD run resumes with its importance
weights and their staleness timestamps intact: the "database" survives
restarts, like the paper's Redis instance would.

With ``gather=False`` a sharded array (model-parallel params, the
data-sharded weight table) is saved **gather-free**: each distinct
addressable shard is stored as its own entry (``<key>::shard<i>``) with
the global shape, dtype, and per-shard index slices recorded in the
manifest — no *device* ever holds the full array: save reads shards as
they sit, and restore reassembles through host RAM only (leaves come
back as numpy; the caller's re-placement, e.g. ``shard_train_state``,
moves each shard straight to its device).  Replica copies (e.g. the
store's model-axis replicas) are deduplicated by their index slices.
Sharded checkpoints restore into any topology — including a single
device — and old replicated checkpoints (no shard entries) keep
restoring exactly as before.

PRNG keys are serialized via their raw ``key_data`` (uint32) with the key
impl recorded in the manifest, so a restored run continues the *same*
random stream — together with the step counter this makes a streamed /
async resume bitwise identical to the uninterrupted run (the streaming
cursor is pure state: the round-robin scoring slice and the swap cadence
are functions of ``step``, and the device window rebuilds cold without
affecting values).  Old checkpoints without key data restore keys from
the template (the previous reseed-on-restore behavior).  bf16 arrays are
stored as uint16 views with a dtype manifest.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_PRNG_TAG = "prngkey:"


def _is_prng_key(x) -> bool:
    try:
        return jnp.issubdtype(x.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


class _KeyLeaf:
    """A PRNG key flattened to (raw uint32 data, impl name)."""

    def __init__(self, key):
        self.data = np.asarray(jax.random.key_data(key))
        try:
            self.impl = str(jax.random.key_impl(key))
        except Exception:
            warnings.warn("jax.random.key_impl failed; stamping the "
                          "checkpointed PRNG key as threefry2x32 — restore "
                          "on a matching jax version to keep the stream")
            self.impl = "threefry2x32"


def _wrap_key(data: np.ndarray, impl: str, template):
    try:
        return jax.random.wrap_key_data(jnp.asarray(data, jnp.uint32),
                                        impl=impl)
    except Exception:
        # unknown impl string on this jax version — the resume is NOT
        # bitwise from here (the key restarts from the template's value)
        warnings.warn(f"cannot rebuild a PRNG key with impl={impl!r} on "
                      "this jax version; keeping the template key — the "
                      "restored random stream will diverge from the "
                      "checkpointed run")
        return template


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass  # empty pytree node (e.g. WeightStore.qscale on f32 stores)
    else:
        key = prefix.rstrip("/")
        # leaves stay un-materialized: save_checkpoint decides per leaf
        # whether to gather (np.asarray) or store shard-by-shard
        out[key] = _KeyLeaf(tree) if _is_prng_key(tree) else tree
    return out


def _unflatten_into(template: Any, flat: dict, prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template))
    key = prefix.rstrip("/")
    if key not in flat:
        return template  # anything missing keeps its current value
    if _is_prng_key(template):
        v = flat[key]
        if isinstance(v, tuple) and v[0] == _PRNG_TAG:
            return _wrap_key(v[1], v[2], template)
        return template  # pre-key-serialization checkpoint: keep the reseed
    # stay on the HOST (numpy): a full param tensor must never land on one
    # device just to be re-sharded — the caller's placement (e.g.
    # shard_train_state) moves each shard straight to its device
    dtype = getattr(template, "dtype", None)
    arr = np.asarray(flat[key])
    return arr.astype(dtype) if dtype is not None else arr


_SHARD_TAG = "sharded:"
_SHARD_SEP = "::shard"


def _is_partially_sharded(x) -> bool:
    """A jax.Array whose addressable shards do NOT each cover the whole
    array (i.e. actually split, not merely replicated)."""
    if not isinstance(x, jax.Array):
        return False
    try:
        shards = x.addressable_shards
    except Exception:
        return False
    return (len(shards) > 1
            and any(s.data.shape != x.shape for s in shards))


def _store_sharded(k: str, x: jax.Array, stored: dict, manifest: dict):
    """Per-shard, gather-free storage of one sharded array: unique shards
    keyed by their index slices (replicas dropped), manifest records how
    to reassemble."""
    seen: dict[tuple, int] = {}
    slices = []
    for s in x.addressable_shards:
        idx = tuple((sl.start or 0, sl.stop if sl.stop is not None else dim)
                    for sl, dim in zip(s.index, x.shape))
        if idx in seen:
            continue
        i = seen[idx] = len(seen)
        data = np.asarray(s.data)
        if data.dtype == jnp.bfloat16:
            data = data.view(np.uint16)
        stored[f"{k}{_SHARD_SEP}{i}"] = data
        slices.append([[int(a), int(b)] for a, b in idx])
    manifest[k] = _SHARD_TAG + json.dumps({
        "shape": list(x.shape), "dtype": str(x.dtype), "slices": slices})


def save_checkpoint(path: str | Path, tree: Any, step: int,
                    gather: bool = True) -> Path:
    """Atomic save: write to a tmp file then rename.  ``gather=False``
    stores sharded arrays shard-by-shard (see module docstring) instead of
    gathering them to the host."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    manifest, stored = {}, {}
    for k, v in _flatten(tree).items():
        if isinstance(v, _KeyLeaf):
            stored[k] = v.data
            manifest[k] = _PRNG_TAG + v.impl
        elif not gather and _is_partially_sharded(v):
            _store_sharded(k, v, stored, manifest)
        else:
            v = np.asarray(v)
            if v.dtype == jnp.bfloat16:
                stored[k] = v.view(np.uint16)
                manifest[k] = "bfloat16"
            else:
                stored[k] = v
    tmp = tempfile.mktemp(dir=path.parent, suffix=".npz")
    np.savez(tmp, __step__=np.int64(step),
             __manifest__=np.frombuffer(
                 json.dumps(manifest).encode(), dtype=np.uint8),
             **stored)
    os.replace(tmp, path)
    return path


def _reassemble_sharded(meta: dict, shards: dict) -> np.ndarray:
    """Rebuild one array from its per-shard entries + manifest slices."""
    dtype = meta["dtype"]
    view_u16 = dtype == "bfloat16"
    out = np.empty(tuple(meta["shape"]),
                   np.uint16 if view_u16 else np.dtype(dtype))
    for i, idx in enumerate(meta["slices"]):
        out[tuple(slice(a, b) for a, b in idx)] = shards[i]
    return out.view(jnp.bfloat16) if view_u16 else out


def restore_checkpoint(path: str | Path, template: Any) -> tuple[Any, int]:
    """Restore into the structure of `template`. Returns (tree, step).
    Gather-free (sharded) entries are reassembled to full host arrays —
    re-place the restored tree (e.g. `shard_train_state`) to put shards
    back on a mesh."""
    with np.load(path, allow_pickle=False) as z:
        step = int(z["__step__"])
        manifest = json.loads(bytes(z["__manifest__"].tobytes()).decode())
        flat = {}
        shard_parts: dict[str, dict] = {}
        for k in z.files:
            if k.startswith("__"):
                continue
            if _SHARD_SEP in k:
                base, _, i = k.rpartition(_SHARD_SEP)
                shard_parts.setdefault(base, {})[int(i)] = z[k]
                continue
            v = z[k]
            tag = manifest.get(k, "")
            if tag == "bfloat16":
                v = v.view(jnp.bfloat16)
            elif tag.startswith(_PRNG_TAG):
                v = (_PRNG_TAG, v, tag[len(_PRNG_TAG):])
            flat[k] = v
        for base, parts in shard_parts.items():
            meta = json.loads(manifest[base][len(_SHARD_TAG):])
            flat[base] = _reassemble_sharded(meta, parts)
    return _unflatten_into(template, flat), step

"""Checkpointing: flat-key npz of any pytree (params, optimizer state, the
ISSGD weight store) with step bookkeeping and atomic writes.

On a pod each host would save its addressable shards; here the host
gathers (CPU container).  The weight-store state is part of the
checkpoint, so a restored ISSGD run resumes with its importance weights
and their staleness timestamps intact — the "database" survives restarts,
like the paper's Redis instance would.

PRNG key arrays are not serialized (they are reseeded on restore); bf16
arrays are stored as uint16 views with a dtype manifest.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SKIP = "__skip__"


def _is_prng_key(x) -> bool:
    try:
        return jnp.issubdtype(x.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        key = prefix.rstrip("/")
        out[key] = _SKIP if _is_prng_key(tree) else np.asarray(tree)
    return out


def _unflatten_into(template: Any, flat: dict, prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template))
    key = prefix.rstrip("/")
    if _is_prng_key(template) or key not in flat:
        return template  # PRNG keys (and anything skipped) keep current value
    return jnp.asarray(flat[key]).astype(getattr(template, "dtype", None))


def save_checkpoint(path: str | Path, tree: Any, step: int) -> Path:
    """Atomic save: write to a tmp file then rename."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    manifest, stored = {}, {}
    for k, v in _flatten(tree).items():
        if isinstance(v, str) and v == _SKIP:
            continue
        if v.dtype == jnp.bfloat16:
            stored[k] = v.view(np.uint16)
            manifest[k] = "bfloat16"
        else:
            stored[k] = v
    tmp = tempfile.mktemp(dir=path.parent, suffix=".npz")
    np.savez(tmp, __step__=np.int64(step),
             __manifest__=np.frombuffer(
                 json.dumps(manifest).encode(), dtype=np.uint8),
             **stored)
    os.replace(tmp, path)
    return path


def restore_checkpoint(path: str | Path, template: Any) -> tuple[Any, int]:
    """Restore into the structure of `template`. Returns (tree, step)."""
    with np.load(path, allow_pickle=False) as z:
        step = int(z["__step__"])
        manifest = json.loads(bytes(z["__manifest__"].tobytes()).decode())
        flat = {}
        for k in z.files:
            if k.startswith("__"):
                continue
            v = z[k]
            if manifest.get(k) == "bfloat16":
                v = v.view(jnp.bfloat16)
            flat[k] = v
    return _unflatten_into(template, flat), step

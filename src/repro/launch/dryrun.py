import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination, lower + compile
the real step function against ShapeDtypeStruct stand-ins (no allocation),
then extract:

  * memory_analysis()  — per-device bytes (proves the sharding fits)
  * cost_analysis()    — per-device FLOPs / bytes accessed (roofline)
  * collective bytes   — parsed from the partitioned HLO text

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod both
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, arch_for_shape, prefill_input_specs,
                                 serve_cache_specs, serve_param_shardings,
                                 train_dataset_specs, train_state_specs)

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the partitioned module.

    Scans for `<dtype>[dims]{...} <collective-op>(` definitions; while-loop
    bodies appear once, so totals are multiplied by trip counts separately
    (we report raw static bytes + per-collective counts)."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # output shape(s) appear before the op name: take ALL shapes on the
        # lhs (tuple outputs) up to the op token
        lhs = line[:m.start()]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def scan_trip_count(cfg) -> int:
    return cfg.num_periods


def _build_train(cfg, shape, mesh, variant: str = "baseline"):
    """Returns (fn, args_shape, in_shardings, out_shardings).

    variant:
      baseline    paper-faithful: separate scoring pass every step
      fused       §Perf optimization: scores emitted by the train forward
                  (coverage probes amortized outside the step)
    """
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, make_train_step
    from repro.core.scorer import make_lm_scorer
    from repro.models.transformer import (per_example_loss,
                                          per_example_loss_and_score)
    from repro.optim import sgd

    n = 2 * shape.global_batch
    data_shape, data_shard = train_dataset_specs(cfg, shape, mesh, n)
    state_shape, state_shard = train_state_specs(cfg, shape, mesh, n)

    opt = sgd(1e-2)  # the paper's optimizer: plain SGD, no state
    tcfg = ISSGDConfig(
        batch_size=shape.global_batch,
        score_batch_size=shape.global_batch,   # workers ≈ one batch per step
        refresh_every=8,
        mode="fused" if variant.startswith("fused") else "relaxed",
        is_cfg=ISConfig(smoothing=1.0))
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import data_axes

    dp = data_axes(mesh)

    def constrain(batch):
        return {
            k: jax.lax.with_sharding_constraint(
                v, jax.sharding.NamedSharding(
                    mesh, P(dp, *([None] * (v.ndim - 1)))))
            for k, v in batch.items()
        }

    step = make_train_step(
        lambda p, b: per_example_loss(p, cfg, b)[0],
        make_lm_scorer(cfg, "logit_grad"),
        opt, tcfg, n, constrain_batch=constrain,
        fused_score=lambda p, b: per_example_loss_and_score(p, cfg, b))
    return (step, (state_shape, data_shape), (state_shard, data_shard),
            None)


def _build_decode(cfg, shape, mesh):
    from repro.serving.engine import decode_step

    params_shape, pshard = serve_param_shardings(cfg, mesh)
    state_shape, state_shard = serve_cache_specs(cfg, shape, mesh)
    b = shape.global_batch
    toks = jax.ShapeDtypeStruct((b,), jnp.int32)
    tshard = state_shard.lengths

    def step(params, tokens, state):
        return decode_step(params, cfg, tokens, state, decode_kernel="ref")

    return (step, (params_shape, toks, state_shape),
            (pshard, tshard, state_shard), None)


def _build_prefill(cfg, shape, mesh):
    from repro.serving.engine import prefill

    params_shape, pshard = serve_param_shardings(cfg, mesh)
    (toks, emb), (tshard, eshard) = prefill_input_specs(cfg, shape, mesh)

    if emb is not None:
        def step(params, tokens, embeds):
            return prefill(params, cfg, tokens, max_len=shape.seq_len,
                           embeds=embeds)
        return step, (params_shape, toks, emb), (pshard, tshard, eshard), None

    def step(params, tokens):
        return prefill(params, cfg, tokens, max_len=shape.seq_len)
    return step, (params_shape, toks), (pshard, tshard), None


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Path, smoke: bool = False,
            variant: str = "baseline") -> dict:
    import dataclasses as _dc
    shape = SHAPES[shape_name]
    if smoke:  # pipeline validation: reduced model, same wiring
        from repro.configs import get_smoke_config
        shape = _dc.replace(shape, seq_len=min(shape.seq_len, 512))
        cfg = arch_for_shape(get_smoke_config(arch), shape)
    else:
        cfg = arch_for_shape(get_config(arch), shape)
    # config-level perf knobs encoded in the variant name (§Perf)
    if "cap1" in variant:
        cfg = _dc.replace(cfg, moe_capacity_factor=1.0)
    if "bf16scan" in variant:
        cfg = _dc.replace(cfg, ssm_scan_dtype="bfloat16")
    m = re.search(r"unroll(\d+)", variant)
    if m:
        cfg = _dc.replace(cfg, ssm_scan_unroll=int(m.group(1)))
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    builders = {"train": _build_train, "prefill": _build_prefill,
                "decode": _build_decode}
    if shape.kind == "train":
        fn, args, in_shard, out_shard = _build_train(cfg, shape, mesh,
                                                     variant=variant)
    else:
        fn, args, in_shard, out_shard = builders[shape.kind](cfg, shape, mesh)

    from repro.dist.context import activation_sharding
    from repro.dist.sharding import data_axes
    batch_axes = data_axes(mesh) if shape.global_batch > 1 else ()
    with mesh, activation_sharding(mesh, batch_axes):
        jitted = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    from repro.launch.hlo_cost import analyze
    walked = analyze(hlo_text)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "num_periods": cfg.num_periods,
        # raw XLA numbers (while bodies counted ONCE — see hlo_cost.py)
        "flops_per_device_raw": float(cost.get("flops", -1)),
        "bytes_accessed_per_device_raw": float(cost.get("bytes accessed", -1)),
        "collectives_raw": coll,
        # loop-scaled walker numbers (trip-count-aware; roofline source)
        "flops_per_device": walked.flops,
        "io_bytes_per_device": walked.io_bytes,
        "collective_bytes_per_device": walked.collective_bytes,
        "collective_by_op": walked.collective_by_op,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "variant": variant,
        "ok": True,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if variant != "baseline":
        tag += f"__{variant}"
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs, same wiring (pipeline check)")
    ap.add_argument("--variant", default="baseline",
                    help="baseline | fused | fused_cap1 | fused_bf16scan ...")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    out_dir = Path(args.out)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_one(arch, shape, mp, out_dir, smoke=args.smoke,
                                variant=args.variant)
                    print(f"[ok] {tag}: flops/dev={r['flops_per_device']:.3e} "
                          f"coll={r['collective_bytes_per_device']:.3e}B "
                          f"compile={r['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append(tag)
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"{len(failures)} FAILURES: {failures}")
        sys.exit(1)
    print("all dry-runs compiled OK")


if __name__ == "__main__":
    main()

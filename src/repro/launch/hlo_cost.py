"""Loop-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-reports FLOPs/bytes/collectives for scan-over-layers programs by the
trip count (64× for a 64-layer stack).  This walker parses the optimized
HLO, builds a per-computation symbol table (op name → shape), and
recursively multiplies every called computation (while bodies, fusions)
by its trip count:

  flops            2·|out|·K for dot ops (K = product of lhs contracting
                   dims, resolved through the symbol table), conv flops
  collective_bytes output bytes of all-gather / all-reduce / reduce-scatter /
                   all-to-all / collective-permute
  io_bytes         output bytes of materializing ops (fusions, dots,
                   copies, collectives) — a post-fusion buffer-write proxy
                   for HBM traffic

Trip counts come from the loop condition's `compare(iv, constant)` pattern
produced by the jax scan/while lowering.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_OP = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:calls=|body=|to_apply=)%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _first_shape(text: str):
    """(elems, bytes) of the first typed shape in `text`, or None."""
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        return n, n * _DTYPE_BYTES[dt]
    return None


def _all_shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str):
    """Dims list of the first typed shape."""
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        return [int(d) for d in dims.split(",") if d]
    return None


@dataclass
class Cost:
    flops: float = 0.0
    collective_bytes: float = 0.0
    io_bytes: float = 0.0
    collective_by_op: dict = field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.collective_bytes += other.collective_bytes * times
        self.io_bytes += other.io_bytes * times
        for k, v in other.collective_by_op.items():
            self.collective_by_op[k] = self.collective_by_op.get(k, 0) + v * times


def parse_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith("  ") and "{" in line and "->" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _symbols(lines: list[str]) -> dict[str, list[int]]:
    """name -> output shape dims for every op in a computation."""
    table: dict[str, list[int]] = {}
    for line in lines:
        m = _OP.match(line)
        if not m:
            continue
        dims = _shape_dims(m.group(2))
        if dims is not None:
            table[m.group(1)] = dims
    return table


_TYPED_OPERAND = re.compile(
    r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s+%([\w.\-]+)")


def _operand_dims(body: str, op: str, table: dict) -> list:
    """Output dims of each operand of `op`, resolved either from the typed
    inline shapes (modern HLO: ``dot(f32[4,64]{1,0} %x, …)``) or through
    the symbol table (bare ``dot(%x, %w)``)."""
    m = re.search(rf"\b{op}\(([^)]*)\)", body)
    if not m:
        return []
    text = m.group(1)
    typed = _TYPED_OPERAND.findall(text)
    if typed:
        return [[int(d) for d in dims.split(",") if d] for _, dims, _ in typed]
    return [table.get(n.strip().lstrip("%"))
            for n in text.split(",") if n.strip()]


def _dot_flops(body: str, table: dict) -> float:
    out = _first_shape(body)
    if out is None:
        return 0.0
    k = 1
    cm = _LHS_CONTRACT.search(body)
    operands = _operand_dims(body, "dot", table)
    lhs_dims = operands[0] if operands else None
    if cm and cm.group(1) and lhs_dims:
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out[0] * k


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for c in _CONST_INT.findall(line):
            best = max(best, int(c))
    return best


def analyze(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))

    cache: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in cache:
            return cache[name]
        cache[name] = Cost()          # cycle guard
        lines = comps.get(name, [])
        table = _symbols(lines)
        total = Cost()
        for line in lines:
            m = _OP.match(line)
            if not m:
                continue
            body = m.group(2)
            if re.search(r"\bwhile\(", body):
                cm = _CALLS.search(body)
                dm = _COND.search(body)
                trip = _trip_count(comps.get(dm.group(1), [])) if dm else 1
                if cm:
                    total.add(comp_cost(cm.group(1)), times=trip)
                continue
            if re.search(r"\b(fusion|call|conditional)\(", body):
                for sub in _CALLS.findall(body):
                    total.add(comp_cost(sub))
                out = _first_shape(body)
                if out:
                    total.io_bytes += out[1]
                continue
            coll = next((c for c in _COLLECTIVES if f" {c}(" in body
                         or f"{c}-start(" in body or body.startswith(f"{c}(")),
                        None)
            if coll:
                nbytes = _all_shape_bytes(body.split(coll)[0])
                total.collective_bytes += nbytes
                total.collective_by_op[coll] = (
                    total.collective_by_op.get(coll, 0) + nbytes)
                total.io_bytes += nbytes
                continue
            if re.search(r"\bdot\(", body):
                total.flops += _dot_flops(body, table)
                out = _first_shape(body)
                if out:
                    total.io_bytes += out[1]
                continue
            if re.search(r"\bconvolution\(", body):
                out = _first_shape(body)
                if out:
                    operands = _operand_dims(body, "convolution", table)
                    ker = operands[1] if len(operands) > 1 else None
                    if ker:
                        kelems = 1
                        for d in ker:
                            kelems *= d
                        total.flops += 2.0 * out[0] * kelems / max(ker[0], 1)
                    total.io_bytes += out[1]
                continue
            if re.search(r"\b(copy|copy-start|dynamic-update-slice|gather|"
                         r"scatter|sort|dynamic-slice)\(", body):
                out = _first_shape(body)
                if out:
                    total.io_bytes += out[1]
        cache[name] = total
        return total

    return comp_cost(entry)

"""Production mesh definitions (TPU v5e).

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading
    "pod" axis (data-parallel across the slower inter-pod links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n: int | None = None, model: int = 1):
    """A mesh over forced host devices (tests on CPU): 1-D ``(data,)`` by
    default, 2-D ``(data, model)`` when ``model > 1`` — the debug twin of
    the production mesh's trailing tensor-parallel axis."""
    if model > 1:
        n = n or len(jax.devices()) // model
        return jax.make_mesh((n, model), ("data", "model"))
    n = n or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link

"""Serving launcher: batched prefill + decode on a reduced config (CPU) or
the production mesh (TPU).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
      --batch 4 --prompt-len 16 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models.transformer import init_transformer
from repro.serving.engine import decode_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--kernel", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.max_len or (args.prompt_len + args.steps)
    params = init_transformer(jax.random.key(args.seed), cfg)
    prompt = jax.random.randint(jax.random.key(args.seed + 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    embeds = None
    if cfg.frontend != "none":
        embeds = jax.random.normal(
            jax.random.key(args.seed + 2),
            (args.batch, min(cfg.num_frontend_tokens, 8), cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02

    t0 = time.time()
    logits, st = jax.jit(
        lambda p, t, e: prefill(p, cfg, t, max_len=max_len, embeds=e))(
            params, prompt, embeds)
    print(f"prefill: {args.batch}x{args.prompt_len} in "
          f"{time.time() - t0:.2f}s")

    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s,
                                               decode_kernel=args.kernel))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.steps):
        logits, st = step(params, tok, st)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    dt = time.time() - t0
    print(f"decode: {args.steps} steps × {args.batch} seqs in {dt:.2f}s "
          f"({args.steps * args.batch / dt:.1f} tok/s)")
    print("sample:", jnp.stack(outs, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()

"""Assigned input shapes + ShapeDtypeStruct stand-ins and shardings for the
multi-pod dry-run (no device allocation ever happens here)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import data_axes, param_pspecs, rules_for
from repro.models.config import ModelConfig
from repro.models.transformer import transformer_specs


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}

# window used when a pure-attention arch runs the long-context shape
LONG_CONTEXT_WINDOW = 8_192


def arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments (see DESIGN.md §Arch-applicability):
    pure-attention archs switch to sliding-window attention for long_500k;
    big-vocab configs use the chunked LM head for training shapes."""
    if shape.kind == "train" and cfg.loss_chunk == 0:
        cfg = dataclasses.replace(cfg, loss_chunk=512)
    if (shape.name == "long_500k" and cfg.ssm_state == 0
            and cfg.sliding_window == 0):
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _dp(mesh, size: int):
    """The data-parallel axes that evenly divide `size` (batch=1 → none)."""
    axes = [a for a in data_axes(mesh)]
    keep = []
    prod = 1
    for a in axes:
        if size % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


# ------------------------------------------------------------------- train
def train_dataset_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                        num_examples: int | None = None):
    """ShapeDtypeStructs + shardings for the device-resident dataset."""
    n = num_examples or 2 * shape.global_batch
    dp = _dp(mesh, n)
    s_text = shape.seq_len - cfg.num_frontend_tokens
    data = {"tokens": _sds((n, s_text + 1), jnp.int32)}
    shard = {"tokens": _ns(mesh, dp, None)}
    if cfg.frontend != "none":
        data["embeds"] = _sds((n, cfg.num_frontend_tokens, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        shard["embeds"] = _ns(mesh, dp, None, None)
    return data, shard


def train_state_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                      num_examples: int):
    """Abstract TrainState (plain-SGD ISSGD, the paper's optimizer)."""
    from repro.core.issgd import TrainState
    from repro.models.transformer import init_transformer

    params_shape = jax.eval_shape(
        lambda k: init_transformer(k, cfg), jax.random.key(0))
    pspecs = param_pspecs(transformer_specs(cfg), params_shape, mesh)
    pshard = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    dp = _dp(mesh, num_examples)
    from repro.core.weight_store import WeightStore
    store = WeightStore(weights=_sds((num_examples,), jnp.float32),
                        scored_at=_sds((num_examples,), jnp.int32))
    store_shard = WeightStore(weights=_ns(mesh, dp),
                              scored_at=_ns(mesh, dp))
    key_shape = jax.eval_shape(lambda: jax.random.key(0))
    state = TrainState(params=params_shape, opt_state=(),
                       stale_params=params_shape, store=store,
                       step=_sds((), jnp.int32), rng=key_shape)
    shard = TrainState(params=pshard, opt_state=(), stale_params=pshard,
                       store=store_shard, step=_ns(mesh),
                       rng=_ns(mesh))
    return state, shard


# ------------------------------------------------------------------- serve
def serve_cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """Abstract ServeState + shardings.

    KV caches shard batch over the data axes and the cache-sequence dim
    over `model` (long-context: over everything that divides).
    """
    from repro.serving.engine import ServeState, cache_shapes

    b = shape.global_batch
    dp = _dp(mesh, b)
    shapes = cache_shapes(cfg, b, shape.seq_len)
    caches, shards = {}, {}
    for name, sds in shapes.items():
        caches[name] = sds
        if ".mamba.conv" in name:
            shards[name] = _ns(mesh, None, dp, None, "model")
        elif ".mamba.h" in name:
            shards[name] = _ns(mesh, None, dp, "model", None)
        elif name.endswith(".latent") or name.endswith(".rope"):
            w_ax = "model" if dp is not None else ("data", "model")
            shards[name] = _ns(mesh, None, dp, w_ax, None)
        else:  # gqa k/v: (P, B, W, Hkv, hd)
            w_ax = "model" if dp is not None else ("data", "model")
            w = sds.shape[2]
            axes_sz = (mesh.shape["model"] if w_ax == "model" else
                       mesh.shape["data"] * mesh.shape["model"])
            if w % axes_sz != 0:
                w_ax = None
            shards[name] = _ns(mesh, None, dp, w_ax, None, None)
    state = ServeState(caches=caches,
                       lengths=_sds((b,), jnp.int32))
    shard = ServeState(caches=shards, lengths=_ns(mesh, dp))
    return state, shard


def serve_param_shardings(cfg: ModelConfig, mesh: Mesh):
    from repro.models.transformer import init_transformer
    params_shape = jax.eval_shape(
        lambda k: init_transformer(k, cfg), jax.random.key(0))
    pspecs = param_pspecs(transformer_specs(cfg), params_shape, mesh)
    return params_shape, jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspecs,
        is_leaf=lambda x: isinstance(x, P))


def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    b = shape.global_batch
    dp = _dp(mesh, b)
    s_text = shape.seq_len - cfg.num_frontend_tokens
    toks = _sds((b, s_text), jnp.int32)
    tshard = _ns(mesh, dp, None)
    if cfg.frontend != "none":
        emb = _sds((b, cfg.num_frontend_tokens, cfg.d_model),
                   jnp.dtype(cfg.dtype))
        eshard = _ns(mesh, dp, None, None)
        return (toks, emb), (tshard, eshard)
    return (toks, None), (tshard, None)

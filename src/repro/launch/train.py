"""ISSGD training launcher.

On real hardware this runs the full distributed ISSGD loop on the
production mesh; on CPU it runs reduced configs end-to-end (the same code
path, smaller mesh), e.g.:

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 50 --batch 8 --seq 64 --strategy logit_grad
  PYTHONPATH=src python -m repro.launch.train --arch mlp_svhn --steps 300

Sharded execution (`core/distributed.py`): `--mesh N` runs the step under
shard_map on an N-device data mesh — dataset, WeightStore, and the scoring
fan-out sharded over the data axis, hierarchical two-stage sampling, no
full-table gathers.  On CPU, N host devices are forced via XLA_FLAGS
automatically, so the whole path works without a pod:

  PYTHONPATH=src python -m repro.launch.train --arch mlp_svhn --smoke --mesh 4

Streaming data plane (`data/streaming.py`): `--stream` keeps the dataset
host-resident in chunked form and feeds the devices a bounded,
proposal-aware window plus per-step host fetches — same-seed bitwise
identical to the resident run, so it composes with `--mesh` and
`--async-scoring` freely:

  PYTHONPATH=src python -m repro.launch.train --arch mlp_svhn --smoke \
      --mesh 4 --stream --window-chunks 4 --chunk-size 64

Model parallelism: `--model-parallel M` adds a trailing `model` axis to
the mesh and tensor-shards params + optimizer state through the
logical→mesh rules of `repro/dist/sharding.py` — composes with every mode
(relaxed/fused/async/streamed).  Per-example grad-norm scores are
psum-reduced over the model axis, so the proposal is exact and a dp×mp
run is same-seed equivalent to the dp-only run:

  PYTHONPATH=src python -m repro.launch.train --arch mlp_svhn --smoke \
      --mesh 2 --model-parallel 2

Transformer archs run the same shard_map data plane with a model-axis-
aware forward (head-sharded attention, ffn-sharded MLP/MoE, channel-
parallel mamba, vocab-parallel embed/unembed) and sequence-parallel
RMSNorm segments (disable with --no-sequence-parallel):

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --mesh 2 --model-parallel 2 --seq 32 --strategy ghost
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _force_host_devices(n: int) -> None:
    """Force n host devices on CPU backends.  Must run before the jax
    backend initializes (importing jax alone does not initialize it)."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return  # caller already chose a device count
    platforms = os.environ.get("JAX_PLATFORMS", "cpu")
    if "cpu" not in platforms:
        return  # real accelerators: use them as-is
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()


# importing jax does NOT initialize the backend; _force_host_devices (called
# first thing in main) can still adjust XLA_FLAGS before any device exists.
import jax
import jax.numpy as jnp

from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_lm_scorer, make_mlp_scorer
from repro.core.strategies import PROPOSALS, make_proposal
from repro.data import make_svhn_like, make_token_dataset
from repro.optim import sgd


def _proposal_name(args) -> str:
    """The resolved proposal strategy: --proposal-strategy, falling back
    to the architecture-native --strategy when unset."""
    return args.proposal_strategy or args.strategy


def build_mlp(args, model_axes=()):
    from repro.configs.mlp_svhn import CONFIG, smoke
    from repro.models.mlp import (init_mlp_classifier, mlp_specs,
                                  per_example_loss)
    cfg = smoke() if args.smoke else CONFIG
    train, _ = make_svhn_like(jax.random.key(args.seed), n=args.examples,
                              dim=cfg.input_dim)
    params = init_mlp_classifier(jax.random.key(args.seed + 1), cfg)
    pel = lambda p, b: per_example_loss(p, b, cfg, model_axes=model_axes)
    scorer = make_proposal(make_mlp_scorer, cfg, _proposal_name(args),
                           model_axes=model_axes)
    return params, train, pel, scorer, mlp_specs(cfg)


def build_lm(args, model_axes=(), seq_shard=False):
    from repro.configs import get_config, get_smoke_config
    from repro.models.transformer import (init_transformer, per_example_loss,
                                          transformer_specs)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    train = make_token_dataset(jax.random.key(args.seed), n=args.examples,
                               seq=args.seq + 1, vocab=cfg.vocab_size)
    params = init_transformer(jax.random.key(args.seed + 1), cfg)
    pel = lambda p, b: per_example_loss(p, cfg, b, model_axes=model_axes,
                                        seq_shard=seq_shard)[0]
    scorer = make_proposal(make_lm_scorer, cfg, _proposal_name(args),
                           model_axes=model_axes, seq_shard=seq_shard)
    return params, train, pel, scorer, transformer_specs(cfg)


def validate_flags(ap, args, mp: int) -> None:
    """Fail fast, with the config field to fix, instead of inside shard_map.

    Rules (also in --help):
      * --model-parallel M with a transformer arch must divide num_heads
        and num_kv_heads (attention shards whole heads), d_inner for SSM
        stacks (the scan is channel-parallel), and MLA's num_heads; dims
        that merely fail elementwise divisibility (d_ff, vocab) fall back
        to replication with a warning instead.
      * --async-scoring needs --mode relaxed|uniform (fused/exact have no
        separate scoring pass to overlap).
      * --stream excludes --mode exact (the oracle rescores the resident
        dataset each step).
      * --strategy full is a single-device test oracle: no --model-parallel.
    """
    if args.async_scoring and args.mode not in ("relaxed", "uniform"):
        ap.error("--async-scoring requires --mode relaxed|uniform (fused "
                 "scores ride the train forward and exact has no separate "
                 "pass to overlap)")
    if args.adaptive_is and args.mode != "relaxed":
        ap.error("--adaptive-is requires --mode relaxed (the controller "
                 "gates the relaxed sampler between uniform and IS; the "
                 "other modes have no gate to drive)")
    if args.stream and args.mode == "exact":
        ap.error("--stream does not support --mode exact (the oracle "
                 "rescores the full dataset each step; keep it resident)")
    if args.serve_loop:
        if not args.stream:
            ap.error("--serve-loop requires --stream (served traffic is "
                     "ingested as chunks of the host-resident store)")
        if args.arch == "mlp_svhn":
            ap.error("--serve-loop needs a token arch (the decode service "
                     "generates tokens); pick a transformer --arch")
        if args.mode not in ("relaxed", "fused"):
            ap.error("--serve-loop requires --mode relaxed|fused (uniform "
                     "sampling draws reserved-capacity rows before they "
                     "are ingested; exact is excluded by --stream)")
    if args.table_dtype == "int8":
        if args.stream or args.serve_loop:
            ap.error("--table-dtype int8 does not compose with --stream/"
                     "--serve-loop yet (the streamed serving ingest "
                     "assumes a float table); use f32 or bf16 there")
        n_local = args.examples // max(args.mesh, 1)
        cs = args.index_chunk_size
        if cs <= 0 or n_local % cs:
            ap.error(f"--table-dtype int8 needs --index-chunk-size > 0 "
                     f"dividing the per-shard rows ({n_local}); got {cs} "
                     f"(per-chunk scales may not straddle shards)")
    if args.index_chunk_size > 0 and \
            (args.examples // max(args.mesh, 1)) % args.index_chunk_size:
        ap.error(f"--index-chunk-size {args.index_chunk_size} must divide "
                 f"the per-shard rows "
                 f"({args.examples // max(args.mesh, 1)})")
    if mp <= 1:
        return
    if _proposal_name(args) == "full":
        ap.error("--strategy full is the vmap-of-grad test oracle and does "
                 "not support --model-parallel; use ghost or ghost_rev")
    if args.arch == "mlp_svhn":
        return  # uneven hidden dims fall back to replication with a warning
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    has_attn = any(s.mixer == "attn" for s in cfg.layer_specs())
    has_ssm = any(s.mixer == "mamba" for s in cfg.layer_specs())
    if has_attn and cfg.num_heads % mp:
        ap.error(f"--model-parallel {mp} does not divide num_heads="
                 f"{cfg.num_heads} of {cfg.name} (attention shards whole "
                 f"heads); pick a degree dividing num_heads or change the "
                 f"config's num_heads")
    if has_attn and cfg.attention == "gqa" and cfg.num_kv_heads % mp:
        ap.error(f"--model-parallel {mp} does not divide num_kv_heads="
                 f"{cfg.num_kv_heads} of {cfg.name} (K/V shard whole "
                 f"heads); pick a degree dividing num_kv_heads or change "
                 f"the config's num_kv_heads")
    if has_ssm and cfg.resolved_d_inner % mp:
        ap.error(f"--model-parallel {mp} does not divide d_inner="
                 f"{cfg.resolved_d_inner} of {cfg.name} (the selective "
                 f"scan is channel-parallel); pick a degree dividing "
                 f"d_inner (config field d_inner, default 2*d_model)")


_FLAG_RULES = """\
flag composition rules (validated up front; see also README and
docs/ARCHITECTURE.md):
  --mesh N            composes with everything; total devices = N * M
  --model-parallel M  composes with every mode and arch; for transformer
                      archs M must divide num_heads and num_kv_heads
                      (whole-head attention shards) and d_inner for SSM
                      stacks (channel-parallel scan); d_ff / vocab dims
                      that M does not divide fall back to replication
                      with a warning naming the parameter
  --async-scoring     requires --mode relaxed|uniform (fused scores ride
                      the train forward; exact has no pass to overlap)
  --stream            composes with --mesh/--model-parallel/--async-scoring
                      and --mode relaxed|uniform|fused; not --mode exact
                      (the oracle rescores the resident dataset)
  --sequence-parallel transformer + --model-parallel only; auto-skips
                      when M does not divide the sequence length
  --strategy full     single-device test oracle; not --model-parallel
  --adaptive-is       requires --mode relaxed (the controller flips the
                      relaxed sampler's uniform/IS gate from live
                      telemetry; composes with --mesh/--async-scoring/
                      --stream/--model-parallel)
  --index tree        composes with everything (draws are bitwise-equal
                      to the dense default; stage-1 masses come from
                      core/mass_index.py)
  --table-dtype       bf16 composes with everything; int8 needs
                      --index-chunk-size dividing the per-shard rows and
                      does not compose with --stream/--serve-loop
  --score-ttl K       composes with everything (per-chunk decay of stale
                      scores toward the uniform floor; 0 = off, the
                      HLO-identical default)
"""


def main():
    ap = argparse.ArgumentParser(
        epilog=_FLAG_RULES,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="mlp_svhn")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--score-batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--examples", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--mode", default="relaxed",
                    choices=["relaxed", "exact", "uniform", "fused"])
    ap.add_argument("--probe-every", type=int, default=8,
                    help="fused mode: run a coverage probe every K steps")
    ap.add_argument("--strategy", default="ghost",
                    choices=["loss", "logit_grad", "ghost", "ghost_rev", "full"])
    ap.add_argument("--proposal-strategy", default="",
                    choices=[""] + list(PROPOSALS),
                    help="proposal strategy from the zoo "
                    "(core/strategies.py): any --strategy name plus "
                    "upper_bound (K&F sqrt(2L) forward-only bound), "
                    "bandit_mixed (convex loss+logit_grad mixture), and "
                    "null (zero scores = uniform proposal); empty falls "
                    "back to --strategy")
    ap.add_argument("--adaptive-is", action="store_true",
                    help="run the adaptive IS controller "
                    "(core/controller.py): the sampler starts uniform and "
                    "switches to IS only when the observed trace ratio "
                    "says it pays; with --async-scoring the swap cadence "
                    "adapts to the dispatch-time ratio too (requires "
                    "--mode relaxed)")
    ap.add_argument("--adapt-every", type=int, default=25,
                    help="controller decision cadence in steps")
    ap.add_argument("--smoothing", type=float, default=1.0)
    ap.add_argument("--refresh-every", type=int, default=8)
    ap.add_argument("--staleness-threshold", type=int, default=0)
    ap.add_argument("--index", default="dense", choices=["dense", "tree"],
                    help="stage-1 mass source for the two-stage draw: "
                    "'tree' routes per-block masses through the chunk "
                    "mass index (core/mass_index.py) — bitwise-equal "
                    "draws, O(log N) write propagation at scale; 'dense' "
                    "recomputes them in-draw (default)")
    ap.add_argument("--table-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="weight-table storage: bf16 halves it, int8 (+ "
                    "per-chunk scale, needs --index-chunk-size) quarters "
                    "it; the proposal distortion is bounded and tested "
                    "(tests/test_sampler_stats.py)")
    ap.add_argument("--score-ttl", type=int, default=0,
                    help="decay scores toward the uniform floor with a "
                    "half-life of K steps per chunk age "
                    "(weight_store.decay_proposal); 0 = off "
                    "(HLO-identical default)")
    ap.add_argument("--index-chunk-size", type=int, default=0,
                    help="chunk granularity for the mass index / int8 "
                    "scales / TTL decay (0 = one chunk per logical "
                    "scoring shard)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="run the sharded step on an N-device data mesh "
                    "(0 = single-device path); on CPU, N host devices are "
                    "forced automatically")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-shard params + optimizer state over a "
                    "trailing M-device model axis (composes with --mesh/"
                    "--async-scoring/--stream and every arch; total "
                    "devices = mesh * M; transformer archs need M to "
                    "divide num_heads/num_kv_heads/d_inner — see the "
                    "rules below)")
    ap.add_argument("--sequence-parallel", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="transformer + --model-parallel: run the RMSNorm "
                    "segments sequence-parallel (on by default when M > 1 "
                    "and M divides the sequence length; "
                    "--no-sequence-parallel keeps them replicated; both "
                    "are exact)")
    ap.add_argument("--save-checkpoint", default="",
                    help="save the final TrainState here (sharded runs "
                    "use the gather-free per-shard npz layout)")
    ap.add_argument("--restore-checkpoint", default="",
                    help="restore a TrainState before training (old "
                    "replicated and new per-shard checkpoints both work)")
    ap.add_argument("--score-shards", type=int, default=0,
                    help="logical scoring shards W (0 = auto: mesh size, "
                    "or 1 single-device)")
    ap.add_argument("--async-scoring", action="store_true",
                    help="overlap the scoring fan-out with the master "
                    "update via the double-buffered WeightStore "
                    "(core/async_pipeline.py; mode relaxed|uniform)")
    ap.add_argument("--swap-every", type=int, default=1,
                    help="async: publish write_buf -> read_buf every K "
                    "steps (the proposal lag is L in [1, K])")
    ap.add_argument("--no-trace-monitors", action="store_true",
                    help="async: skip the fig-4 trace monitors in the "
                    "scoring step (keeps it strictly collective-free; "
                    "traces log as nan)")
    ap.add_argument("--stream", action="store_true",
                    help="host-resident chunked dataset + proposal-aware "
                    "device window (data/streaming.py); bitwise-identical "
                    "to the resident run, composes with --mesh and "
                    "--async-scoring")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="examples per host chunk (0 = auto: an eighth of "
                    "each shard's example range)")
    ap.add_argument("--window-chunks", type=int, default=4,
                    help="device-resident hot chunks per shard")
    ap.add_argument("--prefetch-every", type=int, default=1,
                    help="stage a fresh proposal-ranked window every K "
                    "steps")
    ap.add_argument("--serve-loop", action="store_true",
                    help="close the train/serve loop: run a continuous-"
                    "batching decode tick each train step against "
                    "published param snapshots, and ingest finished "
                    "requests back into the store as scorable examples "
                    "(requires --stream and a token arch)")
    ap.add_argument("--serve-slots", type=int, default=2,
                    help="serve loop: concurrent decode slots")
    ap.add_argument("--serve-prompt-len", type=int, default=4,
                    help="serve loop: synthetic-traffic prompt length")
    ap.add_argument("--serve-max-new", type=int, default=4,
                    help="serve loop: tokens generated per request")
    ap.add_argument("--serve-rate", type=int, default=1,
                    help="serve loop: new requests per serve tick")
    ap.add_argument("--serve-every", type=int, default=1,
                    help="serve loop: run a serve tick every K train steps")
    ap.add_argument("--serve-publish-every", type=int, default=0,
                    help="serve loop: snapshot train params for serving "
                    "every K serve ticks (0 = --swap-every, extending the "
                    "async staleness discipline to decode)")
    ap.add_argument("--serve-decode-steps", type=int, default=2,
                    help="serve loop: lock-step decodes per serve tick")
    ap.add_argument("--serve-reserve-chunks", type=int, default=2,
                    help="serve loop: zero chunks appended up front as "
                    "traffic capacity (reserved rows are proposal-"
                    "invisible until ingested)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    ap.add_argument("--metrics-jsonl", default="",
                    help="write schema-versioned telemetry events (spans, "
                    "counters, per-step metrics, monitors) to this JSONL "
                    "file; tools/metrics_report.py renders a run summary "
                    "from it")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="telemetry cadence in steps for periodic counters "
                    "and metrics records (0 = --log-every)")
    ap.add_argument("--monitors", default="none",
                    help="proposal-health monitors compiled into the step "
                    "as extra outputs: 'all', 'none', or a comma list of "
                    "ess,entropy,max_weight_frac,empty_rows,staleness; "
                    "off is HLO-identical to a monitor-free build and on "
                    "never changes the trajectory")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the window given "
                    "by --profile-steps into this directory")
    ap.add_argument("--profile-steps", default="2:2",
                    help="profiler capture window as START:COUNT train "
                    "steps (default 2:2 — skip compile, grab two steps)")
    ap.add_argument("--telemetry-blocking", action="store_true",
                    help="block on each phase's outputs inside its span "
                    "(true per-phase wall-clock; serializes the async "
                    "scoring/master overlap — off by default)")
    args = ap.parse_args()
    mp = max(args.model_parallel, 1)
    dp = max(args.mesh, 1)
    use_mesh = args.mesh > 0 or mp > 1
    validate_flags(ap, args, mp)
    _force_host_devices(dp * mp if use_mesh else args.mesh)
    model_axes = ("model",) if mp > 1 else ()
    seq_shard = mp > 1 and (args.sequence_parallel is None
                            or args.sequence_parallel)

    from repro.telemetry import EventSink, MonitorSet, NullSink, Telemetry
    try:
        mon_set = MonitorSet.parse(args.monitors)
    except ValueError as e:
        ap.error(f"--monitors: {e}")
    try:
        prof_start, prof_count = map(int, args.profile_steps.split(":"))
    except ValueError:
        ap.error(f"--profile-steps must be START:COUNT, got "
                 f"{args.profile_steps!r}")
    if args.metrics_jsonl:
        sink = EventSink(args.metrics_jsonl,
                         run={"arch": args.arch, "mode": args.mode,
                              "steps": args.steps, "mesh": args.mesh,
                              "model_parallel": mp,
                              "async_scoring": args.async_scoring,
                              "stream": args.stream,
                              "serve_loop": args.serve_loop,
                              "swap_every": args.swap_every,
                              "monitors": list(mon_set.names),
                              "proposal_strategy": _proposal_name(args),
                              "adaptive_is": args.adaptive_is,
                              "seed": args.seed})
    else:
        sink = NullSink()
    ctl = None
    if args.adaptive_is:
        from repro.core.controller import ControllerConfig, ProposalController
        ctl = ProposalController(
            ControllerConfig(adapt_every=args.adapt_every,
                             adapt_swap=args.async_scoring),
            swap_every=args.swap_every)
        # the tap is truthy even over a NullSink, so the metrics/span
        # records the controller feeds on keep flowing file or no file
        sink = ctl.attach(sink)
    tel = Telemetry(sink, every=args.metrics_every or args.log_every,
                    blocking=args.telemetry_blocking)

    if args.arch == "mlp_svhn":
        params, train, pel, scorer, param_specs = build_mlp(args, model_axes)
    else:
        params, train, pel, scorer, param_specs = build_lm(
            args, model_axes, seq_shard=seq_shard)
    pspec_kw = (dict(param_specs=param_specs, params_template=params)
                if mp > 1 else {})

    fused_score = None
    if args.mode == "fused":
        if args.arch == "mlp_svhn":
            from repro.configs.mlp_svhn import CONFIG, smoke
            from repro.models.mlp import per_example_loss_and_score
            _cfg = smoke() if args.smoke else CONFIG
            fused_score = lambda p, b: per_example_loss_and_score(
                p, b, _cfg, model_axes=model_axes)
        else:
            from repro.configs import get_config, get_smoke_config
            from repro.models.transformer import per_example_loss_and_score
            _cfg = (get_smoke_config(args.arch) if args.smoke
                    else get_config(args.arch))
            fused_score = lambda p, b: per_example_loss_and_score(
                p, _cfg, b, model_axes=model_axes, seq_shard=seq_shard)

    opt = sgd(args.lr)
    tcfg = ISSGDConfig(
        batch_size=args.batch, score_batch_size=args.score_batch,
        refresh_every=args.refresh_every, mode=args.mode,
        is_cfg=ISConfig(smoothing=args.smoothing,
                        staleness_threshold=args.staleness_threshold),
        score_shards=max(args.score_shards, 1),
        index=args.index, table_dtype=args.table_dtype,
        score_ttl=args.score_ttl,
        index_chunk_size=args.index_chunk_size)
    state = init_train_state(params, opt, train.size, seed=args.seed,
                             table_dtype=args.table_dtype,
                             index_chunk_size=args.index_chunk_size)
    data = train.arrays
    probe = None
    pipe = None
    plane = None
    mesh = None
    serve = None
    if args.stream:
        import numpy as np
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import (StreamedISSGD, StreamingDataPlane,
                                          make_streamed_steps)
        n_examples = train.size
        n_shards = dp    # data shards; the model axis never splits examples
        if n_examples % n_shards:
            ap.error(f"--examples {n_examples} not divisible by --mesh "
                     f"{n_shards}")
        n_local = n_examples // n_shards
        csize = args.chunk_size
        if not csize:
            # auto: the largest divisor of the per-shard example count
            # that is at most an eighth of it (always exists; 1 divides)
            csize = next(c for c in range(max(n_local // 8, 1), 0, -1)
                         if n_local % c == 0)
        store = ChunkedExampleStore.from_arrays(data, csize)
        n_live = n_examples
        if args.serve_loop:
            # reserve traffic capacity BEFORE any sharded layout: shard
            # chunk ranges are contiguous slices of num_chunks, so the
            # tail must exist up front (store.append_chunk docs)
            for _ in range(max(args.serve_reserve_chunks, 1)):
                store.append_chunk()
            n_examples = store.num_examples
            if store.num_chunks % n_shards:
                ap.error(f"--serve-reserve-chunks {args.serve_reserve_chunks}"
                         f" leaves num_chunks={store.num_chunks} not "
                         f"divisible by --mesh {n_shards}")
            from repro.core.weight_store import init_store, reserve_tail
            state = state._replace(
                store=reserve_tail(
                    init_store(n_examples, table_dtype=args.table_dtype,
                               chunk_size=args.index_chunk_size), n_live))
        wc = max(1, min(args.window_chunks, store.num_chunks // n_shards))
        # the step programs never take the dataset; drop the monolithic
        # device arrays now that the host store holds the examples —
        # the sharding specs only need per-key ndim/dtype
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        train = data = None
        if args.async_scoring:
            from repro.core.weight_store import to_buffered
            state = state._replace(store=to_buffered(state.store))
        if use_mesh:
            from repro.core import distributed as dist
            from repro.launch.mesh import make_debug_mesh
            mesh = make_debug_mesh(dp, model=mp)
            s_step, smp_step, m_step, tcfg = dist.make_sharded_streamed_steps(
                pel, scorer, opt, tcfg, n_examples, mesh, template,
                chunk_size=csize, fused_score=fused_score,
                async_mode=args.async_scoring,
                monitor_traces=not args.no_trace_monitors,
                monitors=mon_set, gated=args.adaptive_is, **pspec_kw)
        else:
            s_step, smp_step, m_step = make_streamed_steps(
                pel, scorer, opt, tcfg, n_examples, csize,
                fused_score=fused_score, async_mode=args.async_scoring,
                monitor_traces=not args.no_trace_monitors,
                monitors=mon_set, gated=args.adaptive_is)
        plane = StreamingDataPlane(store, wc, mesh=mesh)
        pipe = StreamedISSGD(plane, s_step, smp_step, m_step, tcfg,
                             n_examples, async_mode=args.async_scoring,
                             swap_every=args.swap_every,
                             prefetch_every=args.prefetch_every,
                             telemetry=tel, controller=ctl)
        if args.mode == "fused":
            probe = pipe.probe
        if args.serve_loop:
            from repro.configs import get_config, get_smoke_config
            from repro.serving import (ContinuousBatcher, ServeLoop,
                                       TrafficIngest, make_synthetic_traffic)
            scfg = (get_smoke_config(args.arch) if args.smoke
                    else get_config(args.arch))
            serve_max_len = args.serve_prompt_len + args.serve_max_new
            b_pp = None
            if mp > 1:
                from repro.dist.sharding import param_pspecs as _make_pp
                b_pp = _make_pp(param_specs, params, mesh)
            batcher = ContinuousBatcher(
                params, scfg, num_slots=args.serve_slots,
                max_len=serve_max_len, mesh=mesh, param_pspecs=b_pp)
            ingest = TrafficIngest(store, seq_len=args.seq + 1,
                                   start_row=n_live,
                                   capacity_rows=n_examples - n_live)
            traffic = make_synthetic_traffic(
                scfg.vocab_size, args.serve_prompt_len,
                rate=args.serve_rate, max_new_tokens=args.serve_max_new,
                seed=args.seed + 7)
            serve = ServeLoop(
                batcher, ingest, traffic,
                publish_every=args.serve_publish_every or args.swap_every,
                serve_every=args.serve_every,
                decode_steps=args.serve_decode_steps, telemetry=tel)
            pipe.serve_tick = serve.on_train_step
            print(f"serve-loop: {args.serve_slots} slots, max_len "
                  f"{serve_max_len}, {n_examples - n_live} reserved rows",
                  flush=True)
        print(f"streaming: {store.num_chunks} chunks x {csize} rows "
              f"host-resident, window {wc} chunks/shard x {n_shards} "
              f"shard(s)"
              + (f", async swap every {args.swap_every}"
                 if args.async_scoring else ""), flush=True)
    elif args.async_scoring:
        from repro.core.async_pipeline import AsyncPipeline, make_async_steps
        from repro.core.weight_store import to_buffered
        state = state._replace(store=to_buffered(state.store))
        if use_mesh:
            from repro.core import distributed as dist
            from repro.launch.mesh import make_debug_mesh
            mesh = make_debug_mesh(dp, model=mp)
            print(f"mesh: {tuple(mesh.shape.values())} over "
                  f"{jax.device_count()} devices (async, swap every "
                  f"{args.swap_every})", flush=True)
            s_step, m_step, tcfg = dist.make_sharded_async_steps(
                pel, scorer, opt, tcfg, train.size, mesh, data,
                monitor_traces=not args.no_trace_monitors,
                monitors=mon_set, gated=args.adaptive_is, **pspec_kw)
            data = dist.shard_dataset(data, mesh)
        else:
            print(f"async scoring, swap every {args.swap_every}", flush=True)
            s_step, m_step = make_async_steps(
                pel, scorer, opt, tcfg, train.size,
                monitor_traces=not args.no_trace_monitors,
                monitors=mon_set, gated=args.adaptive_is)
        pipe = AsyncPipeline(s_step, m_step, args.swap_every, telemetry=tel,
                             controller=ctl)
    elif use_mesh:
        from repro.core import distributed as dist
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(dp, model=mp)
        print(f"mesh: {tuple(mesh.shape.values())} over "
              f"{jax.device_count()} devices", flush=True)
        raw_step, tcfg = dist.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, data,
            fused_score=fused_score, monitors=mon_set,
            gated=args.adaptive_is, **pspec_kw)
        step_monitors = raw_step.with_monitors  # jax.jit drops attributes
        step_gated = raw_step.gated
        step = jax.jit(raw_step)
        if args.mode == "fused":
            probe = jax.jit(dist.make_sharded_score_step(
                scorer, tcfg, train.size, mesh, data, optimizer=opt,
                **pspec_kw))
        data = dist.shard_dataset(data, mesh)
    else:
        raw_step = make_train_step(pel, scorer, opt, tcfg, train.size,
                                   fused_score=fused_score, monitors=mon_set,
                                   gated=args.adaptive_is)
        step_monitors = raw_step.with_monitors  # jax.jit drops attributes
        step_gated = raw_step.gated
        step = jax.jit(raw_step)
        if args.mode == "fused":
            from repro.core.issgd import make_score_step
            probe = jax.jit(make_score_step(scorer, tcfg, train.size))

    if args.restore_checkpoint:
        from repro.checkpoint import restore_checkpoint
        # restore BEFORE placement: leaves come back as host numpy, so
        # the single shard_train_state below moves each (model-)shard
        # straight to its device — the full tensors never hit a device
        state, ck_step = restore_checkpoint(args.restore_checkpoint, state)
        print(f"restored {args.restore_checkpoint} (step {ck_step})",
              flush=True)
    if mesh is not None:
        from repro.core import distributed as dist
        state = dist.shard_train_state(
            state, mesh, param_specs=pspec_kw.get("param_specs"))

    history = []
    t0 = time.time()
    profiling = False
    for i in range(args.steps):
        if args.profile_dir and i == prof_start:
            jax.profiler.start_trace(args.profile_dir)
            profiling = True
            sink.emit("profile", step=i, action="start",
                      dir=args.profile_dir)
        mon = None
        if pipe is not None:
            state, m = pipe.step(state, data)
            mon = pipe.last_monitors
        else:
            sargs = ((state, data, ctl.gate()) if step_gated
                     else (state, data))
            out = tel.timed("train.step", step, *sargs, step=i)
            if step_monitors:
                state, m, mon = out
            else:
                state, m = out
        if serve is not None:
            # finished traffic lands in the store between steps, once the
            # tick's training dispatches have retired (donation safety)
            state = serve.ingest_into(state)
        if probe is not None and i % args.probe_every == 0:
            state = probe(state, data)
        if profiling and i == prof_start + prof_count - 1:
            # retire the window's dispatches before closing the trace
            jax.block_until_ready(state.params)
            jax.profiler.stop_trace()
            profiling = False
            sink.emit("profile", step=i, action="stop")
        log_now = i % args.log_every == 0 or i == args.steps - 1
        emit_now = bool(sink) and (tel.due(i) or i == args.steps - 1)
        if log_now or emit_now:
            # ONE forced transfer for everything this step logs — per-field
            # float() calls would each block the dispatch queue separately
            vals, mon_vals = jax.device_get(
                ((m.loss, m.grad_norm, m.trace_ideal, m.trace_stale,
                  m.trace_unif, m.ess_frac), mon))
            rec = {"step": i, "loss": float(vals[0]),
                   "grad_norm": float(vals[1]),
                   "trace_ideal": float(vals[2]),
                   "trace_stale": float(vals[3]),
                   "trace_unif": float(vals[4]),
                   "ess_frac": float(vals[5]),
                   "elapsed_s": round(time.time() - t0, 2)}
            if plane is not None:
                rec["stream_hit_rate"] = round(plane.stats.hit_rate, 4)
            if serve is not None:
                rec["served_rows"] = int(serve.ingest.ingested)
            if log_now:
                history.append(rec)
                print(f"step {i:5d} loss {rec['loss']:.4f} "
                      f"√TrΣ ideal/stale/unif = {rec['trace_ideal']:.3f}/"
                      f"{rec['trace_stale']:.3f}/{rec['trace_unif']:.3f} "
                      f"ess {rec['ess_frac']:.3f}", flush=True)
            if emit_now:
                sink.emit("metrics", step=i,
                          **{k: v for k, v in rec.items() if k != "step"})
                if mon_vals is not None:
                    sink.emit("monitors", step=i,
                              **{k: v for k, v in mon_vals.items()})
        if ctl is not None:
            # after the step's metrics have been folded into the window
            d = ctl.maybe_decide(i)
            if d is not None:
                if pipe is not None:
                    pipe.swap_every = d.swap_every
                print(f"controller: step {i} use_is={d.use_is} "
                      f"swap_every={d.swap_every} reason={d.reason}",
                      flush=True)
    if profiling:   # window ran past the end of the run
        jax.block_until_ready(state.params)
        jax.profiler.stop_trace()
        sink.emit("profile", step=args.steps - 1, action="stop")
    if serve is not None:
        print(f"serve-loop: ingested {serve.ingest.ingested} rows "
              f"({serve.ingest.dropped} dropped, "
              f"{len(serve.batcher.finished)} requests finished)",
              flush=True)
    if plane is not None:
        s = plane.stats
        print(f"streaming stats: window hit rate {s.hit_rate:.3f} "
              f"({s.hits} hits / {s.misses} misses), "
              f"{s.streamed_rows} scoring rows streamed, "
              f"{s.swaps} window swaps", flush=True)
    if args.save_checkpoint:
        from repro.checkpoint import save_checkpoint
        # sharded runs save gather-free: per-shard entries + manifest
        save_checkpoint(args.save_checkpoint, state, step=int(state.step),
                        gather=mesh is None)
        print(f"saved checkpoint to {args.save_checkpoint}", flush=True)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=2)
    end = {"steps": args.steps, "elapsed_s": round(time.time() - t0, 2)}
    if history:
        end["final_loss"] = history[-1]["loss"]
    if plane is not None:
        s = plane.stats
        end.update(stream_hit_rate=round(s.hit_rate, 4),
                   stream_window_swaps=s.swaps)
    if serve is not None:
        end.update(served_rows=int(serve.ingest.ingested),
                   served_dropped=int(serve.ingest.dropped))
    sink.emit("run_end", step=args.steps - 1, **end)
    sink.close()


if __name__ == "__main__":
    main()

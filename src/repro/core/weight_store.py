"""The "database" of the paper, TPU-native.

The paper decouples master and workers with a Redis instance that stores one
probability weight per training example.  On a pod, the equivalent with the
right observables is a pair of device arrays sharded over the data-parallel
axes:

    weights   : f32[N]   -- unnormalized probability weights ω̃_n
    scored_at : i32[N]   -- the step at which ω̃_n was last recomputed
                            (-1 = never scored)

The "fire and forget" property of the paper's database is preserved: the
training step *reads* whatever is in the store (however stale) and the
scoring pass *writes* the slice it rescored this step.  Staleness is
observable through `scored_at` exactly like the paper's B.1 timestamps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.importance import ISConfig, apply_staleness_filter, smooth_weights


# scored_at sentinel for *reserved* rows: capacity pre-allocated for traffic
# the serving loop has not ingested yet.  Reserved rows are excluded from
# the proposal (weight forced to 0) and skipped by the scoring fan-out;
# `mark_live` flips them to -1 ("never scored") once real data lands.
EMPTY = -2


class WeightStore(NamedTuple):
    """The paper's database actor: one unnormalized proposal weight (and
    its staleness timestamp) per training example, example-axis-sharded
    over the data axes in distributed runs."""
    weights: jax.Array    # f32[N]  raw (unsmoothed) ω̃ — grad-norm estimates
    scored_at: jax.Array  # i32[N]  step of last scoring, -1 if never


def init_store(num_examples: int, init_weight: float = 0.0) -> WeightStore:
    """Fresh store: nothing scored yet → behaves as uniform (see read)."""
    return WeightStore(
        weights=jnp.full((num_examples,), init_weight, jnp.float32),
        scored_at=jnp.full((num_examples,), -1, jnp.int32),
    )


def reserve_tail(store: WeightStore, num_live: int) -> WeightStore:
    """Mark every row past ``num_live`` as reserved capacity (EMPTY).

    The serving loop pre-allocates store rows for traffic it will ingest
    later; until `mark_live` stamps them, those rows are invisible to the
    proposal and inert under scoring."""
    idx = jnp.arange(store.scored_at.shape[0])
    return store._replace(scored_at=jnp.where(idx < num_live,
                                              store.scored_at,
                                              jnp.asarray(EMPTY, jnp.int32)))


def mark_live(store: WeightStore, indices) -> WeightStore:
    """Flip reserved rows to 'never scored' (-1) once real data lands in
    them, making them eligible for scoring and (once scored) sampling."""
    indices = jnp.asarray(indices, jnp.int32)
    return store._replace(
        scored_at=store.scored_at.at[indices].set(-1))


def write_scores(
    store: WeightStore,
    indices: jax.Array,
    scores: jax.Array,
    step: jax.Array | int,
) -> WeightStore:
    """Workers push fresh ω̃ for the examples they just scored."""
    step = jnp.asarray(step, jnp.int32)
    return WeightStore(
        weights=store.weights.at[indices].set(scores.astype(store.weights.dtype)),
        scored_at=store.scored_at.at[indices].set(step),
    )


def write_scores_global(
    store: WeightStore,
    global_indices: jax.Array,
    scores: jax.Array,
    step: jax.Array | int,
    axes: tuple[str, ...] = (),
) -> WeightStore:
    """Push fresh ω̃ at *global* indices into an example-axis-sharded store:
    each device applies the writes it owns, the rest drop (the fused mode's
    replicated minibatch scores land on whichever shard holds each row).
    With axes=() this is exactly `write_scores`."""
    from repro.core.collectives import scatter_rows
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32),
                            global_indices.shape)
    return WeightStore(
        weights=scatter_rows(store.weights, global_indices, scores, axes),
        scored_at=scatter_rows(store.scored_at, global_indices, step, axes),
    )


class BufferedWeightStore(NamedTuple):
    """Double-buffered store for the async scoring pipeline
    (core/async_pipeline.py).

    The master samples from ``read_buf`` — a snapshot of the table as of
    step ``synced_at`` — while the workers' scoring writes land in
    ``write_buf``, so the two computations share no buffers and can be
    dispatched concurrently.  ``publish`` is the swap (the pipeline's only
    sync point): it snapshots write_buf into read_buf.

    With swap cadence K the master at step t samples from the table as
    written through step K·⌊t/K⌋ − 1, i.e. the run is exactly a
    relaxed-mode run whose proposal is L(t) = t − K·⌊t/K⌋ + 1 ∈ [1, K]
    steps staler — same §4.1 unbiasedness (the IS scales come from the
    same lagged proposal the sampler used), and the lag is observable
    through ``read_buf.scored_at`` exactly like the paper's B.1 timestamps.
    """
    read_buf: WeightStore    # the master's snapshot (proposal source)
    write_buf: WeightStore   # where the scoring fan-out's writes land
    synced_at: jax.Array     # i32: last step whose writes read_buf holds


def _copy_store(store: WeightStore) -> WeightStore:
    """Fresh device buffers (sharding-preserving).  The copies matter:
    read_buf must never alias write_buf, because the scoring step donates
    write_buf for in-place updates."""
    return WeightStore(weights=jnp.copy(store.weights),
                       scored_at=jnp.copy(store.scored_at))


def to_buffered(store: WeightStore) -> BufferedWeightStore:
    """Wrap a plain store for the async pipeline: both buffers start as
    distinct copies of the current table; nothing published yet."""
    return BufferedWeightStore(read_buf=_copy_store(store),
                               write_buf=_copy_store(store),
                               synced_at=jnp.asarray(-1, jnp.int32))


def publish(bstore: BufferedWeightStore,
            step: jax.Array | int) -> BufferedWeightStore:
    """The swap: read_buf ← snapshot of write_buf, stamped with the last
    step whose writes it now holds.  One device-side copy of the table
    shard every K steps — the async pipeline's only sync point."""
    return BufferedWeightStore(read_buf=_copy_store(bstore.write_buf),
                               write_buf=bstore.write_buf,
                               synced_at=jnp.asarray(step, jnp.int32))


def read_proposal(
    store: WeightStore,
    step: jax.Array | int,
    cfg: ISConfig,
) -> jax.Array:
    """The master reads the sampling proposal: staleness-filter (B.1) then
    additive smoothing (B.3).  Never-scored entries act as the neutral
    (uniform) weight, so a cold store reproduces plain SGD exactly.
    Reserved rows (scored_at == EMPTY, serving-loop capacity not yet
    ingested) are excluded outright — zero proposal mass."""
    w = apply_staleness_filter(store.weights, store.scored_at, step, cfg)
    q = smooth_weights(w, cfg)
    return jnp.where(store.scored_at <= EMPTY, jnp.zeros_like(q), q)


def mark_live_buffered(bstore: BufferedWeightStore,
                       indices) -> BufferedWeightStore:
    """`mark_live` on the *write* buffer only: the newly ingested rows
    flow to the master's snapshot at the next `publish`, preserving the
    swap-cadence staleness discipline (read_buf keeps them EMPTY until
    then, so the proposal never sees rows newer than its snapshot)."""
    return bstore._replace(write_buf=mark_live(bstore.write_buf, indices))


class PublishedParams(NamedTuple):
    """A consistent parameter snapshot for serving — the model-weights
    analogue of the proposal's ``read_buf``: serving reads only published
    snapshots, so under publish cadence K it is at most K steps stale and
    the PR 2 swap invariant extends verbatim to decode."""
    params: object          # pytree snapshot (fresh buffers)
    synced_at: jax.Array    # i32: train step the snapshot was taken at


def publish_params(params, step: jax.Array | int) -> PublishedParams:
    """Snapshot the training params into fresh (sharding-preserving)
    buffers for serving — same no-alias rationale as `_copy_store`: the
    training step may donate its param buffers."""
    return PublishedParams(params=jax.tree.map(jnp.copy, params),
                           synced_at=jnp.asarray(step, jnp.int32))


def staleness_stats(store: WeightStore, step: jax.Array | int) -> dict:
    """Monitoring: paper B.1 reports the fraction of weights fresh enough."""
    step = jnp.asarray(step, jnp.int32)
    scored = store.scored_at >= 0
    age = jnp.where(scored, step - store.scored_at, jnp.iinfo(jnp.int32).max)
    return {
        "frac_scored": jnp.mean(scored.astype(jnp.float32)),
        "mean_age": jnp.mean(jnp.where(scored, age, 0).astype(jnp.float32))
        / jnp.maximum(jnp.mean(scored.astype(jnp.float32)), 1e-9),
        "max_age": jnp.max(jnp.where(scored, age, -1)),
    }

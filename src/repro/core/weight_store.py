"""The "database" of the paper, TPU-native.

The paper decouples master and workers with a Redis instance that stores one
probability weight per training example.  On a pod, the equivalent with the
right observables is a pair of device arrays sharded over the data-parallel
axes:

    weights   : f32[N]   -- unnormalized probability weights ω̃_n
    scored_at : i32[N]   -- the step at which ω̃_n was last recomputed
                            (-1 = never scored)

The "fire and forget" property of the paper's database is preserved: the
training step *reads* whatever is in the store (however stale) and the
scoring pass *writes* the slice it rescored this step.  Staleness is
observable through `scored_at` exactly like the paper's B.1 timestamps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.importance import ISConfig, apply_staleness_filter, smooth_weights


# scored_at sentinel for *reserved* rows: capacity pre-allocated for traffic
# the serving loop has not ingested yet.  Reserved rows are excluded from
# the proposal (weight forced to 0) and skipped by the scoring fan-out;
# `mark_live` flips them to -1 ("never scored") once real data lands.
EMPTY = -2

# int8 tables store codes in [0, INT8_LEVELS]; value = code·scale/INT8_LEVELS
INT8_LEVELS = 127
# round-to-nearest-bf16 relative error: 8 mantissa bits → half-ulp 2⁻⁹
BF16_HALF_ULP = 2.0 ** -9


class WeightStore(NamedTuple):
    """The paper's database actor: one unnormalized proposal weight (and
    its staleness timestamp) per training example, example-axis-sharded
    over the data axes in distributed runs.

    ``weights`` is f32 by default; quantized tables (``--table-dtype``)
    store bf16 raw weights (``qscale`` stays None) or int8 codes with a
    per-chunk f32 scale in ``qscale`` (one scale per ``chunk_size``
    contiguous rows; ``value = code · scale / INT8_LEVELS``).  Every
    read/write helper below dispatches on the *static* storage dtype, so
    the f32 path traces the exact pre-quantization program (the HLO gate
    of tests/test_mass_index.py)."""
    weights: jax.Array    # f32/bf16 raw ω̃, or int8 codes (quantized table)
    scored_at: jax.Array  # i32[N]  step of last scoring, -1 if never
    qscale: jax.Array | None = None  # f32[num_chunks] per-chunk int8 scale


def init_store(num_examples: int, init_weight: float = 0.0,
               table_dtype: str = "f32", chunk_size: int = 0) -> WeightStore:
    """Fresh store: nothing scored yet → behaves as uniform (see read).

    ``table_dtype`` selects the storage representation ("f32" | "bf16" |
    "int8"); int8 needs a positive ``chunk_size`` dividing
    ``num_examples`` for its per-chunk scales."""
    scored_at = jnp.full((num_examples,), -1, jnp.int32)
    if table_dtype == "f32":
        return WeightStore(
            weights=jnp.full((num_examples,), init_weight, jnp.float32),
            scored_at=scored_at)
    if table_dtype == "bf16":
        return WeightStore(
            weights=jnp.full((num_examples,), init_weight, jnp.bfloat16),
            scored_at=scored_at)
    if table_dtype != "int8":
        raise ValueError(f"unknown table_dtype {table_dtype!r}")
    if chunk_size <= 0 or num_examples % chunk_size:
        raise ValueError(f"int8 tables need chunk_size > 0 dividing "
                         f"num_examples={num_examples}, got {chunk_size}")
    codes, qscale = quantize_weights(
        jnp.full((num_examples,), init_weight, jnp.float32), chunk_size)
    return WeightStore(weights=codes, scored_at=scored_at, qscale=qscale)


def store_chunk_size(store: WeightStore) -> int:
    """Static chunk size of an int8 table, recovered from the shapes."""
    if store.qscale is None:
        raise ValueError("store has no per-chunk scales (not int8)")
    return store.weights.shape[0] // store.qscale.shape[0]


def quantize_weights(weights: jax.Array,
                     chunk_size: int) -> tuple[jax.Array, jax.Array]:
    """Quantize nonnegative f32 weights to (int8 codes, per-chunk scale).

    Scale_c = max weight in chunk c (1.0 for all-zero chunks so the
    codes stay 0); code = round(clip(w,0,scale)·INT8_LEVELS/scale).
    Negative raw weights clip to code 0 — harmless, because the proposal
    smoothing (B.3) already maps them to the same floor as 0."""
    n = weights.shape[0]
    if chunk_size <= 0 or n % chunk_size:
        raise ValueError(f"chunk_size={chunk_size} must divide n={n}")
    w = jnp.maximum(weights.astype(jnp.float32), 0.0)
    rows = w.reshape(-1, chunk_size)
    scale = jnp.max(rows, axis=1)
    scale = jnp.where(scale > 0, scale, jnp.ones_like(scale))
    codes = jnp.round(rows / scale[:, None] * INT8_LEVELS)
    codes = jnp.clip(codes, 0, INT8_LEVELS).astype(jnp.int8)
    return codes.reshape(-1), scale


def dequantize_weights(store: WeightStore) -> jax.Array:
    """Reconstruct the f32 weight view of a quantized table: int8 codes
    scale back through ``qscale``; bf16 upcasts; f32 passes through."""
    if store.qscale is not None:
        cs = store_chunk_size(store)
        scale_rows = jnp.repeat(store.qscale / INT8_LEVELS, cs)
        return store.weights.astype(jnp.float32) * scale_rows
    if store.weights.dtype != jnp.float32:
        return store.weights.astype(jnp.float32)
    return store.weights


def _requantize(store: WeightStore, weights_f32: jax.Array) -> WeightStore:
    """Write an updated f32 weight view back into the storage dtype:
    int8 tables recompute their per-chunk scales (a write can raise a
    chunk's max), bf16 rounds, f32 stores as-is."""
    if store.qscale is not None:
        codes, qscale = quantize_weights(weights_f32,
                                         store_chunk_size(store))
        return store._replace(weights=codes, qscale=qscale)
    return store._replace(
        weights=weights_f32.astype(store.weights.dtype))


def quantization_tv_bound(store_f32: WeightStore, step: jax.Array | int,
                          cfg: ISConfig, chunk_size: int,
                          table_dtype: str) -> jax.Array:
    """Analytic upper bound on TV(p_f32, p_quantized) for the proposal a
    quantized copy of ``store_f32`` would yield.

    With a_i = filtered-smoothed f32 weights and b_i their quantized
    twins, TV(a/A, b/B) ≤ (1/A)·Σ|a_i − b_i| (triangle inequality on
    both the rows and the normalizer).  Rows the B.1 filter neutralizes
    (never scored / too stale / EMPTY) are bitwise identical in both
    tables, so only surviving rows contribute: per-row error ≤
    2⁻⁹·|w| for bf16 (half-ulp rounding) and scale_c·(1/(2·INT8_LEVELS)
    + 2⁻²⁰) for int8 (half a quantization step plus f32 arithmetic
    slack).  The chi²/TV battery in tests/test_sampler_stats.py asserts
    the measured distance stays under this bound."""
    # apply_staleness_filter on all-ones marks exactly the neutralized rows
    active = apply_staleness_filter(
        jnp.ones_like(store_f32.weights, jnp.float32),
        store_f32.scored_at, step, cfg) > 0
    w = store_f32.weights.astype(jnp.float32)
    if table_dtype == "bf16":
        per_row = BF16_HALF_ULP * jnp.abs(w)
    elif table_dtype == "int8":
        _, scale = quantize_weights(w, chunk_size)
        per_row = jnp.repeat(
            scale * (0.5 / INT8_LEVELS + 2.0 ** -20), chunk_size)
    else:
        raise ValueError(f"no quantization bound for {table_dtype!r}")
    err = jnp.sum(jnp.where(active, per_row, 0.0))
    z = jnp.sum(read_proposal(store_f32, step, cfg))
    return err / z


def decay_proposal(proposal: jax.Array, scored_at: jax.Array,
                   step: jax.Array | int, ttl: float, cfg: ISConfig,
                   chunk_size: int) -> jax.Array:
    """Per-chunk TTL decay of the proposal toward the uniform floor.

    Chunk freshness is its newest ``scored_at`` stamp (the same quantity
    the PR 8 ``staleness`` monitor reduces); a chunk whose freshest row
    is ``age`` steps old decays by ``d = 2^(−age/ttl)``:

        q'_i = u + d_{c(i)} · (q_i − u),   u = smooth_weights(0)

    so at age=ttl a chunk has lost half its excess over the never-scored
    neutral mass ``u`` and q' → u as age → ∞.  Chunks with no scored
    rows keep d=1 (their rows already sit at u), EMPTY rows stay at
    exactly 0, and every row keeps q' ≥ min(q, u) ≥ floor — Theorem 1's
    q>0 support condition survives decay.  ``ttl<=0`` must be handled by
    the caller as the identity (the HLO-gated off path)."""
    if ttl <= 0:
        raise ValueError("decay_proposal requires ttl > 0; ttl==0 is the "
                         "caller's identity path")
    n = proposal.shape[0]
    chunks = -(-n // chunk_size)
    pad = chunks * chunk_size - n
    sa = scored_at
    if pad:
        sa = jnp.concatenate(
            [sa, jnp.full((pad,), EMPTY, jnp.int32)])
    freshest = jnp.max(sa.reshape(chunks, chunk_size), axis=1)
    age = jnp.maximum(jnp.asarray(step, jnp.int32) - freshest, 0)
    age = jnp.where(freshest >= 0, age, 0).astype(jnp.float32)
    d = jnp.exp2(-age / jnp.float32(ttl))
    d_row = jnp.repeat(d, chunk_size)[:n]
    neutral = jnp.asarray(max(cfg.smoothing, cfg.floor), proposal.dtype)
    decayed = neutral + d_row.astype(proposal.dtype) * (proposal - neutral)
    return jnp.where(scored_at <= EMPTY, jnp.zeros_like(decayed), decayed)


def reserve_tail(store: WeightStore, num_live: int) -> WeightStore:
    """Mark every row past ``num_live`` as reserved capacity (EMPTY).

    The serving loop pre-allocates store rows for traffic it will ingest
    later; until `mark_live` stamps them, those rows are invisible to the
    proposal and inert under scoring."""
    idx = jnp.arange(store.scored_at.shape[0])
    return store._replace(scored_at=jnp.where(idx < num_live,
                                              store.scored_at,
                                              jnp.asarray(EMPTY, jnp.int32)))


def mark_live(store: WeightStore, indices) -> WeightStore:
    """Flip reserved rows to 'never scored' (-1) once real data lands in
    them, making them eligible for scoring and (once scored) sampling."""
    indices = jnp.asarray(indices, jnp.int32)
    return store._replace(
        scored_at=store.scored_at.at[indices].set(-1))


def write_scores(
    store: WeightStore,
    indices: jax.Array,
    scores: jax.Array,
    step: jax.Array | int,
) -> WeightStore:
    """Workers push fresh ω̃ for the examples they just scored.

    Quantized (int8) tables round-trip through the f32 view: the touched
    rows are written at full precision, then the affected chunks'
    scales/codes are recomputed (a fresh score can raise a chunk max)."""
    step = jnp.asarray(step, jnp.int32)
    scored_at = store.scored_at.at[indices].set(step)
    if store.qscale is not None:
        w = dequantize_weights(store).at[indices].set(
            scores.astype(jnp.float32))
        return _requantize(store._replace(scored_at=scored_at), w)
    return store._replace(
        weights=store.weights.at[indices].set(
            scores.astype(store.weights.dtype)),
        scored_at=scored_at,
    )


def write_scores_global(
    store: WeightStore,
    global_indices: jax.Array,
    scores: jax.Array,
    step: jax.Array | int,
    axes: tuple[str, ...] = (),
) -> WeightStore:
    """Push fresh ω̃ at *global* indices into an example-axis-sharded store:
    each device applies the writes it owns, the rest drop (the fused mode's
    replicated minibatch scores land on whichever shard holds each row).
    With axes=() this is exactly `write_scores`."""
    from repro.core.collectives import scatter_rows
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32),
                            global_indices.shape)
    scored_at = scatter_rows(store.scored_at, global_indices, step, axes)
    if store.qscale is not None:
        w = scatter_rows(dequantize_weights(store), global_indices,
                         scores.astype(jnp.float32), axes)
        return _requantize(store._replace(scored_at=scored_at), w)
    return store._replace(
        weights=scatter_rows(store.weights, global_indices, scores, axes),
        scored_at=scored_at,
    )


class BufferedWeightStore(NamedTuple):
    """Double-buffered store for the async scoring pipeline
    (core/async_pipeline.py).

    The master samples from ``read_buf`` — a snapshot of the table as of
    step ``synced_at`` — while the workers' scoring writes land in
    ``write_buf``, so the two computations share no buffers and can be
    dispatched concurrently.  ``publish`` is the swap (the pipeline's only
    sync point): it snapshots write_buf into read_buf.

    With swap cadence K the master at step t samples from the table as
    written through step K·⌊t/K⌋ − 1, i.e. the run is exactly a
    relaxed-mode run whose proposal is L(t) = t − K·⌊t/K⌋ + 1 ∈ [1, K]
    steps staler — same §4.1 unbiasedness (the IS scales come from the
    same lagged proposal the sampler used), and the lag is observable
    through ``read_buf.scored_at`` exactly like the paper's B.1 timestamps.
    """
    read_buf: WeightStore    # the master's snapshot (proposal source)
    write_buf: WeightStore   # where the scoring fan-out's writes land
    synced_at: jax.Array     # i32: last step whose writes read_buf holds


def _copy_store(store: WeightStore) -> WeightStore:
    """Fresh device buffers (sharding-preserving).  The copies matter:
    read_buf must never alias write_buf, because the scoring step donates
    write_buf for in-place updates."""
    return WeightStore(weights=jnp.copy(store.weights),
                       scored_at=jnp.copy(store.scored_at),
                       qscale=(None if store.qscale is None
                               else jnp.copy(store.qscale)))


def to_buffered(store: WeightStore) -> BufferedWeightStore:
    """Wrap a plain store for the async pipeline: both buffers start as
    distinct copies of the current table; nothing published yet."""
    return BufferedWeightStore(read_buf=_copy_store(store),
                               write_buf=_copy_store(store),
                               synced_at=jnp.asarray(-1, jnp.int32))


def publish(bstore: BufferedWeightStore,
            step: jax.Array | int) -> BufferedWeightStore:
    """The swap: read_buf ← snapshot of write_buf, stamped with the last
    step whose writes it now holds.  One device-side copy of the table
    shard every K steps — the async pipeline's only sync point."""
    return BufferedWeightStore(read_buf=_copy_store(bstore.write_buf),
                               write_buf=bstore.write_buf,
                               synced_at=jnp.asarray(step, jnp.int32))


def read_proposal(
    store: WeightStore,
    step: jax.Array | int,
    cfg: ISConfig,
) -> jax.Array:
    """The master reads the sampling proposal: staleness-filter (B.1) then
    additive smoothing (B.3).  Never-scored entries act as the neutral
    (uniform) weight, so a cold store reproduces plain SGD exactly.
    Reserved rows (scored_at == EMPTY, serving-loop capacity not yet
    ingested) are excluded outright — zero proposal mass.

    Quantized tables dequantize to their f32 view first, so the sampled
    distribution *is* the quantized proposal (what the chi²/TV battery
    in tests/test_sampler_stats.py tests against); f32 tables trace the
    exact original program (static-dtype dispatch, no device branch)."""
    raw = dequantize_weights(store)
    w = apply_staleness_filter(raw, store.scored_at, step, cfg)
    q = smooth_weights(w, cfg)
    return jnp.where(store.scored_at <= EMPTY, jnp.zeros_like(q), q)


def mark_live_buffered(bstore: BufferedWeightStore,
                       indices) -> BufferedWeightStore:
    """`mark_live` on the *write* buffer only: the newly ingested rows
    flow to the master's snapshot at the next `publish`, preserving the
    swap-cadence staleness discipline (read_buf keeps them EMPTY until
    then, so the proposal never sees rows newer than its snapshot)."""
    return bstore._replace(write_buf=mark_live(bstore.write_buf, indices))


class PublishedParams(NamedTuple):
    """A consistent parameter snapshot for serving — the model-weights
    analogue of the proposal's ``read_buf``: serving reads only published
    snapshots, so under publish cadence K it is at most K steps stale and
    the PR 2 swap invariant extends verbatim to decode."""
    params: object          # pytree snapshot (fresh buffers)
    synced_at: jax.Array    # i32: train step the snapshot was taken at


def publish_params(params, step: jax.Array | int) -> PublishedParams:
    """Snapshot the training params into fresh (sharding-preserving)
    buffers for serving — same no-alias rationale as `_copy_store`: the
    training step may donate its param buffers."""
    return PublishedParams(params=jax.tree.map(jnp.copy, params),
                           synced_at=jnp.asarray(step, jnp.int32))


def staleness_stats(store: WeightStore, step: jax.Array | int) -> dict:
    """Monitoring: paper B.1 reports the fraction of weights fresh enough."""
    step = jnp.asarray(step, jnp.int32)
    scored = store.scored_at >= 0
    age = jnp.where(scored, step - store.scored_at, jnp.iinfo(jnp.int32).max)
    return {
        "frac_scored": jnp.mean(scored.astype(jnp.float32)),
        "mean_age": jnp.mean(jnp.where(scored, age, 0).astype(jnp.float32))
        / jnp.maximum(jnp.mean(scored.astype(jnp.float32)), 1e-9),
        "max_age": jnp.max(jnp.where(scored, age, -1)),
    }

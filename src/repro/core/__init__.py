"""The paper's contribution as a composable public API."""
from repro.core.importance import ISConfig, is_loss_scale, smooth_weights
from repro.core.issgd import (ISSGDConfig, StepMetrics, TrainState,
                              init_train_state, make_score_step,
                              make_train_step)
from repro.core.sampler import make_distributed_sampler, sample_indices
from repro.core.scorer import make_lm_scorer, make_mlp_scorer
from repro.core.variance import (trace_sigma, trace_sigma_all,
                                 trace_sigma_ideal, trace_sigma_unif)
from repro.core.weight_store import (WeightStore, init_store, read_proposal,
                                     write_scores)

__all__ = [
    "ISConfig", "ISSGDConfig", "StepMetrics", "TrainState", "WeightStore",
    "init_store", "init_train_state", "is_loss_scale", "make_distributed_sampler",
    "make_lm_scorer", "make_mlp_scorer", "make_score_step", "make_train_step",
    "read_proposal", "sample_indices", "smooth_weights", "trace_sigma",
    "trace_sigma_all", "trace_sigma_ideal", "trace_sigma_unif", "write_scores",
]

"""ISSGD — the paper's distributed importance-sampling SGD (section 4).

One SPMD train step fuses the paper's three actors (DESIGN.md §2):

  workers   → a scoring pass over a round-robin slice of the dataset,
              evaluated with *stale* parameters θ_stale (refreshed every
              `refresh_every` steps — the paper's parameter-push period);
  database  → the WeightStore (sharded ω̃ + scored_at arrays);
  master    → proposal read (B.1 staleness filter + B.3 smoothing),
              multinomial sampling, IS-scaled unbiased loss (§4.1),
              gradient step.

Modes:
  relaxed   the paper's practical algorithm (stale weights, fire-and-forget)
  exact     the §4.1 oracle: rescore the *whole* dataset with fresh params
            every step (synchronization barriers of fig. 1 enforced)
  uniform   plain SGD baseline (scoring still runs for monitoring parity,
            like the paper's background worker for the SGD runs)
  fused     beyond-paper (the paper's §6 "combine with ASGD" suggestion):
            no separate scoring pass — the training forward itself emits
            the per-example scores for the minibatch it trains on, and the
            store is refreshed for those examples at ~zero extra cost.
            Coverage of unsampled examples comes from an optional probe
            step (make_score_step) the launcher runs every K steps.

Distribution (core/distributed.py wires this under shard_map):

The step body is written against `axes`, a tuple of mesh axis names over
which the dataset, the WeightStore, and the scoring fan-out are sharded.
`cfg.score_shards` (W) fixes a *logical* decomposition of the table into W
contiguous scoring shards, independent of the device count: each device
owns W/num_devices of them, scores a round-robin slice of each per step,
and sampling is hierarchical (block totals → within-block resolve; see
core/sampler.py).  Because W — not the mesh — defines the decomposition,
running with axes=() on one device is bitwise the same algorithm, which is
what the sharded-equivalence tests pin down.  The full f32[N] table is
never gathered: the master only ever touches B sampled rows (one-owner
masked psums) and W block totals.

The step body is factored into two reusable halves — `make_scoring_pass`
(the workers) and `make_master_pass` (the master) — so that the fused step
built here (their lag-0 composition over one store) and the async pipeline
of core/async_pipeline.py (the two halves dispatched concurrently through
a double-buffered store) are literally the same code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import variance
from repro.core.collectives import axis_info, gather_rows, psum
from repro.core.importance import (ISConfig, effective_sample_size,
                                   is_loss_scale)
from repro.core.sampler import two_stage_sample
from repro.core.weight_store import (WeightStore, init_store, read_proposal,
                                     write_scores, write_scores_global)
from repro.data.pipeline import gather_batch
from repro.optim import Optimizer, global_norm


@dataclasses.dataclass(frozen=True)
class ISSGDConfig:
    """Step-shape knobs: batch sizes, refresh cadence, mode, smoothing,
    and the mesh-free logical scoring decomposition W."""
    batch_size: int = 64
    score_batch_size: int = 256        # examples rescored per step ("workers")
    refresh_every: int = 8             # θ_stale refresh period (param pushes)
    mode: str = "relaxed"              # relaxed | exact | uniform | fused
    is_cfg: ISConfig = ISConfig()
    grad_clip: float = 0.0
    score_shards: int = 1              # W: logical scoring shards (mesh-free)
    # --- billion-example sampling structures (ISSUE 10) ------------------
    # stage-1 source: "dense" recomputes block masses in-draw; "tree"
    # routes them through core/mass_index.py (bitwise-equal draws)
    index: str = "dense"               # dense | tree
    # storage dtype of the weight table: f32 | bf16 | int8 (+ per-chunk
    # scale); non-f32 reads dequantize, so the sampled distribution IS
    # the quantized proposal
    table_dtype: str = "f32"
    # TTL decay of stale scores toward the uniform floor, in steps
    # (weight_store.decay_proposal); 0 disables (HLO-identical off path)
    score_ttl: int = 0
    # chunk granularity for the index / int8 scales / TTL decay; 0 →
    # one chunk per logical scoring shard (n_w)
    index_chunk_size: int = 0


class TrainState(NamedTuple):
    """Everything a step carries: master + worker params, the store, the
    step counter, and the PRNG key stream."""
    params: Any
    opt_state: Any
    stale_params: Any                  # the workers' parameter copy
    store: WeightStore
    step: jax.Array
    rng: jax.Array


class StepMetrics(NamedTuple):
    """Per-step monitors (paper fig. 4 traces + sampling diagnostics)."""
    loss: jax.Array
    grad_norm: jax.Array
    # √Tr(Σ(q)) monitors over the freshly scored slice (paper fig. 4)
    trace_ideal: jax.Array
    trace_stale: jax.Array
    trace_unif: jax.Array
    ess_frac: jax.Array                # ESS of proposal / N
    mean_weight: jax.Array
    sample_indices: jax.Array          # which examples were trained on


def init_train_state(params, optimizer: Optimizer, num_examples: int,
                     seed: int = 0, table_dtype: str = "f32",
                     index_chunk_size: int = 0) -> TrainState:
    """Fresh TrainState: stale params start as a copy of θ₀, the store
    unscored (uniform proposal until the first sweep).  ``table_dtype``/
    ``index_chunk_size`` select the store representation (see
    ``weight_store.init_store``)."""
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        stale_params=jax.tree.map(lambda x: x, params),
        store=init_store(num_examples, table_dtype=table_dtype,
                         chunk_size=index_chunk_size),
        step=jnp.zeros((), jnp.int32),
        rng=jax.random.key(seed),
    )


def read_sampling_proposal(store: WeightStore, step, cfg: ISSGDConfig,
                           n_w: int) -> jax.Array:
    """The proposal the master actually draws from: ``read_proposal``
    (B.1 filter + B.3 smoothing + EMPTY mask, dequantizing non-f32
    tables) followed by the optional per-chunk TTL decay toward the
    uniform floor.  ``score_ttl=0`` takes the identity code path —
    byte-identical HLO to a build that never heard of decay (gated in
    tests/test_mass_index.py).  Shard-local: the streamed sample_step
    calls the same function so host and device replay the same draw."""
    proposal = read_proposal(store, step, cfg.is_cfg)
    if cfg.score_ttl > 0:
        from repro.core.weight_store import decay_proposal
        cs = cfg.index_chunk_size or n_w
        proposal = decay_proposal(proposal, store.scored_at, step,
                                  cfg.score_ttl, cfg.is_cfg, cs)
    return proposal


def stage1_block_sums(proposal: jax.Array, w_loc: int,
                      cfg: ISSGDConfig) -> jax.Array | None:
    """Stage-1 masses for ``two_stage_sample``: None in dense mode (the
    draw recomputes them — the default, HLO-gated path); in tree mode
    the per-block masses come from the mass index's canonical reduction,
    which is bitwise the in-draw reduction, so tree draws ≡ dense
    draws (the ISSUE 10 acceptance pin)."""
    if cfg.index == "dense":
        return None
    if cfg.index != "tree":
        raise ValueError(f"unknown index {cfg.index!r}")
    from repro.core.mass_index import block_masses
    return block_masses(proposal, w_loc)


def _resolve_shards(cfg: ISSGDConfig, num_examples: int, sb: int,
                    n_local: int, n_dev: int) -> tuple[int, int, int]:
    """(w_loc, n_w, sb_w): per-device logical shards, shard length, and
    per-shard scoring slice — validated against the static shapes."""
    w = max(cfg.score_shards, 1)
    if w % n_dev:
        raise ValueError(f"score_shards={w} must be divisible by the "
                         f"device count {n_dev}")
    if num_examples % w:
        raise ValueError(f"num_examples={num_examples} not divisible by "
                         f"score_shards={w}")
    if sb % w:
        raise ValueError(f"score_batch_size={sb} not divisible by "
                         f"score_shards={w}")
    if n_local * n_dev != num_examples:
        raise ValueError(f"store shard of {n_local} rows × {n_dev} devices "
                         f"≠ num_examples={num_examples}")
    w_loc = w // n_dev
    return w_loc, n_local // w_loc, sb // w


def _spec_touches(spec, axes: tuple[str, ...]) -> bool:
    """Whether a PartitionSpec shards any dim over one of `axes`."""
    names: set = set()
    for entry in tuple(spec):
        if isinstance(entry, (tuple, list)):
            names.update(entry)
        elif entry is not None:
            names.add(entry)
    return bool(names & set(axes))


def _grad_global_norm(grads, model_axes: tuple[str, ...],
                      param_pspecs) -> jax.Array:
    """The true global grad norm when params (hence grads) may be
    model-axis-sharded: leaves sharded over `model_axes` contribute their
    local partial square-sum, replicated leaves (computed redundantly on
    every model device) are pre-divided by the axis size, and the total is
    psum-reduced before the sqrt.  With model_axes=() this is arithmetic-
    identical to `optim.global_norm`."""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import axis_info
    if not model_axes:
        return global_norm(grads)
    if param_pspecs is None:
        raise ValueError("model_axes set but no param_pspecs: the grad "
                         "norm cannot tell sharded from replicated leaves")
    _, n_model = axis_info(model_axes)

    def leaf(g, spec):
        s = jnp.sum(jnp.square(g.astype(jnp.float32)))
        return s if _spec_touches(spec, model_axes) else s / n_model

    sq = sum(jax.tree.leaves(jax.tree.map(
        leaf, grads, param_pspecs, is_leaf=lambda x: isinstance(x, P))))
    return jnp.sqrt(psum(sq, model_axes))


def _score_slice(step: jax.Array, w_loc: int, n_w: int, sb_w: int) -> jax.Array:
    """Local indices of this step's round-robin scoring slice: each of the
    device's `w_loc` logical shards contributes `sb_w` examples."""
    base = (step * sb_w + jnp.arange(sb_w)) % n_w            # (sb_w,)
    return (jnp.arange(w_loc)[:, None] * n_w + base[None, :]).reshape(-1)


def scoring_layout(cfg: ISSGDConfig, num_examples: int,
                   n_dev: int = 1) -> tuple[int, int, int]:
    """Static (w_loc, n_w, sb_w) scoring layout for an n_dev-device run —
    the host-side streaming scheduler (data/streaming.py) uses this plus
    `_score_slice`'s formula to pre-fetch exactly the rows each device's
    scoring pass will touch, without tracing anything."""
    if num_examples % n_dev:
        raise ValueError(f"num_examples={num_examples} not divisible by "
                         f"{n_dev} devices")
    sb = num_examples if cfg.mode == "exact" else cfg.score_batch_size
    return _resolve_shards(cfg, num_examples, sb, num_examples // n_dev,
                           n_dev)


def make_scoring_pass(
    scorer: Callable,               # (params, batch) -> (B,) ω̃ (grad norms)
    cfg: ISSGDConfig,
    num_examples: int,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
    streaming: bool = False,
) -> Callable:
    """The workers' scoring fan-out as a reusable body.

    Returns ``scoring_pass(score_params, store, step, data) ->
    (store, fresh_scores, stale_slice)``: rescore this step's round-robin
    slice with `score_params` and push into `store`; `stale_slice` is the
    proposal over the slice *before* the write (the eq. 9 monitor input).
    Shard-local end to end (zero collectives) — in the async pipeline this
    is the computation that overlaps the master update.

    With ``streaming=True`` the ``data`` argument is the *pre-gathered*
    scoring slice itself (this device's sb_w·w_loc rows, host-streamed by
    data/streaming.py) rather than the device-resident dataset: the body
    never sees an example-count-sized array, which is the no-full-dataset
    guarantee the streamed HLO gate pins.  The store write still lands at
    the same round-robin indices, so the two variants are bitwise equal.
    """
    is_cfg = cfg.is_cfg
    n = num_examples
    sb = n if cfg.mode == "exact" else cfg.score_batch_size
    if constrain_batch is None:
        constrain_batch = lambda b: b
    axes = tuple(axes)

    def scoring_pass(score_params, store: WeightStore, step, data):
        _, n_dev = axis_info(axes)
        n_local = store.weights.shape[0]
        w_loc, n_w, sb_w = _resolve_shards(cfg, n, sb, n_local, n_dev)
        score_idx = _score_slice(step, w_loc, n_w, sb_w)
        score_batch = constrain_batch(
            data if streaming else gather_batch(data, score_idx))
        fresh_scores = scorer(score_params, score_batch)
        # stale view of the slice BEFORE the write (for eq. 9 monitor)
        pre_proposal = read_proposal(store, step, is_cfg)
        stale_slice = pre_proposal[score_idx]
        # reserved serving-capacity rows (scored_at == EMPTY) stay inert:
        # their scores are forced to 0 and their EMPTY stamp survives the
        # write, so un-ingested rows never gain proposal mass.  With no
        # reserved rows in the slice this is the identity dataflow.
        from repro.core.weight_store import EMPTY
        live = store.scored_at[score_idx] > EMPTY
        fresh_scores = jnp.where(live, fresh_scores,
                                 jnp.zeros_like(fresh_scores))
        stamp = jnp.where(live,
                          jnp.broadcast_to(jnp.asarray(step, jnp.int32),
                                           live.shape),
                          jnp.asarray(EMPTY, jnp.int32))
        new_store = write_scores(store, score_idx, fresh_scores, stamp)
        return new_store, fresh_scores, stale_slice

    return scoring_pass


def make_master_pass(
    per_example_loss: Callable,     # (params, batch) -> (B,) losses
    optimizer: Optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    aux_loss: Optional[Callable] = None,   # (params, batch) -> scalar extra
    fused_score: Optional[Callable] = None,  # (params, batch) ->
    # (losses (B,), scores (B,)); required for mode="fused" — the training
    # forward emits its own importance scores (paper §6 direction)
    constrain_batch: Optional[Callable] = None,  # batch -> batch with
    # sharding constraints; jit-partitioned launchers (dryrun) pass one so
    # the gathered minibatch is batch-sharded over the data axes
    axes: tuple[str, ...] = (),     # mesh axes the example dim is sharded
    # over when the step runs inside shard_map; () = single-device
    model_axes: tuple[str, ...] = (),   # mesh axes the params are tensor-
    # sharded over; per_example_loss/fused_score must then be model-axis-
    # aware (they see local column shards and gather activations), and
    # `param_pspecs` (the tree from dist.sharding.param_pspecs) is
    # required so the grad norm can tell sharded from replicated leaves
    param_pspecs=None,
    monitors=None,                  # telemetry.MonitorSet: compile the
    # enabled proposal-health monitors into the step as ONE extra output
    # (a {name: scalar} dict).  None / empty set is the identity code
    # path — the program is HLO-identical to a monitor-free build, and
    # enabling monitors never changes the trajectory (both pinned in
    # tests/test_telemetry.py)
    streaming: bool = False,        # `data` is the pre-gathered replicated
    # minibatch (B rows) instead of the resident dataset; the sampled
    # indices are still drawn in-program from the store, and the host
    # driver (data/streaming.py) resolves them against its window — the
    # draw is deterministic given (store, step, rng), so both sides agree
    gated: bool = False,            # the controller's uniform↔IS gate: the
    # body takes one extra trailing device-bool `use_is` and selects the
    # sampling branch with jnp.where, so the host can flip modes without
    # a recompile.  gated=False is the identity code path (HLO-identical
    # to a build that never heard of the gate); a closed gate is bitwise
    # the uniform-mode program (both pinned in tests/test_controller.py).
    # Requires mode="relaxed" — the gate *is* the relaxed↔uniform switch.
) -> Callable:
    """The master's half of the step as a reusable body.

    Returns ``master_pass(params, opt_state, stale_params, store, step,
    k_sample, data, fresh_scores=None, stale_slice=None) -> (params,
    opt_state, stale_params, store, metrics)``: proposal read (B.1 + B.3)
    → two-stage sample → IS-scaled unbiased update (§4.1) → parameter
    push.  `store` is whatever proposal source the caller hands it: the
    freshly written store in the fused-step composition, or the lagged
    ``read_buf`` in the async pipeline.  `fresh_scores`/`stale_slice` feed
    the fig-4 trace monitors; when None (async — the monitors ride with
    the scoring step instead) the traces come back NaN.

    With a non-empty ``monitors`` set the return tuple grows one trailing
    element: the ``{name: scalar}`` proposal-health dict of
    telemetry/monitors.py, computed from the same proposal the sampler
    drew from (in async mode that is ``read_buf`` — the observed
    staleness monitor reads the lag right off its scored_at stamps).

    With ``gated=True`` the body takes one extra trailing ``use_is``
    device-bool (LAST in the signature, after the optional score args):
    both the uniform draw and the IS draw are computed from the same
    ``k_sample`` and selected elementwise, so a closed gate reproduces
    the uniform-mode trajectory bit-for-bit and an open gate the relaxed
    one — the controller (core/controller.py) owns the scalar.
    """
    is_cfg = cfg.is_cfg
    n = num_examples
    sb = n if cfg.mode == "exact" else cfg.score_batch_size
    if cfg.mode == "fused" and fused_score is None:
        raise ValueError("mode='fused' requires fused_score")
    if gated and cfg.mode != "relaxed":
        raise ValueError(f"gated=True switches relaxed↔uniform in-program; "
                         f"it requires mode='relaxed', got {cfg.mode!r}")
    if constrain_batch is None:
        constrain_batch = lambda b: b
    axes = tuple(axes)
    model_axes = tuple(model_axes)
    monitors = monitors or None

    def master_pass(params, opt_state, stale_params, store: WeightStore,
                    step, k_sample, data,
                    fresh_scores=None, stale_slice=None, use_is=None):
        if gated and use_is is None:
            raise ValueError("gated master_pass needs the use_is scalar")
        _, n_dev = axis_info(axes)
        n_local = store.weights.shape[0]
        w_loc, n_w, sb_w = _resolve_shards(cfg, n, sb, n_local, n_dev)

        # ---- 2. master reads the proposal (B.1 + B.3 + optional TTL
        # decay, dequantized for non-f32 tables), shard-local -----------------
        proposal = read_sampling_proposal(store, step, cfg, n_w)
        sum_w = psum(jnp.sum(proposal), axes)
        mean_weight = sum_w / n
        if monitors:
            from repro.telemetry.monitors import proposal_monitors
            # over the proposal actually sampled from, BEFORE this step's
            # writes (in async mode `store` is the lagged read_buf, so the
            # staleness monitor observes exactly L(t))
            mon = proposal_monitors(store, proposal, step, axes, n,
                                    monitors, sum_w=sum_w)

        # ---- 3. compose the minibatch (two-stage sample + one-owner gather) --
        if cfg.mode == "uniform":
            idx = jax.random.randint(k_sample, (cfg.batch_size,), 0, n)
            scales = jnp.ones((cfg.batch_size,), jnp.float32)
        elif gated:
            # both draws from the same k_sample (pure functions of the
            # key), selected by the controller's gate: a closed gate IS
            # the uniform branch above, bit-for-bit
            idx_u = jax.random.randint(k_sample, (cfg.batch_size,), 0, n)
            idx_is = two_stage_sample(k_sample, proposal, cfg.batch_size,
                                      axes=axes, shards_per_device=w_loc,
                                      block_sums=stage1_block_sums(
                                          proposal, w_loc, cfg))
            idx = jnp.where(use_is, idx_is, idx_u)
            sampled_w = gather_rows(proposal, idx, axes)
            scales = jnp.where(use_is,
                               is_loss_scale(sampled_w, mean_weight),
                               jnp.ones((cfg.batch_size,), jnp.float32))
        else:
            idx = two_stage_sample(k_sample, proposal, cfg.batch_size,
                                   axes=axes, shards_per_device=w_loc,
                                   block_sums=stage1_block_sums(
                                       proposal, w_loc, cfg))
            sampled_w = gather_rows(proposal, idx, axes)
            scales = is_loss_scale(sampled_w, mean_weight)
        batch = constrain_batch(data if streaming
                                else gather_rows(data, idx, axes))

        # ---- 4. unbiased IS-scaled update (§4.1) ----------------------------
        # The gathered minibatch is replicated; every device computes the
        # identical master update (the paper's single master, SPMD-style) —
        # the parallelism win is the scoring fan-out above, which is the
        # dominant cost (score_batch_size ≫ batch_size).
        def loss_fn(params):
            if cfg.mode == "fused":
                losses, scores = fused_score(params, batch)
                scores = jax.lax.stop_gradient(scores)
            else:
                losses, scores = per_example_loss(params, batch), None
            loss = jnp.mean(losses * scales)
            if aux_loss is not None:
                loss = loss + aux_loss(params, batch)
            return loss, scores

        (loss, batch_scores), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if cfg.mode == "fused":
            # zero-cost refresh for the examples just trained on.
            # NOTE: the fig-4 monitors below are then computed on an
            # importance-SAMPLED slice rather than a uniform one, so
            # trace_stale is biased upward (high-weight examples are
            # over-represented); use the probe step's uniform slices for
            # faithful monitoring in fused mode.
            fresh_scores = batch_scores
            stale_slice = sampled_w  # proposal at idx, already gathered
            store = write_scores_global(store, idx, batch_scores, step, axes)
        gnorm = _grad_global_norm(grads, model_axes, param_pspecs)
        if cfg.grad_clip > 0:
            from repro.optim import clip_by_global_norm
            # clip against the model-axis-aware norm computed above
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip, norm=gnorm)
        new_params, opt_state = optimizer.update(grads, opt_state,
                                                 params, step)

        # ---- 5. parameter push to the workers every K steps ------------------
        if cfg.mode == "exact":
            stale_params = new_params
        else:
            push = (step + 1) % cfg.refresh_every == 0
            stale_params = jax.tree.map(
                lambda new, old: jnp.where(push, new, old),
                new_params, stale_params)

        # ---- 6. paper fig. 4 monitors over the scored slice ------------------
        # ||g_TRUE||² upper bound (B.2): the minibatch gradient norm
        if cfg.mode == "fused":
            # replicated minibatch slice: no psum (it would double-count)
            traces = variance.trace_sigma_all(fresh_scores, stale_slice)
        elif fresh_scores is None:
            # async pipeline: the scoring step owns the trace monitors
            nan = jnp.full((), jnp.nan, jnp.float32)
            traces = variance.TraceSigma(ideal=nan, stale=nan, unif=nan)
        else:
            traces = variance.trace_sigma_all_dist(fresh_scores, stale_slice,
                                                   axes, n_total=sb)
        sum_w2 = psum(jnp.sum(jnp.square(proposal)), axes)
        ess = effective_sample_size(proposal, s1=sum_w, s2=sum_w2) / n

        metrics = StepMetrics(
            loss=loss, grad_norm=gnorm,
            trace_ideal=jnp.sqrt(jnp.maximum(traces.ideal, 0.0)),
            trace_stale=jnp.sqrt(jnp.maximum(traces.stale, 0.0)),
            trace_unif=jnp.sqrt(jnp.maximum(traces.unif, 0.0)),
            ess_frac=ess, mean_weight=mean_weight,
            sample_indices=idx,
        )
        if monitors:
            return new_params, opt_state, stale_params, store, metrics, mon
        return new_params, opt_state, stale_params, store, metrics

    return master_pass


def make_train_step(
    per_example_loss: Callable,     # (params, batch) -> (B,) losses
    scorer: Callable,               # (params, batch) -> (B,) ω̃ (grad norms)
    optimizer: Optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    aux_loss: Optional[Callable] = None,
    fused_score: Optional[Callable] = None,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
    model_axes: tuple[str, ...] = (),
    param_pspecs=None,
    monitors=None,
    gated: bool = False,
) -> Callable:
    """Build the fused ISSGD step: (state, dataset_arrays) -> (state, metrics).

    This is the synchronous composition ``master_pass ∘ scoring_pass`` over
    a single-buffer store: step t's master samples from a proposal that
    already includes step t's scoring writes (lag 0).  The async pipeline
    (core/async_pipeline.py) runs the same two bodies concurrently through
    a double-buffered store instead.

    With a non-empty ``monitors`` (telemetry.MonitorSet) the step returns
    ``(state, metrics, monitor_dict)`` instead — the proposal-health
    scalars ride the compiled step as extra outputs; without it the
    program is untouched (HLO-identical, tests/test_telemetry.py).

    With ``gated=True`` (mode="relaxed" only) the step signature becomes
    ``(state, data, use_is)``: the trailing device-bool selects the
    sampling branch in-program (see ``make_master_pass``), so the
    adaptive controller can flip uniform↔IS without recompiling.
    ``gated=False`` is the identity code path.
    """
    axes = tuple(axes)
    monitors = monitors or None
    scoring = (None if cfg.mode == "fused" else
               make_scoring_pass(scorer, cfg, num_examples,
                                 constrain_batch, axes))
    master = make_master_pass(per_example_loss, optimizer, cfg, num_examples,
                              aux_loss=aux_loss, fused_score=fused_score,
                              constrain_batch=constrain_batch, axes=axes,
                              model_axes=model_axes,
                              param_pspecs=param_pspecs, monitors=monitors,
                              gated=gated)

    def _train_step(state: TrainState, data: dict, use_is=None):
        rng, k_sample = jax.random.split(state.rng)
        step = state.step

        # ---- 1. scoring fan-out (the "workers"), shard-local -----------------
        if cfg.mode == "fused":
            store = state.store   # scores arrive from the train fwd instead
            fresh_scores = stale_slice = None
        else:
            score_params = (state.params if cfg.mode == "exact"
                            else state.stale_params)
            store, fresh_scores, stale_slice = scoring(
                score_params, state.store, step, data)

        # ---- 2-6. the master's half ------------------------------------------
        params, opt_state, stale_params, store, metrics, *mon = master(
            state.params, state.opt_state, state.stale_params, store, step,
            k_sample, data, fresh_scores, stale_slice, use_is)
        new_state = TrainState(params, opt_state, stale_params, store,
                               step + 1, rng)
        if monitors:
            return new_state, metrics, mon[0]
        return new_state, metrics

    if gated:
        def train_step(state: TrainState, data: dict, use_is):
            return _train_step(state, data, use_is)
    else:
        def train_step(state: TrainState, data: dict):
            return _train_step(state, data)

    train_step.with_monitors = bool(monitors)
    train_step.gated = bool(gated)
    return train_step


def make_score_step(
    scorer: Callable,
    cfg: ISSGDConfig,
    num_examples: int,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
) -> Callable:
    """Standalone probe/scoring step: rescore a round-robin slice with the
    workers' stale params and push to the store.  Used (a) by the fused
    mode to keep coverage of unsampled examples, and (b) to amortize
    scoring over K train steps (the B.1 staleness/throughput trade).
    Shard-local end to end: no collectives at all."""
    scoring = make_scoring_pass(scorer, cfg, num_examples,
                                constrain_batch, axes)

    def score_step(state: TrainState, data: dict) -> TrainState:
        store, _, _ = scoring(state.stale_params, state.store,
                              state.step, data)
        return state._replace(store=store)

    return score_step

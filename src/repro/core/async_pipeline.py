"""Async scoring pipeline: overlap the worker fan-out with the master update.

The paper's workers are "fire and forget" (§4, fig. 1): they push scores at
whatever cadence they manage while the master updates without waiting.  The
fused step of core/issgd.py serializes the two — step t's master samples
from a proposal that already includes step t's scoring writes.  This module
splits that step into two independently dispatched computations coordinated
through the double-buffered WeightStore (core/weight_store.py):

  scoring_step  the shard-local fan-out: rescore this step's round-robin
                slice with θ_stale and write into ``write_buf`` (donated,
                so XLA updates the table shard in place);
  master_step   proposal read from ``read_buf`` → two-stage sample →
                IS-scaled unbiased update (§4.1).  Never touches write_buf.

Nothing in master_step's dataflow depends on the same step's scoring_step
(they share no buffers), so JAX async dispatch queues both and the runtime
is free to overlap them — on a mesh the scoring fan-out is shard-local
while the master update is replicated.  The only sync point is the buffer
swap (``weight_store.publish``) every ``swap_every`` steps.

Invariant (pinned in tests/test_async.py): an async run with swap cadence K
is bitwise a relaxed-mode run whose proposal is L(t) = t − K·⌊t/K⌋ + 1
steps staler — the master at step t samples from the table as written
through step K·⌊t/K⌋ − 1.  Unbiasedness (§4.1) is untouched because the
IS loss scales are computed from the same lagged proposal the sampler drew
from; the lag is observable through ``read_buf.scored_at``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import variance
from repro.core.issgd import (ISSGDConfig, StepMetrics, TrainState,
                              init_train_state, make_master_pass,
                              make_scoring_pass)
from repro.core.weight_store import (BufferedWeightStore, publish,
                                     to_buffered)
from repro.optim import Optimizer


class ScoreMetrics(NamedTuple):
    """Fig-4 trace monitors, emitted by the scoring step (the master can't
    compute them in async mode without waiting on the fresh scores)."""
    trace_ideal: jax.Array
    trace_stale: jax.Array
    trace_unif: jax.Array


def score_trace_metrics(fresh_scores, stale_slice, axes, n_total,
                        monitor: bool = True) -> ScoreMetrics:
    """The scoring step's fig-4 monitors as ScoreMetrics (√TrΣ), shared by
    the async pipeline and the streamed scoring step of data/streaming.py.
    With ``monitor=False`` returns NaNs and stays collective-free."""
    if not monitor:
        nan = jnp.full((), jnp.nan, jnp.float32)
        return ScoreMetrics(nan, nan, nan)
    traces = variance.trace_sigma_all_dist(fresh_scores, stale_slice,
                                           axes, n_total=n_total)
    return ScoreMetrics(
        trace_ideal=jnp.sqrt(jnp.maximum(traces.ideal, 0.0)),
        trace_stale=jnp.sqrt(jnp.maximum(traces.stale, 0.0)),
        trace_unif=jnp.sqrt(jnp.maximum(traces.unif, 0.0)))


def make_async_steps(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer: Optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    aux_loss: Optional[Callable] = None,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
    model_axes: tuple[str, ...] = (),
    param_pspecs=None,
    monitor_traces: bool = True,
    monitors=None,
    gated: bool = False,
) -> tuple[Callable, Callable]:
    """Build the two independently dispatched bodies of the async pipeline.

    Returns ``(scoring_step, master_step)``:

      scoring_step(stale_params, write_buf, step, data)
          -> (write_buf', ScoreMetrics)
      master_step(params, opt_state, stale_params, read_buf, step, rng, data)
          -> (params', opt_state', stale_params', step + 1, rng', StepMetrics)

    With ``monitor_traces=False`` the scoring step skips the fig-4 trace
    psums and stays collective-free (NaN monitors); the master's metrics
    always carry NaN traces — AsyncPipeline merges the scoring step's in.

    With a non-empty ``monitors`` (telemetry.MonitorSet) the master step
    grows one trailing ``{name: scalar}`` output — proposal health measured
    on ``read_buf``, i.e. the lagged table the master actually sampled
    from, so the ``staleness`` monitor observes exactly the invariant's
    L(t).  ``master_step.with_monitors`` records the arity for drivers
    (capture it *before* jax.jit, which drops function attributes).

    With ``gated=True`` (mode="relaxed" only) the master step takes one
    extra trailing ``use_is`` device-bool — the adaptive controller's
    uniform↔IS gate, selected in-program so flips never recompile
    (``master_step.gated`` records the arity, also pre-jit).
    """
    if cfg.mode not in ("relaxed", "uniform"):
        raise ValueError(
            "async scoring supports mode='relaxed'/'uniform' (exact needs "
            "the fig-1 sync barrier; fused already merges the passes), got "
            f"{cfg.mode!r}")
    axes = tuple(axes)
    monitors = monitors or None
    scoring_pass = make_scoring_pass(scorer, cfg, num_examples,
                                     constrain_batch, axes)
    master_pass = make_master_pass(per_example_loss, optimizer, cfg,
                                   num_examples, aux_loss=aux_loss,
                                   constrain_batch=constrain_batch, axes=axes,
                                   model_axes=model_axes,
                                   param_pspecs=param_pspecs,
                                   monitors=monitors, gated=gated)
    sb = cfg.score_batch_size

    def scoring_step(stale_params, write_buf, step, data):
        store, fresh_scores, stale_slice = scoring_pass(
            stale_params, write_buf, step, data)
        smetrics = score_trace_metrics(fresh_scores, stale_slice, axes,
                                       n_total=sb, monitor=monitor_traces)
        return store, smetrics

    def _master_step(params, opt_state, stale_params, read_buf, step, rng,
                     data, use_is=None):
        rng, k_sample = jax.random.split(rng)
        params, opt_state, stale_params, _, metrics, *mon = master_pass(
            params, opt_state, stale_params, read_buf, step, k_sample, data,
            None, None, use_is)
        out = (params, opt_state, stale_params, step + 1, rng, metrics)
        return out + (mon[0],) if monitors else out

    if gated:
        def master_step(params, opt_state, stale_params, read_buf, step,
                        rng, data, use_is):
            return _master_step(params, opt_state, stale_params, read_buf,
                                step, rng, data, use_is)
    else:
        def master_step(params, opt_state, stale_params, read_buf, step,
                        rng, data):
            return _master_step(params, opt_state, stale_params, read_buf,
                                step, rng, data)

    master_step.with_monitors = bool(monitors)
    master_step.gated = bool(gated)
    return scoring_step, master_step


class AsyncPipeline:
    """Host-side driver: dispatches the fan-out and the master update as
    independent computations and runs the swap cadence.

    ``step(state, data)`` expects a TrainState whose ``store`` is a
    BufferedWeightStore (see ``init_async_state`` / ``to_buffered``).  The
    scoring step is dispatched first — fire and forget — then the master;
    async dispatch returns before either executes, and because the master's
    inputs never include write_buf the runtime can overlap the two.  Every
    ``swap_every`` steps the freshly written table is published to read_buf
    (the only sync point between the streams).

    A pipeline instance is per-run: the swap cadence rides on a host-side
    call counter (initialized from the first state's step), so driving a
    second, reset TrainState through the same instance phase-shifts the
    swaps when swap_every > 1.

    ``telemetry`` (telemetry.Telemetry) times each phase as a dispatch
    span — non-blocking by default, so instrumentation never re-serializes
    the scoring/master overlap — and emits a swap counter at the
    telemetry cadence.  When the master step was built with monitors, the
    trailing monitor dict lands on ``self.last_monitors`` (device arrays;
    the driver's logger fetches them).

    When the master step was built ``gated=True``, pass the adaptive
    ``controller`` (core/controller.ProposalController): its ``gate()``
    scalar is appended to every master dispatch, and the driver applies
    decided swap cadences by assigning ``pipe.swap_every`` (a host int,
    consulted fresh each step).
    """

    def __init__(self, scoring_step: Callable, master_step: Callable,
                 swap_every: int = 1, *, jit: bool = True,
                 donate: bool = True,
                 serve_tick: Optional[Callable] = None,
                 telemetry=None, controller=None):
        if swap_every < 1:
            raise ValueError(f"swap_every must be >= 1, got {swap_every}")
        # serve_tick(state) is interleaved between the scoring and master
        # dispatches: the serving loop decodes against its published param
        # snapshot in the window the two training programs overlap
        self.serve_tick = serve_tick
        # jax.jit drops function attributes — capture the arity first
        self._with_monitors = bool(getattr(master_step, "with_monitors",
                                           False))
        self._gated = bool(getattr(master_step, "gated", False))
        self.controller = controller
        if self._gated and controller is None:
            raise ValueError("master_step was built gated=True; pass the "
                             "controller= that owns its use_is gate")
        if jit:
            # donate write_buf: the table shard is updated in place
            scoring_step = jax.jit(
                scoring_step, donate_argnums=(1,) if donate else ())
            master_step = jax.jit(master_step)
        self._scoring = scoring_step
        self._master = master_step
        self.swap_every = int(swap_every)
        self._t: Optional[int] = None  # host-side step counter (swap cadence)
        if telemetry is None:
            from repro.telemetry import Telemetry
            telemetry = Telemetry.null()
        self.telemetry = telemetry
        self.swaps = 0                 # published tables over this run
        self.last_monitors: Optional[dict] = None

    def step(self, state: TrainState, data: dict
             ) -> tuple[TrainState, StepMetrics]:
        """One async step: dispatch the scoring fan-out (into write_buf)
        and the master update (sampling from read_buf) as independent
        computations, then swap the buffers every `swap_every` steps."""
        if self._t is None:
            self._t = int(state.step)   # one host sync, at startup only
        tel = self.telemetry
        bs: BufferedWeightStore = state.store
        write_buf, smetrics = tel.timed(
            "scoring.dispatch", self._scoring, state.stale_params,
            bs.write_buf, state.step, data, step=self._t)
        if self.serve_tick is not None:
            with tel.span("serve.tick", step=self._t):
                self.serve_tick(state)
        margs = (state.params, state.opt_state, state.stale_params,
                 bs.read_buf, state.step, state.rng, data)
        if self._gated:
            margs += (self.controller.gate(),)
        out = tel.timed("master.dispatch", self._master, *margs, step=self._t)
        if self._with_monitors:
            params, opt_state, stale_params, step, rng, metrics, mon = out
            self.last_monitors = mon
        else:
            params, opt_state, stale_params, step, rng, metrics = out
        self._t += 1
        bs = BufferedWeightStore(bs.read_buf, write_buf, bs.synced_at)
        if self._t % self.swap_every == 0:
            # stamp with the device-side step (the writes just published run
            # through state.step) — correct even if the pipeline is reused
            # with a fresh TrainState; only the swap *cadence* rides on the
            # host counter, which is why a pipeline instance is per-run.
            with tel.span("store.publish", step=self._t):
                bs = publish(bs, state.step)
            self.swaps += 1
        if tel.due(self._t):
            tel.counter("store.swaps", self.swaps, step=self._t)
        metrics = metrics._replace(trace_ideal=smetrics.trace_ideal,
                                   trace_stale=smetrics.trace_stale,
                                   trace_unif=smetrics.trace_unif)
        new_state = TrainState(params, opt_state, stale_params, bs, step, rng)
        return new_state, metrics


def make_async_pipeline(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer: Optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    swap_every: int = 1,
    aux_loss: Optional[Callable] = None,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
    monitor_traces: bool = True,
    jit: bool = True,
) -> AsyncPipeline:
    """Single-call constructor for the (single-device) async pipeline."""
    scoring_step, master_step = make_async_steps(
        per_example_loss, scorer, optimizer, cfg, num_examples,
        aux_loss=aux_loss, constrain_batch=constrain_batch, axes=axes,
        monitor_traces=monitor_traces)
    return AsyncPipeline(scoring_step, master_step, swap_every, jit=jit)


def init_async_state(params, optimizer: Optimizer, num_examples: int,
                     seed: int = 0) -> TrainState:
    """TrainState for the async pipeline: plain init with the store wrapped
    into a BufferedWeightStore (both buffers cold)."""
    state = init_train_state(params, optimizer, num_examples, seed=seed)
    return state._replace(store=to_buffered(state.store))

"""Asynchronous SGD baseline + the paper's §6 combination proposal.

The paper compares ISSGD conceptually against ASGD but ships no ASGD
implementation ("we are not currently in possession of a good
production-quality ASGD implementation").  We provide one — in the same
deterministic-staleness style as the rest of this repo — and the §6
recommendation: drop the master/worker distinction, have every peer push
gradients AND importance weights, so all peers run ISSGD steps.

Simulation model (bulk-synchronous emulation of asynchrony, like the
ISSGD runtime): gradients applied at step t were computed on parameters
from step t−delay (a FIFO of parameter snapshots).  delay=0 recovers
synchronous SGD exactly.

Modes:
  uniform     plain ASGD: uniform minibatches, stale gradients
  issgd       §6 combination: minibatches sampled from the shared weight
              store, IS-scaled unbiased-at-stale-params gradients, and the
              peer's fused scores pushed back to the store
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.importance import ISConfig, is_loss_scale
from repro.core.sampler import sample_indices
from repro.core.weight_store import (WeightStore, init_store, read_proposal,
                                     write_scores)
from repro.data.pipeline import gather_batch
from repro.optim import Optimizer, global_norm


@dataclasses.dataclass(frozen=True)
class ASGDConfig:
    """Knobs of the delayed-gradient ASGD baseline (paper §6 comparison)."""
    batch_size: int = 64
    delay: int = 4                  # gradient staleness in steps
    mode: str = "uniform"           # uniform | issgd
    is_cfg: ISConfig = ISConfig()


class ASGDState(NamedTuple):
    """Train state with the FIFO of delayed parameter snapshots."""
    params: Any
    opt_state: Any
    fifo: Any                       # stacked (delay+1, ...) param snapshots
    store: WeightStore
    step: jax.Array
    rng: jax.Array


class ASGDMetrics(NamedTuple):
    """Per-step monitors: loss, grad norm, and the staleness gap."""
    loss: jax.Array
    grad_norm: jax.Array
    delay_gap: jax.Array            # ||θ_t − θ_{t−delay}|| (staleness size)


def init_asgd_state(params, optimizer: Optimizer, cfg: ASGDConfig,
                    num_examples: int, seed: int = 0) -> ASGDState:
    """Fresh ASGDState: the snapshot FIFO starts as delay+1 copies of θ₀."""
    fifo = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.delay + 1,) + x.shape),
        params)
    return ASGDState(params=params, opt_state=optimizer.init(params),
                     fifo=fifo, store=init_store(num_examples),
                     step=jnp.zeros((), jnp.int32), rng=jax.random.key(seed))


def make_asgd_step(
    per_example_loss: Callable,                  # (params, batch) -> (B,)
    optimizer: Optimizer,
    cfg: ASGDConfig,
    num_examples: int,
    fused_score: Optional[Callable] = None,      # for mode="issgd"
) -> Callable:
    """Build the delayed-gradient step: the update applied at step t was
    computed on the parameters of step t − delay (the FIFO head); replicated
    single-device semantics, used by benchmarks/asgd_comparison.py."""
    n = num_examples
    if cfg.mode == "issgd" and fused_score is None:
        raise ValueError("mode='issgd' requires fused_score")

    def asgd_step(state: ASGDState, data: dict) -> tuple[ASGDState, ASGDMetrics]:
        rng, k_sample = jax.random.split(state.rng)
        step = state.step
        # the peer computes on delay-old parameters (FIFO head)
        delayed = jax.tree.map(lambda b: b[0], state.fifo)

        if cfg.mode == "issgd":
            proposal = read_proposal(state.store, step, cfg.is_cfg)
            idx = sample_indices(k_sample, proposal, cfg.batch_size)
            scales = is_loss_scale(proposal[idx], jnp.mean(proposal))
        else:
            idx = jax.random.randint(k_sample, (cfg.batch_size,), 0, n)
            scales = jnp.ones((cfg.batch_size,), jnp.float32)
        batch = gather_batch(data, idx)

        def loss_fn(p):
            if cfg.mode == "issgd":
                losses, scores = fused_score(p, batch)
                scores = jax.lax.stop_gradient(scores)
            else:
                losses, scores = per_example_loss(p, batch), None
            return jnp.mean(losses * scales), scores

        # the STALE gradient: evaluated at θ_{t−delay}, applied at θ_t
        (loss, scores), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(delayed)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params, step)

        store = state.store
        if cfg.mode == "issgd":
            # the peer shares its importance weights like its gradients (§6)
            store = write_scores(store, idx, scores, step)

        # advance the staleness FIFO: drop oldest, append fresh params
        fifo = jax.tree.map(
            lambda buf, new: jnp.concatenate([buf[1:], new[None]], axis=0),
            state.fifo, params)

        gap = global_norm(jax.tree.map(lambda a, b: a - b, state.params,
                                       delayed))
        metrics = ASGDMetrics(loss=loss, grad_norm=global_norm(grads),
                              delay_gap=gap)
        return ASGDState(params, opt_state, fifo, store, step + 1,
                         rng), metrics

    return asgd_step

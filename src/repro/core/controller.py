"""Adaptive IS controller: decides *when importance sampling pays* and
*how often to swap* — purely from the PR 8 telemetry stream.

The controller never grows its own probes.  It taps the run's
:class:`~repro.telemetry.events.EventSink` (``attach`` wraps the sink;
every record still lands in the file) and folds exactly the values the
JSONL carries:

* ``metrics`` records → the variance-ratio gate.  The in-step traces
  give √TrΣ under the uniform estimator (``trace_unif``) and under the
  current stale proposal (``trace_stale``); when their ratio clears
  ``var_margin`` (and ``ess_frac`` stays above ``ess_floor``), switching
  the sampler from uniform to IS is predicted to *reduce* gradient
  variance — the Katharopoulos & Fleuret "is IS worth it yet?" test.
  The gate starts closed (uniform), matching their recipe.
* ``span`` records → swap-cadence selection.  The scoring/master
  dispatch-time ratio says how many master steps one scoring fan-out
  costs; K = clip(round(ratio), kmin, kmax) keeps the async pipeline's
  scoring fan-out off the master's critical path.

The gate itself is a device scalar (`gate()`), consumed by step
functions built with ``gated=True`` (see `core/issgd.py`): flipping it
never recompiles, and a never-opening gate is bitwise a plain
uniform-mode run (pinned in tests/test_controller.py).

Because the controller observes the post-serialization values (spans
after their 6-digit rounding, fields after JSON normalization), every
decision is an exact pure fold over the event stream:
:func:`replay_decisions` re-derives the in-run decisions bit-for-bit
from the JSONL alone.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, NamedTuple, Optional

from repro.telemetry.events import _jsonable

#: Event kinds the controller emits into the stream it taps.
CONFIG_KIND = "controller.config"
DECISION_KIND = "controller.decision"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Decision-rule parameters (all serialized into the
    ``controller.config`` record so offline replay is self-contained).

    ``adapt_every``: steps between decisions.  ``var_margin``: the gate
    opens when mean(trace_unif)/mean(trace_stale) over the window
    exceeds this (1.0 = any predicted reduction; >1 demands margin).
    ``ess_floor``: with a positive floor, an observed ``ess_frac`` below
    it vetoes the gate (a collapsed proposal makes the IS estimate
    high-variance even when the trace ratio looks good).
    ``hysteresis``: consecutive disagreeing decisions required before
    the gate actually flips.  ``adapt_swap`` + ``swap_min``/``swap_max``
    control cadence selection from the dispatch-time ratio.
    """
    adapt_every: int = 25
    var_margin: float = 1.0
    ess_floor: float = 0.0
    hysteresis: int = 1
    adapt_swap: bool = False
    swap_min: int = 1
    swap_max: int = 8


class Decision(NamedTuple):
    """One controller decision, mirroring the ``controller.decision``
    record field-for-field (None ↔ JSON null for unobserved inputs)."""
    step: int
    use_is: bool
    swap_every: int
    var_ratio: Optional[float]
    dispatch_ratio: Optional[float]
    ess: Optional[float]
    reason: str


def _is_finite_number(x) -> bool:
    """True for real finite int/float (rejects None, NaN, bool, str)."""
    return (isinstance(x, (int, float)) and not isinstance(x, bool)
            and x == x and x not in (float("inf"), float("-inf")))


class ProposalController:
    """Online uniform↔IS gate + swap-cadence selector over a tapped sink.

    Usage::

        ctl = ProposalController(ControllerConfig(...), swap_every=K)
        sink = ctl.attach(EventSink(path))     # wrap the run's sink
        step = make_train_step(..., gated=True)
        ...
        st, m = step(st, data, ctl.gate())     # gate as a device scalar
        ...                                    # emit metrics as usual
        d = ctl.maybe_decide(i)                # decision cadence
        if d is not None: pipe.swap_every = d.swap_every

    State folds only values that went through the tap, so
    :func:`replay_decisions` over the resulting JSONL reproduces
    ``self.decisions`` exactly.
    """

    def __init__(self, cfg: ControllerConfig = ControllerConfig(), *,
                 swap_every: int = 1, use_is: bool = False):
        if cfg.adapt_every < 1:
            raise ValueError("adapt_every must be >= 1")
        self.cfg = cfg
        self.use_is = bool(use_is)
        self.swap_every = int(swap_every)
        self.decisions: List[Decision] = []
        self._sink = None
        self._streak = 0
        self._gate = None
        self._gate_val = None
        self._reset_window()

    # ----------------------------------------------------------- plumbing
    def _reset_window(self) -> None:
        self._stale_sum = 0.0
        self._unif_sum = 0.0
        self._pairs = 0
        self._ess = None
        self._score_s = 0.0
        self._score_n = 0
        self._master_s = 0.0
        self._master_n = 0

    def attach(self, sink):
        """Wrap ``sink`` in a :class:`ControllerTap` and emit the
        ``controller.config`` record.  Returns the tap — use it as the
        run's sink from here on."""
        tap = ControllerTap(sink, self)
        self._sink = tap
        tap.emit(CONFIG_KIND, **dataclasses.asdict(self.cfg),
                 swap_every=self.swap_every, use_is=self.use_is)
        return tap

    def gate(self):
        """The current gate as a device bool scalar (cached per value, so
        repeated calls between decisions reuse one transfer)."""
        if self._gate_val is not self.use_is:
            import jax.numpy as jnp
            self._gate = jnp.asarray(self.use_is)
            self._gate_val = self.use_is
        return self._gate

    # -------------------------------------------------------- observation
    def observe_event(self, kind: str, step, fields: dict) -> None:
        """Fold one event record into the decision window.  Only
        ``metrics`` (traces + ess) and ``span`` (dispatch times) move
        state; everything else — including the controller's own
        records — is ignored."""
        if kind == "metrics":
            s, u = fields.get("trace_stale"), fields.get("trace_unif")
            if (_is_finite_number(s) and _is_finite_number(u)
                    and s > 0.0 and u > 0.0):
                self._stale_sum += s
                self._unif_sum += u
                self._pairs += 1
            e = fields.get("ess_frac")
            if _is_finite_number(e):
                self._ess = float(e)
        elif kind == "span":
            name, d = fields.get("name"), fields.get("dur_s")
            if not _is_finite_number(d):
                return
            if name == "scoring.dispatch":
                self._score_s += d
                self._score_n += 1
            elif name == "master.dispatch":
                self._master_s += d
                self._master_n += 1

    # ----------------------------------------------------------- decision
    def maybe_decide(self, step: int) -> Optional[Decision]:
        """Decide at the configured cadence: a decision fires when
        ``(step + 1) % adapt_every == 0`` (i.e. after the window's last
        step has emitted), else returns None."""
        if (step + 1) % self.cfg.adapt_every != 0:
            return None
        return self._decide(step)

    def _decide(self, step: int) -> Decision:
        cfg = self.cfg
        var_ratio = (self._unif_sum / self._stale_sum
                     if self._pairs else None)
        dispatch_ratio = (self._score_s / self._master_s
                          if self._score_n and self._master_n
                          and self._master_s > 0.0 else None)
        ess = self._ess

        if var_ratio is None:
            want, reason = self.use_is, "no-signal"
        elif cfg.ess_floor > 0.0 and ess is not None and ess < cfg.ess_floor:
            want, reason = False, "ess-floor"
        elif var_ratio > cfg.var_margin:
            want, reason = True, "is-pays"
        else:
            want, reason = False, "uniform-pays"

        if want != self.use_is:
            self._streak += 1
            if self._streak >= cfg.hysteresis:
                self.use_is = want
                self._streak = 0
            else:
                reason += "-pending"
        else:
            self._streak = 0

        if cfg.adapt_swap and dispatch_ratio is not None:
            self.swap_every = min(max(int(round(dispatch_ratio)),
                                      cfg.swap_min), cfg.swap_max)

        d = Decision(step=int(step), use_is=self.use_is,
                     swap_every=self.swap_every, var_ratio=var_ratio,
                     dispatch_ratio=dispatch_ratio, ess=ess, reason=reason)
        self.decisions.append(d)
        self._reset_window()
        if self._sink is not None:
            self._sink.emit(DECISION_KIND, step=d.step,
                            **{k: v for k, v in d._asdict().items()
                               if k != "step"})
        return d


class ControllerTap:
    """Sink wrapper feeding the controller the exact serialized values.

    Every record is JSON-normalized *first* (``_jsonable`` on fields,
    span durations after their 6-digit rounding), observed by the
    controller, then forwarded to the wrapped sink — so the controller's
    in-run inputs are bit-for-bit the JSONL contents, the contract
    behind :func:`replay_decisions`.  Always truthy, even over a
    :class:`~repro.telemetry.events.NullSink`, so drivers keep emitting
    the metrics/spans the controller feeds on.
    """

    def __init__(self, inner, controller: ProposalController):
        self._inner = inner
        self._ctl = controller

    @property
    def path(self):
        """Pass-through to the wrapped sink's output path."""
        return self._inner.path

    def emit(self, kind: str, step=None, **fields) -> None:
        """Normalize, observe, forward."""
        norm = {k: _jsonable(v) for k, v in fields.items()}
        self._ctl.observe_event(kind, step, norm)
        self._inner.emit(kind, step=step, **norm)

    def span(self, name: str, dur_s: float, step=None) -> None:
        """Span shorthand, rounding like ``EventSink.span`` before the
        controller sees the duration."""
        self.emit("span", step=step, name=name, dur_s=round(dur_s, 6))

    def counter(self, name: str, value, step=None) -> None:
        """Counter shorthand mirroring ``EventSink.counter``."""
        self.emit("counter", step=step, name=name, value=value)

    def flush(self) -> None:
        """Pass-through flush."""
        self._inner.flush()

    def close(self) -> None:
        """Pass-through close."""
        self._inner.close()

    def __bool__(self) -> bool:
        return True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def replay_decisions(events: Iterable[dict], *,
                     strict: bool = True) -> List[Decision]:
    """Recompute controller decisions offline from an event stream.

    Feed it :func:`repro.telemetry.events.read_events` output: the
    ``controller.config`` record seeds a fresh controller, every other
    record is folded through the same ``observe_event``, and at each
    recorded ``controller.decision`` the rule is re-run.  With
    ``strict`` (default) any disagreement between a recomputed decision
    and the recorded one raises — the exact-replay contract pinned in
    tests/test_controller.py.
    """
    ctl: Optional[ProposalController] = None
    out: List[Decision] = []
    cfg_fields = {f.name for f in dataclasses.fields(ControllerConfig)}
    for rec in events:
        kind = rec.get("kind")
        if kind == CONFIG_KIND:
            cfg = ControllerConfig(**{k: rec[k] for k in cfg_fields
                                      if k in rec})
            ctl = ProposalController(cfg, swap_every=rec.get("swap_every", 1),
                                     use_is=rec.get("use_is", False))
        elif kind == DECISION_KIND:
            if ctl is None:
                raise ValueError("controller.decision before "
                                 "controller.config in event stream")
            d = ctl._decide(rec["step"])
            if strict:
                recorded = Decision(
                    step=rec["step"], use_is=rec["use_is"],
                    swap_every=rec["swap_every"],
                    var_ratio=rec.get("var_ratio"),
                    dispatch_ratio=rec.get("dispatch_ratio"),
                    ess=rec.get("ess"), reason=rec["reason"])
                if d != recorded:
                    raise ValueError(
                        f"replay mismatch at step {rec['step']}: "
                        f"recomputed {d} != recorded {recorded}")
            out.append(d)
        elif ctl is not None:
            ctl.observe_event(kind, rec.get("step"), rec)
    return out

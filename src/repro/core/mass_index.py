"""Hierarchical chunk-level mass index — the billion-example stage-1.

The two-stage draw (core/sampler.py) needs, per step, the mass of every
stage-1 block of the proposal.  The dense path recomputes all of them
with one O(n_local) reduction per draw.  This module maintains the same
masses *incrementally* at the chunk granularity the streaming plane
already tracks (data/store.py chunks):

  * ``chunk_masses`` / ``block_masses`` — the canonical leaf reduction.
    One XLA ``sum`` over each fixed-size chunk row, bitwise-identical to
    the reduction inside ``sampler.chunk_proposal_mass`` and
    ``sampler.two_stage_sample``'s stage-1.  That shared reduction is
    the exactness contract: a maintained leaf always equals the fresh
    dense leaf bit for bit (pinned by the hypothesis battery in
    tests/test_mass_index.py).
  * ``MassIndex`` — leaves + a perfect binary segment tree of pairwise
    sums.  ``refresh_chunks`` recomputes only the touched leaves (again
    with the canonical reduction) and their O(log C) ancestor paths, so
    a B-row score write costs O(B·chunk_size + B·log C) instead of a
    full per-shard rebuild.  Ancestors are recomputed from their
    children — never delta-adjusted — so ``refresh_chunks`` is
    *bitwise* equal to ``build_index`` on the updated table (also
    property-pinned).
  * ``sample_chunks`` — O(log C) root-to-leaf descent resolving a
    uniform draw to its chunk; ``indexed_sample`` composes it with the
    unchanged within-chunk stage-2 for a full O(M·(log C + chunk_size))
    draw that never materializes a table-sized CDF.

Inside the training step, ``--index tree`` routes stage-1 through
``block_masses`` at the configured W granularity (see
``issgd.make_master_pass``): because the leaf reduction is the dense
reduction, tree-mode draws are bitwise-equal to dense-mode draws — the
acceptance pin of ISSUE 10.  The incremental ``refresh_chunks`` /
``sample_chunks`` machinery is what `benchmarks/sampling_scale.py`
measures and what a host-side index maintainer uses.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _num_chunks(n: int, chunk_size: int) -> int:
    """Chunk count covering n rows, trailing partial chunk included."""
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return -(-n // chunk_size)


def _pad_to_chunks(table: jax.Array, chunk_size: int) -> jax.Array:
    """Zero-pad the table so it reshapes into whole chunks (the trailing
    partial chunk contributes exactly its partial mass)."""
    n = table.shape[0]
    chunks = _num_chunks(n, chunk_size)
    pad = chunks * chunk_size - n
    if pad:
        table = jnp.concatenate(
            [table, jnp.zeros((pad,), table.dtype)])
    return table


def chunk_masses(table: jax.Array, chunk_size: int) -> jax.Array:
    """Per-chunk mass of a (shard-local) table: the canonical leaf
    reduction — ``sum`` along the minor chunk axis, the same reduction
    ``sampler.chunk_proposal_mass`` performs, so the two agree bitwise."""
    padded = _pad_to_chunks(table, chunk_size)
    return jnp.sum(padded.reshape(-1, chunk_size), axis=1)


def block_masses(table: jax.Array, num_blocks: int) -> jax.Array:
    """Stage-1 masses at the W-block granularity of the two-stage draw:
    ``sum`` over each of ``num_blocks`` equal contiguous blocks — the
    *identical* reduction ``two_stage_sample`` computes internally, so
    feeding these back as ``block_sums`` reproduces its draws bitwise."""
    n = table.shape[0]
    if n % num_blocks:
        raise ValueError(f"table size {n} not divisible by "
                         f"{num_blocks} blocks")
    ctype = jnp.float64 if table.dtype == jnp.float64 else jnp.float32
    return jnp.sum(table.astype(ctype).reshape(num_blocks, -1), axis=1)


class MassIndex(NamedTuple):
    """Chunk-mass leaves + a perfect binary segment tree over them.

    ``tree`` is the classic 1-indexed layout over ``P = next_pow2(C)``
    padded leaves: node ``i`` has children ``2i``/``2i+1``, leaves live
    at ``P .. P+C-1``, ``tree[1]`` is the total mass.  Every interior
    node is exactly the pairwise sum of its children, which makes
    incremental refresh bitwise-equal to a full rebuild."""
    mass: jax.Array   # f32[C]  leaf chunk masses (trailing chunk partial)
    tree: jax.Array   # f32[2P] segment tree; tree[0] unused


def _leaf_base(num_chunks: int) -> int:
    """P: the power-of-two leaf span of the tree for C chunks."""
    return 1 << max(num_chunks - 1, 1).bit_length() if num_chunks > 1 else 1


def tree_from_masses(mass: jax.Array) -> jax.Array:
    """Build the segment tree bottom-up from leaf masses: O(C) pairwise
    sums, log C levels."""
    c = mass.shape[0]
    p = _leaf_base(c)
    leaves = jnp.zeros((p,), mass.dtype).at[:c].set(mass)
    levels = [leaves]
    while levels[-1].shape[0] > 1:
        lvl = levels[-1].reshape(-1, 2)
        levels.append(lvl[:, 0] + lvl[:, 1])
    # concatenate root-first: tree[1]=root, then level of 2, 4, ... P
    tree = jnp.concatenate([jnp.zeros((1,), mass.dtype)]
                           + [lvl for lvl in reversed(levels)])
    return tree


def build_index(table: jax.Array, chunk_size: int) -> MassIndex:
    """Index a table from scratch: canonical leaf reduction + tree build."""
    mass = chunk_masses(table.astype(jnp.float32), chunk_size)
    return MassIndex(mass=mass, tree=tree_from_masses(mass))


def total_mass(index: MassIndex) -> jax.Array:
    """The root: total proposal mass over all chunks."""
    return index.tree[1]


def refresh_chunks(index: MassIndex, table: jax.Array, chunk_size: int,
                   chunk_ids: jax.Array) -> MassIndex:
    """Recompute the leaves for ``chunk_ids`` from the (already updated)
    table and propagate up the tree: O(B·chunk_size + B·log C).

    Leaves are recomputed with the canonical reduction (never
    delta-adjusted) and every touched ancestor is recomputed from its
    two children, so the result is bitwise ``build_index(table)`` —
    the property test's refresh≡rebuild pin.  Duplicate chunk ids are
    harmless (same value written)."""
    c = index.mass.shape[0]
    p = _leaf_base(c)
    chunk_ids = jnp.clip(jnp.asarray(chunk_ids, jnp.int32), 0, c - 1)
    padded = _pad_to_chunks(table.astype(jnp.float32), chunk_size)
    rows = padded.reshape(-1, chunk_size)[chunk_ids]      # (B, chunk_size)
    fresh = jnp.sum(rows, axis=1)                         # canonical reduction
    mass = index.mass.at[chunk_ids].set(fresh)
    tree = index.tree.at[p + chunk_ids].set(fresh)
    node = p + chunk_ids
    while p > 1:
        node = node // 2
        p //= 2
        tree = tree.at[node].set(tree[2 * node] + tree[2 * node + 1])
    return MassIndex(mass=mass, tree=tree)


def sample_chunks(index: MassIndex, u: jax.Array) -> jax.Array:
    """Resolve uniform draws ``u`` in [0, total) to chunk ids by O(log C)
    root-to-leaf descent: at each node go left if the draw lands in the
    left child's mass, else subtract it and go right — the tree *is* the
    CDF, no cumsum over chunks is ever formed."""
    c = index.mass.shape[0]
    p = _leaf_base(c)
    node = jnp.ones(u.shape, jnp.int32)
    rem = u
    while p > 1:
        left = index.tree[2 * node]
        go_right = rem >= left
        rem = jnp.where(go_right, rem - left, rem)
        node = 2 * node + go_right.astype(jnp.int32)
        p //= 2
    return jnp.clip(node - _leaf_base(c), 0, c - 1)


def indexed_sample(key: jax.Array, table: jax.Array, index: MassIndex,
                   chunk_size: int, num_samples: int) -> jax.Array:
    """Full two-stage draw through the index: O(log C) chunk descent per
    draw, then the unchanged within-chunk stage-2 (a cumsum over the M
    winning chunks' rows only — never a table-sized CDF)."""
    total = total_mass(index)
    u = jax.random.uniform(key, (num_samples,), jnp.float32) * total
    chunk = sample_chunks(index, u)
    # residual mass inside the winning chunk = u - mass of all chunks
    # before it; recover it from the descent by re-walking prefix sums
    # cheaply: prefix(chunk) via the tree in O(log C).
    rem = u - _prefix_mass(index, chunk)
    padded = _pad_to_chunks(table.astype(jnp.float32), chunk_size)
    rows = padded.reshape(-1, chunk_size)[chunk]          # (M, chunk_size)
    cdf = jnp.cumsum(rows, axis=1)
    pos = jnp.sum((cdf <= rem[:, None]).astype(jnp.int32), axis=1)
    pos = jnp.clip(pos, 0, chunk_size - 1)
    gidx = chunk * chunk_size + pos
    return jnp.clip(gidx, 0, table.shape[0] - 1).astype(jnp.int32)


def _prefix_mass(index: MassIndex, chunk: jax.Array) -> jax.Array:
    """Mass of all chunks strictly before ``chunk``: descend the tree
    accumulating left-child masses wherever the path goes right —
    O(log C), the exact pairwise sums the descent itself subtracts."""
    c = index.mass.shape[0]
    p = _leaf_base(c)
    target = chunk + p
    node = jnp.ones(chunk.shape, jnp.int32)
    acc = jnp.zeros(chunk.shape, jnp.float32)
    depth = p
    while depth > 1:
        depth //= 2
        went_right = (target // depth) % 2 == 1
        acc = acc + jnp.where(went_right, index.tree[2 * node],
                              jnp.zeros_like(acc))
        node = 2 * node + went_right.astype(jnp.int32)
    return acc

"""Per-example gradient-norm scoring — the paper's ω̃_n = ||g(x_n)||₂.

Strategies (config `score_strategy`):

  loss        ω̃_n = L(x_n).  Cheapest (forward only); a curriculum-style
              heuristic, not the optimal proposal.  Baseline for ablations.
  logit_grad  ω̃_n = ||∂L_n/∂logits||₂ in closed form from the forward pass
              (softmax CE ⇒ p − onehot).  Forward-only.  The "cheap
              approximation" family the paper's §6 anticipates; the standard
              EL2N-style proxy of the full gradient norm.
  ghost       EXACT ||∇_θ L_n||₂ over every tapped linear (paper Prop. 1 via
              the per_example_sqnorm kernel for rank-1 layers, plus our
              ghost-norm extension for sequence-shared layers).  One forward
              + one backward, no per-example gradient materialization.
  ghost_rev   same quantity, computed with a manual reverse scan over the
              layer periods: stores only the P period-boundary activations
              plus ONE period's records/cotangents at a time (vs ghost's
              all-layer records) — the memory-scalable exact scorer.
  full        vmap-of-grad oracle.  O(B·|θ|) memory — tests only.

All strategies return ω̃ ≥ 0 of shape (B,) in float32.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops

STRATEGIES = ("loss", "logit_grad", "ghost", "ghost_rev", "full")


# --------------------------------------------------------------- ghost core
def _contribution(x: jax.Array, dt: jax.Array, batch: int,
                  with_bias: bool, scanned: bool) -> jax.Array:
    """Squared per-example grad-norm contribution of one tapped linear.

    `scanned` declares whether the arrays carry a leading period axis (the
    scan-stacked records); never guessed from shapes — a (P, B*S, d)
    token-flattened record is shape-ambiguous with (B, S, d) when P == B.

    Shapes handled:
      not scanned: (B, d) rank-1 (paper Prop. 1) | (B, S, d) ghost ext.
      scanned:     (P, B, S, d) | (P, B*S, d) token-flattened (MoE router)
    """
    if not scanned:
        if x.ndim == 2:
            return ops.per_example_sqnorm(x, dt, with_bias=with_bias)
        return ops.ghost_norm(x, dt)
    if x.ndim == 3:  # (P, B*S, d) token-flattened inside scan
        p = x.shape[0]
        s = x.shape[1] // batch
        x = x.reshape(p, batch, s, x.shape[-1])
        dt = dt.reshape(p, batch, s, dt.shape[-1])
    # (P, B, S, d): every (period, example) row is an independent layer copy
    p, b = x.shape[:2]
    r = ops.ghost_norm(x.reshape(p * b, *x.shape[2:]),
                       dt.reshape(p * b, *dt.shape[2:]))
    return jnp.sum(r.reshape(p, b), axis=0)


def ghost_sq_norms(
    loss_with_taps: Callable,
    tap_shapes: dict,
    batch: int,
    scanned_names: Optional[set] = None,
    with_bias: bool = False,
    model_axes: tuple[str, ...] = (),
    sharded_names: Optional[set] = None,
) -> tuple[jax.Array, jax.Array]:
    """Exact per-example squared grad-norms via the tap trick.

    loss_with_taps(taps) -> (per_example_losses (B,), records dict) where
    records[name] is the INPUT of the linear whose output tap is taps[name].
    `scanned_names`: which records carry a leading period axis (default:
    every name except "unembed" — the transformer convention).

    With ``model_axes`` set (model-parallel params inside shard_map), the
    taps of column-sharded layers carry this device's dY column slice, so
    their contributions are partial sums over the model axis; the names in
    ``sharded_names`` are summed as-is, contributions of replicated layers
    (computed redundantly on every model device) are pre-divided by the
    model-axis size, and the total is psum-reduced over ``model_axes``
    into the exact per-example grad-norm — replicated, so every model
    replica writes identical proposal weights into the store.

    Two fused-kernel fast paths ride on the record walk:
      * names ending in ``.qkv_scores`` are SCORE taps — their cotangent
        already IS the finished (B,)/(P,B) per-example score emitted by
        the flash-attention backward epilogue (see models/attention.attn),
        so it is summed in directly (no Prop.-1 kernel call);
      * consecutive runs of rank-1 (2-D, unscanned) taps with the same
        model-axis scaling class are batched through
        `ops.per_example_sqnorm_multi` — one grid sweep instead of one
        kernel launch per tapped linear.

    Returns (sq_norms (B,), per_example_losses (B,)).
    """
    from repro.core.collectives import axis_info, psum
    taps0 = {k: jnp.zeros(v.shape, v.dtype) for k, v in tap_shapes.items()}

    def f(taps):
        losses, records = loss_with_taps(taps)
        return jnp.sum(losses), (losses, records)

    _, pull, (losses, records) = jax.vjp(f, taps0, has_aux=True)
    (dtaps,) = pull(jnp.ones((), jnp.float32))

    _, n_model = axis_info(tuple(model_axes))
    sq = jnp.zeros((batch,), jnp.float32)
    group_x: list = []
    group_d: list = []
    group_div = False

    def _flush(sq):
        nonlocal group_x, group_d, group_div
        if not group_x:
            return sq
        if len(group_x) == 1:
            contrib = ops.per_example_sqnorm(group_x[0], group_d[0],
                                             with_bias=with_bias)
        else:
            contrib = ops.per_example_sqnorm_multi(
                tuple(group_x), tuple(group_d), with_bias=with_bias)
        if group_div:
            contrib = contrib / n_model  # replicated layers: counted once
        group_x, group_d, group_div = [], [], False
        return sq + contrib

    for name, x in records.items():
        if name not in dtaps:
            continue
        scanned = (name in scanned_names) if scanned_names is not None \
            else (name != "unembed")
        dt = dtaps[name]
        divide = bool(model_axes) and name not in (sharded_names or ())
        if name.endswith(".qkv_scores"):
            sq = _flush(sq)
            contrib = dt.astype(jnp.float32)
            if scanned:  # (P, B) stacked over scan periods
                contrib = jnp.sum(contrib, axis=0)
            if divide:
                contrib = contrib / n_model
            sq = sq + contrib
            continue
        if not scanned and x.ndim == 2:  # rank-1 tap: groupable
            if group_x and group_div != divide:
                sq = _flush(sq)
            group_x.append(x)
            group_d.append(dt)
            group_div = divide
            continue
        sq = _flush(sq)
        contrib = _contribution(x, dt, batch, with_bias, scanned)
        if divide:
            contrib = contrib / n_model
        sq = sq + contrib
    sq = _flush(sq)
    return psum(sq, tuple(model_axes)), losses


# ----------------------------------------------------------- LM strategies
def make_lm_scorer(cfg, strategy: str, ssm_mode: str = "ref",
                   model_axes: tuple[str, ...] = (),
                   seq_shard: bool = False,
                   attn_impl: str = "ref",
                   attn_scores: Optional[str] = None) -> Callable:
    """Scorer for transformer LMs.  Returns fn(params, batch) -> ω̃ (B,).

    With ``model_axes`` set the returned scorer expects model-axis-sharded
    params inside shard_map (head/ffn/channel shards, see
    models/transformer.forward).  Gradient-norm strategies compute
    per-example partial squared norms from the local dY slices of the
    sharded layers (`sharded_tap_names` classifies which taps are partial
    vs replicated) and psum them over the model axes, so the proposal ω̃
    is exact and replicated across model devices; forward-only strategies
    (loss / logit_grad) read the gathered replicated logits and need no
    reduction.  ``seq_shard`` threads sequence parallelism through the
    forward.  The `full` vmap-of-grad oracle is single-device-only.

    ``attn_impl`` selects the attention path ("ref" chunked-jnp, "flash"
    trainable Pallas kernel).  ``attn_scores`` ("fused"/"separate",
    ghost/ghost_rev with attn_impl="flash" only) swaps each attention
    layer's wq/wk/wv ghost Gram terms for the flash-backward score tap
    ||dQ||²+||dK||²+||dV||² at the attention interface — an EL2N-style
    proxy of those three terms at near-zero extra cost ("fused" reads it
    from the backward kernel epilogue; "separate" re-reads the gradients
    from HBM, the bitwise-pinned reference).  The resulting ω̃ is NO
    LONGER the exact full-parameter grad-norm; all other layers' terms
    stay exact.
    """
    from repro.models.transformer import (per_example_loss,
                                          sharded_tap_names,
                                          tap_structure,
                                          tap_structure_from_params)
    model_axes = tuple(model_axes)
    if attn_scores is not None:
        if attn_scores not in ("fused", "separate"):
            raise ValueError(f"attn_scores must be 'fused', 'separate' or "
                             f"None, got {attn_scores!r}")
        if strategy not in ("ghost", "ghost_rev"):
            raise ValueError(
                f"attn_scores={attn_scores!r} modifies the ghost-tap walk; "
                f"it has no effect on strategy {strategy!r} — use 'ghost' "
                f"or 'ghost_rev'")
        if attn_impl != "flash":
            raise ValueError(
                f"attn_scores={attn_scores!r} needs the trainable flash "
                f"kernel (attn_impl='flash'), got attn_impl={attn_impl!r}")
        if cfg.attention == "mla":
            raise ValueError("attn_scores is a GQA flash-kernel feature; "
                             "attention='mla' has no flash backward")

    if strategy == "loss":
        def score(params, batch):
            losses, _ = per_example_loss(params, cfg, batch,
                                         ssm_mode=ssm_mode,
                                         model_axes=model_axes,
                                         seq_shard=seq_shard)
            return jnp.maximum(losses.astype(jnp.float32), 0.0)
        return score

    if strategy == "logit_grad":
        from repro.models.transformer import forward, lm_head_metrics

        def score(params, batch):
            tokens = batch["tokens"]
            embeds = batch.get("embeds")
            n_front = embeds.shape[1] if embeds is not None else 0
            h, _ = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                           ssm_mode=ssm_mode, return_hidden=True,
                           model_axes=model_axes, seq_shard=seq_shard)
            # chunked head: never materializes (B,S,V) logits at once
            _, grad_norm = lm_head_metrics(params, cfg, h[:, n_front:],
                                           tokens[:, 1:],
                                           model_axes=model_axes)
            return grad_norm
        return score

    if strategy == "ghost":
        def score(params, batch):
            b, s = batch["tokens"].shape
            if model_axes:
                tap_shapes = tap_structure_from_params(
                    params, cfg, b, s - 1, model_axes=model_axes,
                    ssm_mode=ssm_mode, attn_impl=attn_impl,
                    attn_scores=attn_scores)
                sharded = sharded_tap_names(params, cfg,
                                            attn_scores=attn_scores)
            else:
                tap_shapes = tap_structure(cfg, b, s - 1,
                                           attn_impl=attn_impl,
                                           attn_scores=attn_scores)
                sharded = None
            # the unembed tap lives outside the scan: add it explicitly
            def loss_with_taps(taps):
                losses, aux = per_example_loss(
                    params, cfg, batch, taps=taps, collect=True,
                    ssm_mode=ssm_mode, model_axes=model_axes,
                    seq_shard=seq_shard, attn_impl=attn_impl,
                    attn_scores=attn_scores)
                return losses, aux.records
            sq, _ = ghost_sq_norms(loss_with_taps, tap_shapes, b,
                                   with_bias=False, model_axes=model_axes,
                                   sharded_names=sharded)
            return jnp.sqrt(sq)
        return score

    if strategy == "ghost_rev":
        return _make_ghost_rev_scorer(cfg, ssm_mode, model_axes=model_axes,
                                      seq_shard=seq_shard,
                                      attn_impl=attn_impl,
                                      attn_scores=attn_scores)

    if strategy == "full":
        if model_axes:
            raise ValueError(
                "strategy 'full' (the vmap-of-grad test oracle) does not "
                "support model-axis-sharded params; use 'ghost' or "
                "'ghost_rev', which psum partial per-example norms over "
                "the model axes")

        def score(params, batch):
            def loss_one(p, tokens):
                losses, _ = per_example_loss(
                    p, cfg, {"tokens": tokens[None]}, ssm_mode=ssm_mode)
                return losses[0]
            grads = jax.vmap(jax.grad(loss_one), in_axes=(None, 0))(
                params, batch["tokens"])
            leaves = jax.tree.leaves(grads)
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)),
                             axis=tuple(range(1, g.ndim))) for g in leaves)
            return jnp.sqrt(sq)
        return score

    raise ValueError(f"unknown strategy {strategy!r}")


# ----------------------------------------------- memory-scalable ghost_rev
def _make_ghost_rev_scorer(cfg, ssm_mode: str,
                           model_axes: tuple[str, ...] = (),
                           seq_shard: bool = False,
                           attn_impl: str = "ref",
                           attn_scores: Optional[str] = None):
    """Exact ghost scoring via a manual reverse scan over layer periods.

    Memory: P boundary activations + ONE period of records/cotangents,
    instead of `ghost`'s records+cotangents for every layer at once —
    the remat structure of training, applied to per-example scoring.

    With ``model_axes`` the per-period contributions follow the same
    partial/replicated classification as `ghost` (sharded_tap_names) and
    the accumulated squared norms psum over the model axes at the end.
    """
    import jax.numpy as jnp
    from repro.core.collectives import axis_info, psum
    from repro.models.layers import Tape, rmsnorm, unembed, embed
    from repro.models.transformer import (_apply_layer, sharded_tap_names,
                                          tap_structure,
                                          tap_structure_from_params)

    specs = cfg.layer_specs()
    model_axes = tuple(model_axes)

    def score(params, batch):
        _, n_model = axis_info(model_axes)
        sharded_names = sharded_tap_names(params, cfg,
                                          attn_scores=attn_scores) \
            if model_axes else set()
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        n_front = embeds.shape[1] if embeds is not None else 0
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        b, s_text = inputs.shape

        h0 = embed(params["embed"], inputs, cfg, model_axes=model_axes)
        if embeds is not None:
            h0 = jnp.concatenate([embeds.astype(h0.dtype), h0], axis=1)
        s = h0.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def period_fwd(h, pp, ptaps, collect):
            tape = Tape(taps=ptaps, records={} if collect else None)
            for i, spec in enumerate(specs):
                h, _ = _apply_layer(pp[f"l{i}"], h, cfg, spec, positions,
                                    tape, f"l{i}", ssm_mode,
                                    model_axes=model_axes,
                                    seq_shard=seq_shard,
                                    attn_impl=attn_impl,
                                    attn_scores=attn_scores)
            return h, tape.records

        # ---- phase A: forward, storing only period-boundary activations
        def f_a(h, pp):
            h2, _ = period_fwd(h, pp, None, False)
            return h2, h  # ys = this period's INPUT boundary

        h_final, boundaries = jax.lax.scan(f_a, h0, params["layers"])

        # ---- head: per-example loss cotangent + unembed ghost term
        def head_losses(h):
            hn = rmsnorm(params["final_norm"], h, cfg.norm_eps)
            logits = unembed(params["embed"], hn, cfg,
                             model_axes=model_axes)[:, n_front:]
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, targets[..., None], -1)[..., 0]
            return jnp.sum(jnp.mean(nll, axis=-1)), (hn, lp)

        (_, (hn, lp)), head_vjp = jax.vjp(head_losses, h_final, has_aux=False)
        dh_final, = head_vjp((jnp.ones(()), (jnp.zeros_like(hn),
                                             jnp.zeros_like(lp))))
        # closed-form dL/dlogits for the unembed ghost contribution —
        # computed from the GATHERED full-vocab logits, so under model
        # parallelism it is replicated and counted once (÷ n_model)
        p_soft = jnp.exp(lp)
        onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=jnp.float32)
        dlogits = (p_soft - onehot) / s_text
        sq = ops.ghost_norm(hn[:, n_front:], dlogits) / n_model

        # per-period tap template (strip the leading period axis + unembed)
        full_taps = (tap_structure_from_params(
                         params, cfg, b, s_text + n_front,
                         model_axes=model_axes, ssm_mode=ssm_mode,
                         attn_impl=attn_impl, attn_scores=attn_scores)
                     if model_axes else
                     tap_structure(cfg, b, s_text + n_front,
                                   attn_impl=attn_impl,
                                   attn_scores=attn_scores))
        period_taps = {
            k: jnp.zeros(v.shape[1:], v.dtype)
            for k, v in full_taps.items() if k != "unembed"
        }

        # ---- phase B: reverse scan, one period of cotangents at a time
        def f_b(carry, xs):
            dh, acc = carry
            pp, h_in = xs
            (h_out, records), vjp = jax.vjp(
                lambda h, t: period_fwd(h, pp, t, True), h_in, period_taps)
            zero_rec = jax.tree.map(jnp.zeros_like, records)
            dh_prev, dtaps = vjp((dh, zero_rec))
            contrib = jnp.zeros((b,), jnp.float32)
            for name, x in records.items():
                if name not in dtaps:
                    continue
                dt = dtaps[name]
                if name.endswith(".qkv_scores"):
                    # score tap: the cotangent IS the finished (B,) score
                    c = dt.astype(jnp.float32)
                elif x.ndim == 2 and x.shape[0] != b:  # token-flat (T,d)
                    x = x.reshape(b, -1, x.shape[-1])
                    dt = dt.reshape(b, -1, dt.shape[-1])
                    c = _contribution(x, dt, b, False, scanned=False)
                else:
                    c = _contribution(x, dt, b, False, scanned=False)
                if model_axes and name not in sharded_names:
                    c = c / n_model  # replicated layer: counted once
                contrib = contrib + c
            return (dh_prev, acc + contrib), None

        (_, sq_layers), _ = jax.lax.scan(
            f_b, (dh_final, sq), (params["layers"], boundaries),
            reverse=True)
        return jnp.sqrt(psum(sq_layers, model_axes))

    return score


# ---------------------------------------------------------- MLP strategies
def make_mlp_scorer(cfg, strategy: str,
                    model_axes: tuple[str, ...] = ()) -> Callable:
    """Scorer for the paper's MLP classifier (faithful Prop.-1 path).

    With ``model_axes`` the returned scorer expects model-axis-sharded
    params (column shards, inside shard_map).  Gradient-norm strategies
    compute per-example partial squared norms from the local shards and
    psum them over the model axes, so the proposal ω̃ is exact and
    replicated across model devices; forward-only strategies (loss /
    logit_grad) read the gathered replicated logits and need no reduction.
    """
    from repro.models.mlp import layer_is_sharded, mlp_forward, per_example_loss
    from repro.models.layers import Tape
    from repro.core.collectives import axis_info, psum
    model_axes = tuple(model_axes)
    n_layers = len(cfg.hidden) + 1

    if strategy == "loss":
        def score(params, batch):
            return jnp.maximum(
                per_example_loss(params, batch, cfg, model_axes=model_axes),
                0.0)
        return score

    if strategy == "logit_grad":
        def score(params, batch):
            logits = mlp_forward(params, batch["x"], cfg,
                                 model_axes=model_axes)
            p = jax.nn.softmax(logits.astype(jnp.float32), -1)
            py = jnp.take_along_axis(p, batch["y"][:, None], -1)[:, 0]
            sq = jnp.sum(jnp.square(p), -1) - 2.0 * py + 1.0
            return jnp.sqrt(sq)
        return score

    if strategy == "ghost":
        def score(params, batch):
            b = batch["x"].shape[0]
            sharded = {f"fc{i}" for i in range(n_layers)
                       if model_axes and layer_is_sharded(params, cfg, i)}
            # discover tap shapes with one abstract trace
            shapes: dict = {}
            def probe(x):
                t = Tape(tap_shapes=shapes)
                return per_example_loss(params, {"x": x, "y": batch["y"]},
                                        cfg, tape=t, model_axes=model_axes)
            jax.eval_shape(probe, batch["x"])

            def loss_with_taps(taps):
                t = Tape(taps=taps, records={})
                losses = per_example_loss(params, batch, cfg, tape=t,
                                          model_axes=model_axes)
                return losses, t.records
            sq, _ = ghost_sq_norms(loss_with_taps, shapes, b,
                                   scanned_names=set(), with_bias=True,
                                   model_axes=model_axes,
                                   sharded_names=sharded)
            return jnp.sqrt(sq)
        return score

    if strategy == "full":
        def score(params, batch):
            def loss_one(p, x, y):
                return per_example_loss(p, {"x": x[None], "y": y[None]}, cfg,
                                        model_axes=model_axes)[0]
            grads = jax.vmap(jax.grad(loss_one), in_axes=(None, 0, 0))(
                params, batch["x"], batch["y"])
            _, n_model = axis_info(model_axes)

            def leaf_sq(i, g):
                s = jnp.sum(jnp.square(g.astype(jnp.float32)),
                            axis=tuple(range(1, g.ndim)))
                if model_axes and not layer_is_sharded(params, cfg, i):
                    s = s / n_model  # replicated layer: counted once
                return s

            sq = sum(leaf_sq(i, g)
                     for i in range(n_layers)
                     for g in jax.tree.leaves(grads[f"fc{i}"]))
            return jnp.sqrt(psum(sq, model_axes))
        return score

    raise ValueError(f"unknown strategy {strategy!r}")

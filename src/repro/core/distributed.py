"""Distributed ISSGD execution: the paper's system shape on a mesh.

Runs the one-code-path step of core/issgd.py under ``shard_map`` on meshes
from launch/mesh.py:

  * the dataset and the WeightStore (`weights`, `scored_at`) are sharded
    over the data axes (contiguous blocks of the example dim per device);
  * each device scores the round-robin slices of the logical scoring
    shards it owns — the paper's worker fan-out, with zero communication;
  * sampling is hierarchical two-stage (W block totals shared by one psum
    of a W-float vector, then within-block resolution by the owner), so no
    step ever gathers the full f32[N] table — the wire cost per step is
    W floats + B indices + B proposal rows, the paper's "one float per
    sample instead of gradients";
  * parameters stay replicated and the master update is computed
    redundantly on every device (bitwise-identical), which keeps the
    sharded run numerically equal to the single-device one.

`launch/train.py --mesh N` is the CLI entry; on CPU it forces N host
devices via XLA_FLAGS so the whole path is testable without a pod.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.issgd import (ISSGDConfig, StepMetrics, TrainState,
                              make_score_step, make_train_step)
from repro.core.weight_store import BufferedWeightStore, WeightStore
from repro.dist import data_axes, model_axes, param_pspecs, shard_map
from repro.dist.sharding import dim_spec


def _dspec(axes: tuple[str, ...]) -> P:
    return P(dim_spec(axes))


def _store_pspec(axes: tuple[str, ...], quantized: bool = False) -> WeightStore:
    """Spec tree for a WeightStore shard: ``quantized`` adds the int8
    table's per-chunk scale leaf (example-axis-sharded like the codes —
    chunk boundaries never straddle devices)."""
    return WeightStore(weights=_dspec(axes), scored_at=_dspec(axes),
                       qscale=_dspec(axes) if quantized else None)


def _is_pspec(x) -> bool:
    return isinstance(x, P)


def mesh_device_count(mesh: Mesh, axes: Optional[tuple[str, ...]] = None) -> int:
    """Device count over `axes` of `mesh` (default: the data axes)."""
    axes = data_axes(mesh) if axes is None else axes
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def opt_state_pspecs(opt_state, params, params_pspecs):
    """PartitionSpec tree for an optimizer state: any subtree that mirrors
    the param tree (sgd momentum, each of adam's m/v) inherits the param
    specs; scalar bookkeeping leaves replicate.  `opt_state` may be a
    ShapeDtypeStruct tree (from jax.eval_shape(optimizer.init, params))."""
    pdef = jax.tree.structure(params)

    def rec(sub):
        try:
            if jax.tree.structure(sub) == pdef:
                return params_pspecs
        except Exception:
            pass
        if isinstance(sub, dict):
            return {k: rec(v) for k, v in sub.items()}
        if isinstance(sub, (list, tuple)) and not hasattr(sub, "_fields"):
            return type(sub)(rec(v) for v in sub)
        return P()

    return rec(opt_state)


def _resolve_param_specs(mesh: Mesh, optimizer, param_specs, params_template):
    """(params_pspec_tree, opt_pspec_tree, model_axes) for the builders.

    Without `param_specs` — or on a mesh with no (non-trivial) model axis —
    params stay replicated (`P()`) and model_axes is (), which keeps every
    pre-model-parallel call site bitwise unchanged."""
    maxes = model_axes(mesh)
    if param_specs is None or not maxes:
        return P(), P(), ()
    if params_template is None:
        raise ValueError("param_specs given but no params_template: the "
                         "logical→mesh rules need the concrete shapes")
    pp = param_pspecs(param_specs, params_template, mesh)
    if optimizer is None:
        op = P()
    else:
        opt_t = jax.eval_shape(optimizer.init, params_template)
        op = opt_state_pspecs(opt_t, params_template, pp)
    return pp, op, maxes


def train_state_pspecs(mesh: Mesh, params_pspecs=P(),
                       opt_pspecs=P(), quantized: bool = False) -> TrainState:
    """PartitionSpec tree for TrainState: params/opt replicated unless
    model-parallel spec trees are passed in, the WeightStore sharded over
    the data axes.  (Async states carry a BufferedWeightStore instead —
    `shard_train_state` places those via `_place_store`; the async step
    functions take the individual buffers, never the whole state, so no
    buffered spec tree is needed.)"""
    axes = data_axes(mesh)
    return TrainState(
        params=params_pspecs, opt_state=opt_pspecs,
        stale_params=params_pspecs,
        store=_store_pspec(axes, quantized),
        step=P(), rng=P(),
    )


def dataset_pspecs(data: dict, mesh: Mesh) -> dict:
    """Example-axis sharding for every dataset array."""
    axes = data_axes(mesh)
    return {k: P(dim_spec(axes), *([None] * (v.ndim - 1)))
            for k, v in data.items()}


def shard_dataset(data: dict, mesh: Mesh) -> dict:
    """Place every dataset array on `mesh`, example-axis-sharded."""
    specs = dataset_pspecs(data, mesh)
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in data.items()}


def _place_store(store, mesh: Mesh, axes: tuple[str, ...]):
    """Place a (possibly double-buffered) WeightStore on `mesh`."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    if isinstance(store, BufferedWeightStore):
        return BufferedWeightStore(
            read_buf=_place_store(store.read_buf, mesh, axes),
            write_buf=_place_store(store.write_buf, mesh, axes),
            synced_at=put(store.synced_at, P()))
    return WeightStore(weights=put(store.weights, _dspec(axes)),
                       scored_at=put(store.scored_at, _dspec(axes)),
                       qscale=(None if store.qscale is None
                               else put(store.qscale, _dspec(axes))))


def shard_train_state(state: TrainState, mesh: Mesh,
                      param_specs=None) -> TrainState:
    """Place a TrainState on `mesh`: sharded store (plain or
    double-buffered), params replicated — or tensor-sharded over the model
    axis when `param_specs` (the logical-axis tree, e.g. `mlp_specs`) is
    given and the mesh carries one."""
    axes = data_axes(mesh)
    pp, _, _ = _resolve_param_specs(mesh, None, param_specs, state.params)
    op = (P() if isinstance(pp, P)
          else opt_state_pspecs(state.opt_state, state.params, pp))

    def place(subtree, spec):
        if isinstance(spec, P):
            return jax.tree.map(
                lambda x: jax.device_put(x, NamedSharding(mesh, spec)),
                subtree)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            subtree, spec, is_leaf=_is_pspec)

    return TrainState(
        params=place(state.params, pp),
        opt_state=place(state.opt_state, op),
        stale_params=place(state.stale_params, pp),
        store=_place_store(state.store, mesh, axes),
        step=place(state.step, P()),
        rng=place(state.rng, P()),
    )


def resolve_score_shards(cfg: ISSGDConfig, mesh: Mesh) -> ISSGDConfig:
    """Default W to the device count when the config leaves it at 1, and
    validate divisibility (W must be a multiple of the data-axis size)."""
    import dataclasses
    nd = mesh_device_count(mesh)
    w = cfg.score_shards
    if w <= 1:
        return dataclasses.replace(cfg, score_shards=nd)
    if w % nd:
        raise ValueError(f"score_shards={w} must be a multiple of the "
                         f"data-axis device count {nd}")
    return cfg


def make_sharded_train_step(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    mesh: Mesh,
    data_template: dict,
    aux_loss: Optional[Callable] = None,
    fused_score: Optional[Callable] = None,
    param_specs=None,
    params_template=None,
    monitors=None,
    gated: bool = False,
) -> tuple[Callable, ISSGDConfig]:
    """The ISSGD step under shard_map over `mesh`.

    Returns (step, cfg) where `step(state, data) -> (state, metrics)` —
    state/data must be placed with `shard_train_state`/`shard_dataset` —
    and `cfg` has score_shards resolved against the mesh.  The returned fn
    is shard_map-wrapped but not jitted; wrap in jax.jit at the call site.

    With `param_specs` (a logical-axis tree such as `mlp_specs(cfg)`) and
    `params_template` on a mesh carrying a model axis, params + optimizer
    state are tensor-sharded through the `param_pspecs` rules; the
    loss/scorer callables must then be model-axis-aware (built with
    ``model_axes=("model",)``).

    With a non-empty ``monitors`` the step returns ``(state, metrics,
    {name: scalar})`` — the monitor scalars psum/pmax to global values
    inside the program and come out replicated (P() specs).

    With ``gated=True`` the step takes the controller's replicated
    ``use_is`` device bool as a trailing argument (see
    core/issgd.make_train_step); ``step.gated`` is reattached on the
    shard_mapped wrapper for callers to capture pre-jit.
    """
    axes = data_axes(mesh)
    monitors = monitors or None
    nd = mesh_device_count(mesh, axes)
    cfg = resolve_score_shards(cfg, mesh)
    if num_examples % nd:
        raise ValueError(f"num_examples={num_examples} not divisible by "
                         f"{nd} devices")
    pp, op, maxes = _resolve_param_specs(mesh, optimizer, param_specs,
                                         params_template)

    body = make_train_step(per_example_loss, scorer, optimizer, cfg,
                           num_examples, aux_loss=aux_loss,
                           fused_score=fused_score, axes=axes,
                           model_axes=maxes,
                           param_pspecs=pp if maxes else None,
                           monitors=monitors, gated=gated)
    state_specs = train_state_pspecs(mesh, pp, op,
                                     quantized=cfg.table_dtype == "int8")
    dspecs = dataset_pspecs(data_template, mesh)
    metric_specs = StepMetrics(*([P()] * len(StepMetrics._fields)))
    in_specs = (state_specs, dspecs)
    if gated:
        in_specs += (P(),)          # the replicated use_is scalar
    out_specs = (state_specs, metric_specs)
    if monitors:
        out_specs += ({name: P() for name in monitors.names},)

    step = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    step.with_monitors = bool(monitors)
    step.gated = bool(gated)
    return step, cfg


def make_sharded_async_steps(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    mesh: Mesh,
    data_template: dict,
    aux_loss: Optional[Callable] = None,
    monitor_traces: bool = True,
    param_specs=None,
    params_template=None,
    monitors=None,
    gated: bool = False,
) -> tuple[Callable, Callable, ISSGDConfig]:
    """The async pipeline's two computations under shard_map over `mesh`.

    Returns ``(scoring_step, master_step, cfg)`` — the raw shard_mapped
    bodies of core/async_pipeline.make_async_steps, ready to hand to
    AsyncPipeline (which jits them, donating write_buf).  The scoring step
    writes only the device-local shard of write_buf; the master samples
    from the sharded read_buf with the hierarchical two-stage draw, so it
    never gathers the full f32[N] table (the HLO gate of
    tests/test_async.py pins this for the async master too).

    With the default ``monitor_traces=True`` the scoring step ends with
    the fig-4 trace psums (3 scalars — cross-device rendezvous inside the
    scoring program, parity with the fused step's monitors); pass
    ``monitor_traces=False`` (train.py ``--no-trace-monitors``) for the
    strictly collective-free scoring build the HLO gate pins.

    With a non-empty ``monitors`` the master step grows the trailing
    monitor dict (replicated); ``master_step.with_monitors`` is reattached
    on the shard_mapped wrapper for AsyncPipeline to capture pre-jit.
    With ``gated=True`` the master takes the controller's replicated
    ``use_is`` bool as a trailing argument (``master_step.gated`` is
    likewise reattached).
    """
    from repro.core.async_pipeline import ScoreMetrics, make_async_steps

    axes = data_axes(mesh)
    monitors = monitors or None
    nd = mesh_device_count(mesh, axes)
    cfg = resolve_score_shards(cfg, mesh)
    if num_examples % nd:
        raise ValueError(f"num_examples={num_examples} not divisible by "
                         f"{nd} devices")

    pp, op, maxes = _resolve_param_specs(mesh, optimizer, param_specs,
                                         params_template)
    scoring_body, master_body = make_async_steps(
        per_example_loss, scorer, optimizer, cfg, num_examples,
        aux_loss=aux_loss, axes=axes, model_axes=maxes,
        param_pspecs=pp if maxes else None, monitor_traces=monitor_traces,
        monitors=monitors, gated=gated)
    store_spec = _store_pspec(axes, quantized=cfg.table_dtype == "int8")
    dspecs = dataset_pspecs(data_template, mesh)
    metric_specs = StepMetrics(*([P()] * len(StepMetrics._fields)))
    smetric_specs = ScoreMetrics(*([P()] * len(ScoreMetrics._fields)))
    master_in = (pp, op, pp, store_spec, P(), P(), dspecs)
    if gated:
        master_in += (P(),)         # the replicated use_is scalar
    master_out = (pp, op, pp, P(), P(), metric_specs)
    if monitors:
        master_out += ({name: P() for name in monitors.names},)

    scoring_step = shard_map(
        scoring_body, mesh=mesh,
        in_specs=(pp, store_spec, P(), dspecs),
        out_specs=(store_spec, smetric_specs),
    )
    master_step = shard_map(
        master_body, mesh=mesh,
        in_specs=master_in,
        out_specs=master_out,
    )
    master_step.with_monitors = bool(monitors)
    master_step.gated = bool(gated)
    return scoring_step, master_step, cfg


def make_sharded_streamed_steps(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    mesh: Mesh,
    data_template: dict,
    chunk_size: int,
    aux_loss: Optional[Callable] = None,
    fused_score: Optional[Callable] = None,
    async_mode: bool = False,
    monitor_traces: bool = True,
    param_specs=None,
    params_template=None,
    monitors=None,
    gated: bool = False,
) -> tuple[Callable, Callable, Callable, ISSGDConfig]:
    """The streamed data plane's three device programs under shard_map.

    Returns ``(scoring_step, sample_step, master_step, cfg)`` ready for
    data.streaming.StreamedISSGD.  The scoring fan-out consumes its
    host-streamed round-robin rows example-axis-sharded (each device gets
    exactly its slice — still zero collectives in the non-monitored
    build); the sampled minibatch arrives replicated; neither program ever
    takes the dataset, so the streamed HLO gate extends the no-full-table
    guarantee to the examples themselves: the only example-count-sized
    arrays in any program are the sharded f32[N] table shards.

    ``data_template`` only fixes per-key ndim/dtype for the specs; shapes
    may differ (the template is typically the resident arrays or one host
    chunk).

    With ``gated=True`` both the sample and master programs take the
    controller's replicated ``use_is`` bool as a trailing argument
    (``.gated`` reattached on both wrappers).
    """
    from repro.core.async_pipeline import ScoreMetrics
    from repro.data.streaming import make_streamed_steps

    axes = data_axes(mesh)
    monitors = monitors or None
    nd = mesh_device_count(mesh, axes)
    cfg = resolve_score_shards(cfg, mesh)
    if num_examples % nd:
        raise ValueError(f"num_examples={num_examples} not divisible by "
                         f"{nd} devices")

    pp, op, maxes = _resolve_param_specs(mesh, optimizer, param_specs,
                                         params_template)
    scoring_body, sample_body, master_body = make_streamed_steps(
        per_example_loss, scorer, optimizer, cfg, num_examples, chunk_size,
        aux_loss=aux_loss, fused_score=fused_score, axes=axes,
        model_axes=maxes, param_pspecs=pp if maxes else None,
        async_mode=async_mode, monitor_traces=monitor_traces,
        monitors=monitors, gated=gated)
    expect_scores = master_body.expect_scores

    store_spec = _store_pspec(axes, quantized=cfg.table_dtype == "int8")
    ds = _dspec(axes)
    sharded_rows = dataset_pspecs(data_template, mesh)   # scoring stream
    replicated_rows = {k: P() for k in data_template}    # sampled minibatch
    smetric_specs = ScoreMetrics(*([P()] * len(ScoreMetrics._fields)))
    metric_specs = StepMetrics(*([P()] * len(StepMetrics._fields)))

    scoring_step = shard_map(
        scoring_body, mesh=mesh,
        in_specs=(pp, store_spec, P(), sharded_rows),
        out_specs=(store_spec, ds, ds, smetric_specs),
    )
    sample_in = (store_spec, P(), P())
    if gated:
        sample_in += (P(),)         # the replicated use_is scalar
    sample_step = shard_map(
        sample_body, mesh=mesh,
        in_specs=sample_in,
        out_specs=(P(), P()),
    )
    master_in = (pp, op, pp, store_spec, P(), P(), replicated_rows)
    if expect_scores:
        master_in += (ds, ds)
    if gated:
        master_in += (P(),)
    master_out = (pp, op, pp, store_spec, P(), P(), metric_specs)
    if monitors:
        master_out += ({name: P() for name in monitors.names},)
    master_step = shard_map(
        master_body, mesh=mesh,
        in_specs=master_in,
        out_specs=master_out,
    )
    master_step.expect_scores = expect_scores
    master_step.with_monitors = bool(monitors)
    master_step.gated = bool(gated)
    sample_step.gated = bool(gated)
    return scoring_step, sample_step, master_step, cfg


def make_sharded_score_step(
    scorer: Callable,
    cfg: ISSGDConfig,
    num_examples: int,
    mesh: Mesh,
    data_template: dict,
    param_specs=None,
    params_template=None,
    optimizer=None,
) -> Callable:
    """The standalone probe/scoring pass under shard_map (fused-mode
    coverage).  Fully shard-local on the data plane: zero collectives
    without model parallelism (with it, only the scorer's model-axis
    gathers/psums).  `optimizer` is needed only to spec the opt_state the
    probe passes through untouched when params are model-sharded."""
    axes = data_axes(mesh)
    cfg = resolve_score_shards(cfg, mesh)
    body = make_score_step(scorer, cfg, num_examples, axes=axes)
    pp, op, _ = _resolve_param_specs(mesh, optimizer, param_specs,
                                     params_template)
    state_specs = train_state_pspecs(mesh, pp, op,
                                     quantized=cfg.table_dtype == "int8")
    dspecs = dataset_pspecs(data_template, mesh)
    return shard_map(
        body, mesh=mesh,
        in_specs=(state_specs, dspecs),
        out_specs=state_specs,
    )

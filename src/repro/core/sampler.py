"""Multinomial (with replacement) sampling from the weight table.

Single-host path: inverse-CDF via cumsum + searchsorted — O(N + M log N),
no M×N Gumbel matrix.

Distributed path (`shard_sample`): the table is sharded over the data axes.
Each shard computes its local weight sum; an all-gather of the (tiny) shard
sums gives every shard the global CDF *over shards*; each of the M global
uniform draws lands in exactly one shard, which resolves it against its
local CDF.  The resolved global indices are combined with a psum (each draw
is claimed by exactly one shard, all others contribute 0).  Communication:
one all-gather of `num_shards` floats + one psum of M ints — this is the
TPU translation of the paper's "workers communicate one float per sample
instead of gradients".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def sample_indices(
    key: jax.Array,
    weights: jax.Array,
    num_samples: int,
) -> jax.Array:
    """Multinomial-with-replacement over unnormalized `weights` (host path)."""
    cdf = jnp.cumsum(weights.astype(jnp.float64) if weights.dtype == jnp.float64
                     else weights.astype(jnp.float32))
    total = cdf[-1]
    u = jax.random.uniform(key, (num_samples,), dtype=cdf.dtype) * total
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, weights.shape[0] - 1).astype(jnp.int32)


def shard_sample(
    key: jax.Array,
    local_weights: jax.Array,
    num_samples: int,
    axis_names: tuple[str, ...],
) -> jax.Array:
    """SPMD body (call inside shard_map): sample M global indices from the
    sharded table.  Every shard receives the same `key` and returns the same
    M global indices (replicated output).

    axis_names: mesh axes the table's example-dim is sharded over, e.g.
    ("pod", "data") or ("data",).
    """
    n_local = local_weights.shape[0]
    local_sum = jnp.sum(local_weights, dtype=jnp.float32)

    # Flatten the (possibly multi-axis) shard grid into a linear shard id.
    shard_id = jnp.zeros((), jnp.int32)
    num_shards = 1
    for ax in axis_names:
        size = jax.lax.axis_size(ax)
        shard_id = shard_id * size + jax.lax.axis_index(ax)
        num_shards *= size

    # All shards learn all shard sums (num_shards floats).
    contrib = jnp.zeros((num_shards,), jnp.float32).at[shard_id].set(local_sum)
    shard_sums = contrib
    for ax in axis_names:
        shard_sums = jax.lax.psum(shard_sums, ax)

    shard_cdf = jnp.cumsum(shard_sums)
    total = shard_cdf[-1]
    shard_starts = shard_cdf - shard_sums  # prefix of weight mass per shard

    # Same key on every shard → identical global draws.
    u = jax.random.uniform(key, (num_samples,), jnp.float32) * total

    # Which shard owns each draw?
    owner = jnp.searchsorted(shard_cdf, u, side="right")
    owner = jnp.clip(owner, 0, num_shards - 1)
    mine = owner == shard_id

    # Resolve *all* draws against the local CDF (masked later).
    local_cdf = jnp.cumsum(local_weights.astype(jnp.float32))
    local_u = u - shard_starts[owner]
    local_idx = jnp.searchsorted(local_cdf, local_u, side="right")
    local_idx = jnp.clip(local_idx, 0, n_local - 1)

    global_idx = jnp.where(mine, local_idx + shard_id * n_local, 0)
    for ax in axis_names:
        global_idx = jax.lax.psum(global_idx, ax)
    return global_idx.astype(jnp.int32)


def make_distributed_sampler(mesh, table_axes: tuple[str, ...]):
    """Wrap `shard_sample` in a shard_map over `mesh`.

    Returns fn(key, weights_sharded, num_samples) -> replicated i32[M].
    """
    shard_map = jax.shard_map

    table_spec = P(table_axes)

    def sampler(key, weights, num_samples: int):
        def body(key, local_w):
            return shard_sample(key, local_w, num_samples, table_axes)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), table_spec),
            out_specs=P(),
            check_vma=False,
        )(key, weights)

    return sampler

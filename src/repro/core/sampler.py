"""Hierarchical multinomial (with replacement) sampling from the ω̃ table.

One algorithm for every scale (the paper's "workers communicate one float
per sample instead of gradients", expressed as a fixed two-stage draw):

  1. the table is divided into W *logical scoring shards* (contiguous
     blocks); every device owns W/num_devices of them.  Each block's weight
     mass is summed locally and the W block totals are shared with one
     psum of a W-float vector;
  2. each of the M global uniform draws picks a block via the (tiny) block
     CDF, then resolves within the winning block against that block's local
     CDF.  The owning device claims the draw; a psum of the one-owner masks
     combines the M global indices.

Because the block decomposition is fixed by W — NOT by the device count —
the arithmetic is bitwise identical for any mesh size that divides W:
single-device execution (axes=()) is the mesh-size-1 special case of the
sharded path, not a separate code path.  No step ever materializes the
full f32[N] table on one device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import axis_info, psum


def two_stage_sample(
    key: jax.Array,
    local_weights: jax.Array,
    num_samples: int,
    axes: tuple[str, ...] = (),
    shards_per_device: int = 1,
    block_sums: jax.Array | None = None,
) -> jax.Array:
    """Draw `num_samples` global indices ∝ the sharded, unnormalized table.

    local_weights: this device's (n_local,) slice, viewed as
    `shards_per_device` contiguous logical blocks.  Every device receives
    the same `key` and returns the same replicated i32[M] global indices.

    ``block_sums`` optionally supplies the stage-1 per-block masses from
    an external maintainer (core/mass_index.py, the ``--index tree``
    path) instead of the in-draw reduction.  The index computes them
    with the *identical* reduction, so the draws stay bitwise-equal —
    and with ``block_sums=None`` this is byte-for-byte the original
    program (the dense default's HLO gate).
    """
    w_loc = shards_per_device
    n_local = local_weights.shape[0]
    if n_local % w_loc:
        raise ValueError(f"local table size {n_local} not divisible by "
                         f"{w_loc} logical shards")
    n_w = n_local // w_loc
    dev_id, n_dev = axis_info(axes)
    num_shards = w_loc * n_dev

    # f64 tables keep their precision through the CDFs (large-N callers)
    ctype = (jnp.float64 if local_weights.dtype == jnp.float64
             else jnp.float32)
    blocks = local_weights.astype(ctype).reshape(w_loc, n_w)
    if block_sums is None:
        block_sums = jnp.sum(blocks, axis=1)                 # (w_loc,)
    else:
        if block_sums.shape != (w_loc,):
            raise ValueError(f"block_sums shape {block_sums.shape} != "
                             f"({w_loc},)")
        block_sums = block_sums.astype(ctype)
    first = dev_id * w_loc
    sums = jax.lax.dynamic_update_slice(
        jnp.zeros((num_shards,), ctype), block_sums, (first,))
    sums = psum(sums, axes)                                  # (W,) everywhere

    shard_cdf = jnp.cumsum(sums)
    total = shard_cdf[-1]
    shard_starts = shard_cdf - sums

    # Same key on every device → identical global draws.
    u = jax.random.uniform(key, (num_samples,), ctype) * total

    owner = jnp.clip(jnp.searchsorted(shard_cdf, u, side="right"),
                     0, num_shards - 1)
    mine = (owner >= first) & (owner < first + w_loc)
    lb = jnp.clip(owner - first, 0, w_loc - 1)

    # Resolve within the winning block (mesh-invariant: block CDF + global
    # block start only — never a cross-block flattened CDF).  Vectorized
    # bisect_right over (block, u) pairs: O(M·log n_w) scalar gathers,
    # never an (M, n_w) gathered-CDF intermediate; the result is the exact
    # searchsorted count, so the algorithm change is bitwise-invisible.
    block_cdf = jnp.cumsum(blocks, axis=1)                   # (w_loc, n_w)
    local_u = u - shard_starts[owner]
    lo = jnp.zeros(u.shape, jnp.int32)
    hi = jnp.full(u.shape, n_w, jnp.int32)
    for _ in range(max(n_w.bit_length(), 1)):
        mid = (lo + hi) // 2
        go_right = block_cdf[lb, mid] <= local_u             # side="right"
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    pos = jnp.clip(lo, 0, n_w - 1)

    gidx = dev_id * n_local + lb * n_w + pos
    gidx = psum(jnp.where(mine, gidx, 0), axes)
    return gidx.astype(jnp.int32)


def index_to_chunk(idx, chunk_size: int):
    """Resolve global example indices to (chunk, offset) coordinates of the
    chunked example store (data/store.py).  Works on jnp and np arrays —
    the device programs use it to bucket proposal mass per chunk, the host
    data plane uses it to route sampled indices to window slots or host
    fetches."""
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return idx // chunk_size, idx % chunk_size


def chunk_proposal_mass(proposal: jax.Array, chunk_size: int,
                        axes: tuple[str, ...] = ()) -> jax.Array:
    """Per-chunk mass of the (shard-local) proposal, combined into the
    replicated global f32[num_chunks] vector.

    This is the signal the streaming data plane prefetches on: chunks
    carrying the most proposal mass are made device-resident before they
    are drawn.  Same one-owner layout as the two-stage draw — device d's
    chunks occupy the contiguous block starting at d * local_chunks — so
    one psum of a num_chunks-float vector shares it (never the f32[N]
    table).

    A trailing partial chunk (n_local not divisible by chunk_size) is
    zero-padded and contributes exactly its partial mass — the same
    convention as the host store's last chunk.  NOTE the streaming plane
    itself still requires exact multiples (ChunkedExampleStore's
    fixed-size chunks); that assumption is pinned in
    tests/test_mass_index.py alongside this padding behavior."""
    n_local = proposal.shape[0]
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    local_chunks = -(-n_local // chunk_size)
    pad = local_chunks * chunk_size - n_local
    if pad:
        proposal = jnp.concatenate(
            [proposal, jnp.zeros((pad,), proposal.dtype)])
    dev_id, n_dev = axis_info(axes)
    local_mass = jnp.sum(proposal.reshape(local_chunks, chunk_size), axis=1)
    mass = jax.lax.dynamic_update_slice(
        jnp.zeros((local_chunks * n_dev,), local_mass.dtype),
        local_mass, (dev_id * local_chunks,))
    return psum(mass, axes)


def sample_indices(
    key: jax.Array,
    weights: jax.Array,
    num_samples: int,
    num_shards: int = 1,
) -> jax.Array:
    """Host-path multinomial: the axes=() special case of the two-stage
    draw.  `num_shards` controls the logical block decomposition (must
    match the distributed run it is being compared against)."""
    return two_stage_sample(key, weights, num_samples, axes=(),
                            shards_per_device=num_shards)


def shard_sample(
    key: jax.Array,
    local_weights: jax.Array,
    num_samples: int,
    axis_names: tuple[str, ...],
) -> jax.Array:
    """SPMD body (call inside shard_map): one logical shard per device."""
    return two_stage_sample(key, local_weights, num_samples,
                            axes=tuple(axis_names), shards_per_device=1)


def make_distributed_sampler(mesh, table_axes: tuple[str, ...]):
    """Wrap the two-stage draw in a shard_map over `mesh`.

    Returns fn(key, weights_sharded, num_samples) -> replicated i32[M].
    """
    from repro.dist import shard_map
    from repro.dist.sharding import dim_spec

    table_spec = P(dim_spec(table_axes))

    def sampler(key, weights, num_samples: int):
        def body(key, local_w):
            return shard_sample(key, local_w, num_samples, table_axes)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), table_spec),
            out_specs=P(),
        )(key, weights)

    return sampler

"""Importance-sampling primitives from the paper.

Implements:
  * additive smoothing of probability weights (paper appendix B.3),
  * staleness-threshold filtering (paper appendix B.1),
  * the unbiased IS-scaled minibatch loss of section 4.1:

        L(minibatch) = (1/N sum_n w_n) * 1/M sum_m  L(x_{i_m}) / w_{i_m}

All functions are pure jnp and shard-agnostic: they operate on whatever
slice of the weight table they are given, plus (optionally) precomputed
global reductions so callers can psum across shards.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ISConfig:
    """Knobs of the ISSGD estimator (paper sections 4 and B.1/B.3)."""

    # Additive smoothing constant `c` (B.3): q ∝ (w + c).  c → ∞ recovers
    # plain uniform SGD; c = 0 is the raw (risky) optimal proposal.
    smoothing: float = 1.0
    # Staleness threshold in *steps* (B.1): weights whose `scored_at` is
    # older than `staleness_threshold` steps are replaced by the smoothing
    # floor (i.e. treated as "no information", not dropped — dropping
    # examples would bias p(x)).  <= 0 disables the filter.
    staleness_threshold: int = 0
    # Floor applied after smoothing to keep q(x) > 0 wherever p(x) > 0,
    # which Theorem 1 requires for unbiasedness.
    floor: float = 1e-8


def smooth_weights(raw: jax.Array, cfg: ISConfig) -> jax.Array:
    """Additive smoothing (B.3): w̃ = max(raw, 0) + c, floored to keep q>0."""
    w = jnp.maximum(raw, 0.0) + jnp.asarray(cfg.smoothing, raw.dtype)
    return jnp.maximum(w, jnp.asarray(cfg.floor, raw.dtype))


def apply_staleness_filter(
    weights: jax.Array,
    scored_at: jax.Array,
    step: jax.Array | int,
    cfg: ISConfig,
) -> jax.Array:
    """B.1: weights scored more than `staleness_threshold` steps ago revert
    to the neutral raw value 0 — after additive smoothing (B.3) they carry
    exactly the uniform belief `c`, like a never-scored entry.

    Entries with scored_at < 0 (never scored) are always treated as neutral.
    """
    neutral = jnp.asarray(0.0, weights.dtype)
    never = scored_at < 0
    if cfg.staleness_threshold > 0:
        stale = (jnp.asarray(step) - scored_at) > cfg.staleness_threshold
        mask = jnp.logical_or(stale, never)
    else:
        mask = never
    return jnp.where(mask, neutral, weights)


def normalize(weights: jax.Array, total: Optional[jax.Array] = None) -> jax.Array:
    """ω_n = ω̃_n / Σω̃.  `total` lets distributed callers pass a psum."""
    if total is None:
        total = jnp.sum(weights)
    return weights / total


def is_loss_scale(
    sampled_weights: jax.Array,
    mean_weight: jax.Array,
) -> jax.Array:
    """Per-sample loss scale of section 4.1.

    For a minibatch drawn with probabilities ∝ ω̃, the unbiased loss is
        (1/N Σ_n ω̃_n) · 1/M Σ_m L(x_{i_m}) / ω̃_{i_m}
    so each sampled example's loss is multiplied by  mean(ω̃)/ω̃_{i_m}.
    When all ω̃ are equal this returns exactly 1 (plain SGD), the paper's
    sanity check.
    """
    return mean_weight / sampled_weights


def effective_sample_size(
    weights: jax.Array,
    s1: Optional[jax.Array] = None,
    s2: Optional[jax.Array] = None,
) -> jax.Array:
    """Kish ESS of the proposal over the table — a monitoring quantity.

    ESS = (Σw)² / Σw².  Equals N for uniform weights; small ESS warns that
    the proposal is peaked (the B.3 time-bomb regime).  `s1`/`s2` let
    distributed callers pass psummed global sums over a sharded table.
    """
    s1 = jnp.sum(weights) if s1 is None else s1
    s2 = jnp.sum(jnp.square(weights)) if s2 is None else s2
    return jnp.square(s1) / jnp.maximum(s2, 1e-30)


def proposal_entropy(
    weights: jax.Array,
    axes: tuple[str, ...] = (),
    sum_w: Optional[jax.Array] = None,
) -> jax.Array:
    """Entropy of ω (B.3 suggests monitoring it to adapt the smoothing).

    The canonical (and only) entropy implementation — the telemetry
    monitors delegate here.  Shard-decomposable:

        H(ω) = log Σw − (Σ w·log w)/Σw   over   ω = w/Σw,

    with zero-mass rows contributing their exact limit 0, so one psum of
    the w·log w partials over ``axes`` gives the global entropy of a
    sharded table.  ``sum_w`` lets callers share an existing psum'd
    total; with the defaults (no axes, no total) this is plain local
    arithmetic on whatever slice it is handed.
    """
    if sum_w is None:
        local = jnp.sum(weights)
        if axes:
            from repro.core.collectives import psum
            sum_w = psum(local, tuple(axes))
        else:
            sum_w = local
    sum_w = jnp.maximum(sum_w, 1e-30)
    wlogw = jnp.where(weights > 0,
                      weights * jnp.log(jnp.maximum(weights, 1e-30)),
                      jnp.zeros_like(weights))
    partial = jnp.sum(wlogw)
    if axes:
        from repro.core.collectives import psum
        partial = psum(partial, tuple(axes))
    return jnp.log(sum_w) - partial / sum_w

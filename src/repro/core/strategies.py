"""Pluggable proposal strategies over the WeightStore.

`core/scorer.py` owns the per-architecture score functions (loss /
logit_grad / ghost / ghost_rev / full).  This module is the layer above:
it resolves a *proposal strategy name* into a ``(params, batch) -> (B,)``
scorer, delegating the base names to the architecture factory untouched
(same bits, same compile) and adding the strategy zoo on top:

``upper_bound``
    Katharopoulos & Fleuret-style forward-only proposal ω̃ = sqrt(2·L).
    For softmax cross-entropy, Pinsker's inequality gives
    ‖p − y‖₁ ≤ sqrt(2·CE), and ‖p − y‖₂ ≤ ‖p − y‖₁, so sqrt(2L) is a
    provable upper bound on the ``logit_grad`` score at loss-forward
    cost (pinned in tests/test_sampler_stats.py).

``bandit_mixed``
    Convex mixture ω̃ = Σ_k λ_k·s_k over base scorers (Bouchard et al.,
    Online Learning to Sample).  The mixture is per-example pure — no
    batch statistics — so the store's global normalization turns it into
    a mixture of the component proposals with mass-reweighted
    coefficients, shard-safe under every mesh.  ``BanditMixer`` learns λ
    across runs/rounds from observed variance-reduction rewards.

``null``
    Constant-zero scores: the honest uniform-mode stub.  A raw weight of
    0 smooths to the additive floor (the uniform belief), and the
    scoring pass compiles to a trivial program — so a uniform benchmark
    leg keeps monitoring parity without billing a ghost backward to
    plain SGD.

Any proposal strategy composes with every execution mode
(relaxed / async / streamed / sharded) because it plugs in where the
architecture scorer always did.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.scorer import STRATEGIES

#: Every name `make_proposal` resolves: the architecture-native score
#: strategies plus the zoo built on top of them.
PROPOSALS = STRATEGIES + ("upper_bound", "bandit_mixed", "null")


def upper_bound_scorer(loss_scorer: Callable) -> Callable:
    """Wrap a loss scorer into the K&F upper-bound proposal ω̃ = sqrt(2·L).

    ``loss_scorer`` must return per-example non-negative losses (the
    ``"loss"`` strategy of either architecture factory qualifies); the
    wrapper costs one sqrt on top of the forward pass.
    """
    def score(params, batch):
        return jnp.sqrt(2.0 * jnp.maximum(loss_scorer(params, batch), 0.0))
    return score


def mixed_scorer(scorers: Sequence[Callable],
                 weights: Optional[Sequence[float]] = None) -> Callable:
    """Convex mixture of base scorers: ω̃ = Σ_k λ_k · s_k(params, batch).

    ``weights`` (defaults to uniform) are normalized to sum to 1 and
    baked in as compile-time constants — re-build the step to move λ
    (``BanditMixer`` round boundaries).  The combination is per-example
    pure, so it is exact under data- and model-sharded scoring.
    """
    scorers = tuple(scorers)
    if not scorers:
        raise ValueError("mixed_scorer needs at least one component")
    if weights is None:
        lam = (1.0 / len(scorers),) * len(scorers)
    else:
        lam = tuple(float(w) for w in weights)
        if len(lam) != len(scorers):
            raise ValueError(
                f"{len(lam)} mixture weights for {len(scorers)} scorers")
        if min(lam) < 0.0:
            raise ValueError("mixture weights must be non-negative")
        total = sum(lam)
        if total <= 0.0:
            raise ValueError("mixture weights must not all be zero")
        lam = tuple(w / total for w in lam)

    def score(params, batch):
        acc = lam[0] * scorers[0](params, batch)
        for l_k, s_k in zip(lam[1:], scorers[1:]):
            acc = acc + l_k * s_k(params, batch)
        return acc
    return score


def null_scorer() -> Callable:
    """Constant-zero scorer: smooths to the uniform proposal.

    The scoring pass still runs (monitoring parity with the IS modes)
    but compiles to a near-empty program — the right baseline leg for
    uniform-mode benchmarks.
    """
    def score(params, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        return jnp.zeros((b,), jnp.float32)
    return score


def make_proposal(base_factory: Callable, cfg, strategy: str, *,
                  mix: Optional[Sequence[float]] = None,
                  mix_of: Sequence[str] = ("loss", "logit_grad"),
                  **factory_kw) -> Callable:
    """Resolve ``strategy`` into a ``(params, batch) -> (B,) ω̃`` scorer.

    ``base_factory`` is an architecture scorer factory
    (:func:`repro.core.scorer.make_mlp_scorer` or ``make_lm_scorer``);
    ``factory_kw`` is forwarded to every base-factory call (model_axes,
    attn_impl, ...).  Names in :data:`repro.core.scorer.STRATEGIES`
    delegate to the factory unchanged, so default runs compile the exact
    pre-zoo program.  ``mix`` / ``mix_of`` configure the
    ``bandit_mixed`` mixture (λ coefficients and component strategies).
    """
    if strategy in STRATEGIES:
        return base_factory(cfg, strategy, **factory_kw)
    if strategy == "upper_bound":
        return upper_bound_scorer(base_factory(cfg, "loss", **factory_kw))
    if strategy == "bandit_mixed":
        comps = tuple(base_factory(cfg, s, **factory_kw) for s in mix_of)
        return mixed_scorer(comps, mix)
    if strategy == "null":
        return null_scorer()
    raise ValueError(f"unknown proposal strategy {strategy!r}; "
                     f"available: {', '.join(PROPOSALS)}")


class BanditMixer:
    """EXP3-style multiplicative-weights learner for mixture coefficients.

    One bandit round per observed scalar reward (typically the achieved
    variance reduction √TrΣ_unif/√TrΣ_stale of a run sampled under the
    current mixture).  With a single mixture-level reward the
    importance-weighted per-arm estimate reduces to share-proportional
    credit: each arm's cumulative score grows by ``reward · λ_k``, and
    ``mix()`` returns the softmax of the cumulative scores with a γ
    exploration floor.  Deterministic: no internal randomness, so
    benchmark runs are reproducible.
    """

    def __init__(self, arms: Sequence[str], eta: float = 0.5,
                 explore: float = 0.1):
        self.arms = tuple(arms)
        if not self.arms:
            raise ValueError("BanditMixer needs at least one arm")
        self.eta = float(eta)
        self.explore = float(explore)
        self._scores = [0.0] * len(self.arms)
        self.rounds = 0

    def mix(self) -> tuple:
        """Current mixture λ: exploration-floored softmax of arm scores."""
        m = max(self._scores)
        exps = [math.exp(self.eta * (s - m)) for s in self._scores]
        z = sum(exps)
        k = len(exps)
        return tuple((1.0 - self.explore) * e / z + self.explore / k
                     for e in exps)

    def update(self, reward: float) -> None:
        """Credit ``reward`` to each arm in proportion to its share of
        the mixture that earned it, and advance the round counter."""
        lam = self.mix()
        for j, l_j in enumerate(lam):
            self._scores[j] += float(reward) * l_j
        self.rounds += 1

"""Axis-polymorphic collectives — the one-code-path primitive layer.

Every ISSGD step helper is written against a tuple of mesh axis names
`axes`.  Inside ``shard_map`` the tuple names real mesh axes and these
helpers lower to psums; with ``axes=()`` (single device, no shard_map)
they degenerate to exact local arithmetic.  That is what makes the
single-device train step literally the mesh-size-1 special case of the
sharded one rather than a second implementation.

The gather/scatter helpers assume the standard contiguous layout for an
example-axis array sharded over `axes`: global index ``g`` lives on the
device with linear id ``g // n_local`` at local offset ``g % n_local``.
Cross-device reads are one-owner masked psums (the non-owners contribute
exact zeros, so the combined value is bitwise the owner's row — this is
what keeps sharded and single-device runs numerically identical).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Axes = tuple


def psum(x, axes: Axes):
    """lax.psum over `axes`; identity when axes is empty."""
    if not axes:
        return x
    return jax.lax.psum(x, axes if len(axes) > 1 else axes[0])


def pmax(x, axes: Axes):
    """lax.pmax over `axes`; identity when axes is empty.  (The telemetry
    monitors use it for global max-weight / freshest-stamp reductions.)"""
    if not axes:
        return x
    return jax.lax.pmax(x, axes if len(axes) > 1 else axes[0])


def axis_size(ax: str) -> int:
    """Static size of a mapped axis (psum-of-1 constant-folds on every
    jax version; jax.lax.axis_size only exists on newer ones)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)


def axis_info(axes: Axes) -> tuple[jax.Array, int]:
    """(linear device id over `axes`, static total device count)."""
    if not axes:
        return jnp.zeros((), jnp.int32), 1
    dev = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        size = axis_size(ax)
        dev = dev * size + jax.lax.axis_index(ax)
        n *= size
    return dev, n


def psum_backward(x, axes: Axes):
    """Identity forward, psum-over-`axes` backward — Megatron's "f" operator.

    Wrap the (replicated) input of a linear whose weight is column-sharded
    over the model axes: the forward passes the activation through
    untouched, but the cotangent arriving from the sharded matmul is only
    this device's partial contribution (dy_local @ w_localᵀ), so the
    backward psums it into the exact full input-gradient.  With axes=()
    this is the identity in both directions."""
    axes = tuple(axes)
    if not axes:
        return x

    @jax.custom_vjp
    def f(x):
        return x

    f.defvjp(lambda x: (x, None), lambda _, ct: (psum(ct, axes),))
    return f(x)


def psum_forward(x, axes: Axes):
    """Psum forward, identity backward — Megatron's row-parallel reduce.

    Wrap the *partial* output of a linear whose weight is row-sharded over
    the model axes (its input was a local column slice): the forward psums
    the per-device partials into the exact full output, and the backward
    hands each device the (replicated) cotangent untouched — which is the
    exact gradient for its local partial, because every consumer of the
    psum'd output is replicated over `axes`.  Only valid under that
    replicated-consumer contract (the transpose pair of `psum_backward`,
    the way `all_gather_replicated` pairs with a slice).  With axes=()
    this is the identity in both directions."""
    axes = tuple(axes)
    if not axes:
        return x

    @jax.custom_vjp
    def f(x):
        return psum(x, axes)

    f.defvjp(lambda x: (psum(x, axes), None), lambda _, ct: (ct,))
    return f(x)


def scatter_seq(x, axes: Axes, axis: int = 1):
    """Slice this device's chunk of dim `axis` from a replicated array —
    the entry into a sequence-parallel segment (Megatron-SP style).

    Forward: each device of `axes` keeps its own contiguous chunk (linear
    device-id order, matching `all_gather_replicated`'s tiling, so
    ``all_gather_replicated(scatter_seq(x))`` is the identity).  Backward:
    the per-chunk cotangents are embedded at their offsets and psum'd over
    `axes`, reconstituting the *replicated* full cotangent — each chunk's
    gradient lives on exactly one device, so the psum is an exact
    disjoint-support sum, and everything upstream (residual stream, layer
    norms, embeddings) keeps receiving replicated cotangents.  With
    axes=() this is the identity."""
    axes = tuple(axes)
    if not axes:
        return x
    full = x.shape[axis]

    @jax.custom_vjp
    def f(x):
        dev, n = axis_info(axes)
        local = full // n
        return jax.lax.dynamic_slice_in_dim(x, dev * local, local, axis)

    def fwd(x):
        return f(x), None

    def bwd(_, ct):
        dev, n = axis_info(axes)
        local = full // n
        shape = list(ct.shape)
        shape[axis] = full
        z = jnp.zeros(shape, ct.dtype)
        z = jax.lax.dynamic_update_slice_in_dim(z, ct, dev * local, axis)
        return (psum(z, axes),)

    f.defvjp(fwd, bwd)
    return f(x)


def all_gather_replicated(x, axes: Axes, axis: int = -1):
    """All-gather `x` along dim `axis` over mesh `axes`, for a *replicated
    consumer* — Megatron's "g" operator, transpose-paired with
    `psum_backward`.

    Chunks are tiled in linear-device-id order, matching the contiguous
    layout NamedSharding gives a dim sharded over `axes`.  The custom
    backward slices the device's own chunk of the cotangent instead of the
    default psum-scatter: everything downstream of the gather is computed
    redundantly on every device of `axes` (replicated loss), so the
    per-device cotangents are identical and the default transpose would
    overcount by the axis size.  Only valid under that replicated-consumer
    contract.  With axes=() this is the identity."""
    axes = tuple(axes)
    if not axes:
        return x
    local = x.shape[axis]

    @jax.custom_vjp
    def gather(x):
        y = x
        for ax in reversed(axes):  # innermost axis first → id-order tiling
            y = jax.lax.all_gather(y, ax, axis=axis, tiled=True)
        return y

    def fwd(x):
        return gather(x), None

    def bwd(_, ct):
        dev, _ = axis_info(axes)
        return (jax.lax.dynamic_slice_in_dim(ct, dev * local, local, axis),)

    gather.defvjp(fwd, bwd)
    return gather(x)


def gather_rows(arrays: Any, idx: jax.Array, axes: Axes) -> Any:
    """Gather rows at *global* indices `idx` from example-axis-sharded
    arrays; the result is replicated (identical on every device).

    arrays: pytree whose leaves are local shards with a common leading
    example axis.  With axes=() this is exactly ``leaf[idx]``.
    """
    from repro.data.pipeline import take_rows
    dev_id, _ = axis_info(axes)

    def one(a):
        n_local = a.shape[0]
        lidx = idx - dev_id * n_local
        mine = (lidx >= 0) & (lidx < n_local)
        # explicit clip: foreign rows clamp in-shard and are masked to zero
        # below, so the clamped value never escapes the psum
        rows = take_rows(a, lidx, mode="clip")
        mask = mine.reshape((-1,) + (1,) * (rows.ndim - 1))
        return psum(jnp.where(mask, rows, jnp.zeros_like(rows)), axes)

    return jax.tree.map(one, arrays)


def scatter_rows(array: jax.Array, idx: jax.Array, values: jax.Array,
                 axes: Axes) -> jax.Array:
    """Write `values` at *global* indices `idx` into an example-axis-sharded
    array; each device applies only the writes it owns (others drop).

    Duplicate indices follow **last-write-wins** semantics: fused-mode
    minibatches sample with replacement, and XLA's scatter leaves the order
    of colliding updates unspecified, so every occurrence except the last is
    dropped before the scatter (deterministic on every backend)."""
    dev_id, _ = axis_info(axes)
    n_local = array.shape[0]
    lidx = idx - dev_id * n_local
    mine = (lidx >= 0) & (lidx < n_local)
    # i-th write survives only if no j > i targets the same index
    dup_later = jnp.triu(idx[:, None] == idx[None, :], k=1)
    is_last = ~jnp.any(dup_later, axis=1)
    safe = jnp.where(mine & is_last, lidx, n_local)  # out of bounds → dropped
    return array.at[safe].set(values.astype(array.dtype), mode="drop")

"""Axis-polymorphic collectives — the one-code-path primitive layer.

Every ISSGD step helper is written against a tuple of mesh axis names
`axes`.  Inside ``shard_map`` the tuple names real mesh axes and these
helpers lower to psums; with ``axes=()`` (single device, no shard_map)
they degenerate to exact local arithmetic.  That is what makes the
single-device train step literally the mesh-size-1 special case of the
sharded one rather than a second implementation.

The gather/scatter helpers assume the standard contiguous layout for an
example-axis array sharded over `axes`: global index ``g`` lives on the
device with linear id ``g // n_local`` at local offset ``g % n_local``.
Cross-device reads are one-owner masked psums (the non-owners contribute
exact zeros, so the combined value is bitwise the owner's row — this is
what keeps sharded and single-device runs numerically identical).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Axes = tuple


def psum(x, axes: Axes):
    """lax.psum over `axes`; identity when axes is empty."""
    if not axes:
        return x
    return jax.lax.psum(x, axes if len(axes) > 1 else axes[0])


def axis_size(ax: str) -> int:
    """Static size of a mapped axis (psum-of-1 constant-folds on every
    jax version; jax.lax.axis_size only exists on newer ones)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)


def axis_info(axes: Axes) -> tuple[jax.Array, int]:
    """(linear device id over `axes`, static total device count)."""
    if not axes:
        return jnp.zeros((), jnp.int32), 1
    dev = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        size = axis_size(ax)
        dev = dev * size + jax.lax.axis_index(ax)
        n *= size
    return dev, n


def gather_rows(arrays: Any, idx: jax.Array, axes: Axes) -> Any:
    """Gather rows at *global* indices `idx` from example-axis-sharded
    arrays; the result is replicated (identical on every device).

    arrays: pytree whose leaves are local shards with a common leading
    example axis.  With axes=() this is exactly ``leaf[idx]``.
    """
    from repro.data.pipeline import take_rows
    dev_id, _ = axis_info(axes)

    def one(a):
        n_local = a.shape[0]
        lidx = idx - dev_id * n_local
        mine = (lidx >= 0) & (lidx < n_local)
        # explicit clip: foreign rows clamp in-shard and are masked to zero
        # below, so the clamped value never escapes the psum
        rows = take_rows(a, lidx, mode="clip")
        mask = mine.reshape((-1,) + (1,) * (rows.ndim - 1))
        return psum(jnp.where(mask, rows, jnp.zeros_like(rows)), axes)

    return jax.tree.map(one, arrays)


def scatter_rows(array: jax.Array, idx: jax.Array, values: jax.Array,
                 axes: Axes) -> jax.Array:
    """Write `values` at *global* indices `idx` into an example-axis-sharded
    array; each device applies only the writes it owns (others drop).

    Duplicate indices follow **last-write-wins** semantics: fused-mode
    minibatches sample with replacement, and XLA's scatter leaves the order
    of colliding updates unspecified, so every occurrence except the last is
    dropped before the scatter (deterministic on every backend)."""
    dev_id, _ = axis_info(axes)
    n_local = array.shape[0]
    lidx = idx - dev_id * n_local
    mine = (lidx >= 0) & (lidx < n_local)
    # i-th write survives only if no j > i targets the same index
    dup_later = jnp.triu(idx[:, None] == idx[None, :], k=1)
    is_last = ~jnp.any(dup_later, axis=1)
    safe = jnp.where(mine & is_last, lidx, n_local)  # out of bounds → dropped
    return array.at[safe].set(values.astype(array.dtype), mode="drop")

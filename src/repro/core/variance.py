"""Trace-of-covariance monitors (paper eqs. 6-9 and appendix B.2).

Given per-example gradient norms g_n = ||grad L(x_n)||_2 over (a shard of)
the training set and the proposal weights ω̃_n actually used, these compute

    Tr(Σ(q))       = (1/N Σ ω̃_n)(1/N Σ g_n²/ω̃_n) − ||g_TRUE||²     (eq. 6)
    Tr(Σ(q_IDEAL)) = (1/N Σ g_n)² − ||g_TRUE||²                      (eq. 7)
    Tr(Σ(q_UNIF))  = 1/N Σ g_n² − ||g_TRUE||²                        (eq. 8)
    Tr(Σ(q_STALE)) = (1/N Σ ω̃_n^OLD)(1/N Σ g_n²/ω̃_n^OLD) − ||g_TRUE||²  (eq. 9)

All functions take optional precomputed partial sums so distributed callers
can psum shard-local reductions first; on a single host just call them
directly with full arrays.

||g_TRUE||² is approximated per B.2 by the squared norm of minibatch-mean
gradients (an upper bound on the true value — identical additive constant in
all three monitors, so the *ordering* claims of the paper are preserved
exactly regardless of the approximation).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class TraceSigma(NamedTuple):
    """Tr Σ(q) under the ideal, stale, and uniform proposals (fig. 4)."""
    ideal: jax.Array
    stale: jax.Array
    unif: jax.Array


def _mean(x: jax.Array, n: Optional[jax.Array] = None) -> jax.Array:
    if n is None:
        return jnp.mean(x)
    return jnp.sum(x) / n


def trace_sigma(
    grad_norms: jax.Array,
    weights: jax.Array,
    g_true_sq: jax.Array | float = 0.0,
    n_total: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. 6 / Corollary 1: Tr(Σ(q)) for q ∝ ω̃ (weights need not be fresh)."""
    w_mean = _mean(weights, n_total)
    ratio_mean = _mean(jnp.square(grad_norms) / jnp.maximum(weights, 1e-30), n_total)
    return w_mean * ratio_mean - g_true_sq


def trace_sigma_ideal(
    grad_norms: jax.Array,
    g_true_sq: jax.Array | float = 0.0,
    n_total: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. 7: the lower bound, achieved by ω̃_n = g_n (fresh oracle)."""
    return jnp.square(_mean(grad_norms, n_total)) - g_true_sq


def trace_sigma_unif(
    grad_norms: jax.Array,
    g_true_sq: jax.Array | float = 0.0,
    n_total: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. 8: plain SGD (uniform proposal)."""
    return _mean(jnp.square(grad_norms), n_total) - g_true_sq


def trace_sigma_all(
    grad_norms: jax.Array,
    stale_weights: jax.Array,
    g_true_sq: jax.Array | float = 0.0,
    n_total: Optional[jax.Array] = None,
) -> TraceSigma:
    """The three monitors of figure 4, sharing one ||g_TRUE||² estimate."""
    return TraceSigma(
        ideal=trace_sigma_ideal(grad_norms, g_true_sq, n_total),
        stale=trace_sigma(grad_norms, stale_weights, g_true_sq, n_total),
        unif=trace_sigma_unif(grad_norms, g_true_sq, n_total),
    )


def trace_sigma_all_dist(
    grad_norms: jax.Array,
    stale_weights: jax.Array,
    axes: tuple[str, ...],
    n_total: jax.Array | int,
    g_true_sq: jax.Array | float = 0.0,
) -> TraceSigma:
    """Figure-4 monitors over a *sharded* scored slice: shard-local partial
    sums are psummed over `axes` before the eq. 6-9 formulas.  With
    axes=() this equals `trace_sigma_all` on the full arrays."""
    from repro.core.collectives import psum
    g = grad_norms.astype(jnp.float32)
    w = stale_weights.astype(jnp.float32)
    n = jnp.asarray(n_total, jnp.float32)
    sum_g = psum(jnp.sum(g), axes)
    sum_g2 = psum(jnp.sum(jnp.square(g)), axes)
    sum_w = psum(jnp.sum(w), axes)
    sum_ratio = psum(jnp.sum(jnp.square(g) / jnp.maximum(w, 1e-30)), axes)
    return TraceSigma(
        ideal=jnp.square(sum_g / n) - g_true_sq,
        stale=(sum_w / n) * (sum_ratio / n) - g_true_sq,
        unif=sum_g2 / n - g_true_sq,
    )


def g_true_sq_upper_bound(minibatch_mean_grad_norms: jax.Array) -> jax.Array:
    """B.2: average of per-minibatch mean-gradient norms, squared.

    By Jensen this upper-bounds ||g_TRUE||₂ (the norm of the full-train-set
    mean gradient); near convergence both go to ~0 and the three Tr(Σ)
    monitors become exact.
    """
    return jnp.square(jnp.mean(minibatch_mean_grad_norms))

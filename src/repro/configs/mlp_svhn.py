"""The paper's own model (§5.1): permutation-invariant SVHN MLP,
4 hidden layers × 2048 ReLU units, softmax over 10 digits."""
import dataclasses

from repro.models.mlp import MLPConfig

CONFIG = MLPConfig(
    name="mlp_svhn",
    input_dim=3072,
    num_classes=10,
    hidden=(2048, 2048, 2048, 2048),
)


def smoke() -> MLPConfig:
    return dataclasses.replace(CONFIG, name="mlp_svhn-smoke",
                               input_dim=64, hidden=(128, 128))

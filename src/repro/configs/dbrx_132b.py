"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    num_experts_per_tok=4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="dbrx-132b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, dtype="float32")

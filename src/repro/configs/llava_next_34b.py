"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/SigLIP vision tower + projector is a STUB per the brief:
input_specs() provides `embeds` — anyres patch embeddings of shape
(B, num_frontend_tokens, d_model) prepended to the text tokens.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    num_frontend_tokens=2880,  # anyres: base 576 + 4 tiles × 576
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-next-34b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        num_frontend_tokens=16, dtype="float32")

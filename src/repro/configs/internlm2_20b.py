"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.  [arXiv:2403.17297]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-20b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        dtype="float32")

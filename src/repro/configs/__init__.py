"""Architecture registry: the 10 assigned architectures + the paper's MLP.

Each module exposes CONFIG (the exact assigned spec) and smoke() (a reduced
same-family variant for CPU tests).  ``get_config(name)`` /
``get_smoke_config(name)`` / ``ARCH_NAMES`` are the public API; the
launcher's --arch flag resolves through here.
"""
from __future__ import annotations

import importlib

ARCH_NAMES = (
    "grok_1_314b",
    "deepseek_7b",
    "minicpm3_4b",
    "glm4_9b",
    "musicgen_medium",
    "jamba_v0_1_52b",
    "dbrx_132b",
    "llava_next_34b",
    "internlm2_20b",
    "falcon_mamba_7b",
)

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}
_ALIASES.update({
    "grok-1-314b": "grok_1_314b",
    "deepseek-7b": "deepseek_7b",
    "minicpm3-4b": "minicpm3_4b",
    "glm4-9b": "glm4_9b",
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "dbrx-132b": "dbrx_132b",
    "llava-next-34b": "llava_next_34b",
    "internlm2-20b": "internlm2_20b",
    "falcon-mamba-7b": "falcon_mamba_7b",
})


def _module(name: str):
    key = _ALIASES.get(name, name)
    if key not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).smoke()

"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-4b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=8, d_ff=512, vocab_size=512,
        q_lora_rank=96, kv_lora_rank=64, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, dtype="float32")

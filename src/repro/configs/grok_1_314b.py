"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    logits_softcap=30.0,     # grok uses output softcapping
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="grok-1-314b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, dtype="float32")

"""falcon-mamba-7b [ssm] — 64L d_model=4096 attn-free d_ff=0 vocab=65024,
ssm_state=16, mamba-1 arch.  [arXiv:2410.05355]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,            # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                 # mamba blocks only, no FF sub-layer
    vocab_size=65024,
    attention="none",
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-7b-smoke", num_layers=2, d_model=256,
        vocab_size=512, ssm_state=8, d_inner=512, dtype="float32")

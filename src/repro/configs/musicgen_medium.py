"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048, decoder-only over EnCodec tokens.  [arXiv:2306.05284]

The EnCodec/conditioning frontend is a STUB per the brief: input_specs()
provides `embeds` — precomputed conditioning-frame embeddings of shape
(B, num_frontend_tokens, d_model) prepended to the token stream.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    frontend="audio",
    num_frontend_tokens=64,   # text/melody conditioning stub
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-medium-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=8, d_ff=512, vocab_size=512,
        num_frontend_tokens=8, dtype="float32")

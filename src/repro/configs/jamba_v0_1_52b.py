"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 1:7 interleave.
[arXiv:2403.19887]

Period structure (8 layers): attention at offset 4 of each block, MoE on
every other layer — matching the published interleave.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
    attn_every=8,
    attn_offset=4,
)


def smoke() -> ModelConfig:
    # 2-layer period preserving the family: l0 = mamba+MLP, l1 = attn+MoE
    return dataclasses.replace(
        CONFIG, name="jamba-v0.1-52b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, ssm_state=8, d_inner=512,
        attn_every=2, attn_offset=1, moe_every=2, moe_offset=1,
        dtype="float32")

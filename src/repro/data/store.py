"""Host-resident chunked example store — the dataset half of the paper's
"too big for one place" premise.

The paper keeps the training set and its importance-weight database out of
the master's memory: workers sweep the full dataset, the master touches
only the sampled minibatch.  `ChunkedExampleStore` is the dataset-side
equivalent of the sharded WeightStore: examples live in host memory as
fixed-size numpy chunks with a stable global index space

    global index g  ->  chunk g // chunk_size, offset g % chunk_size

and each data-axis shard owns a *contiguous* chunk range (shard d of D
owns chunks [d·K, (d+1)·K) with K = num_chunks // D), mirroring the
contiguous-block layout of core/collectives.py so the same
index-arithmetic resolves rows on both sides.

Device residency is someone else's job: data/streaming.py keeps a bounded
window of chunks on device and fetches the rest from here in batched,
chunk-grouped reads.  On a multi-host pod each host would hold only its
own chunk range (the ranges are the unit of cross-host ownership); in the
single-host container every range is local, same code path.
"""
from __future__ import annotations

from typing import Iterator, Mapping

import numpy as np

from repro.core.sampler import index_to_chunk


class ChunkedExampleStore:
    """Fixed-size host-memory chunks of an example-axis array tree."""

    def __init__(self, chunks: list[dict[str, np.ndarray]], chunk_size: int):
        if not chunks:
            raise ValueError("need at least one chunk")
        self.chunk_size = int(chunk_size)
        self._chunks = chunks
        for c, chunk in enumerate(chunks):
            for k, v in chunk.items():
                if v.shape[0] != self.chunk_size:
                    raise ValueError(
                        f"chunk {c} array {k!r} has {v.shape[0]} rows, "
                        f"expected chunk_size={self.chunk_size}")

    @classmethod
    def from_arrays(cls, arrays: Mapping[str, np.ndarray],
                    chunk_size: int) -> "ChunkedExampleStore":
        """Chunk an array tree (jax or numpy) into host memory.  Each chunk
        is its own contiguous allocation — after this, nothing references
        the monolithic arrays."""
        host = {k: np.asarray(v) for k, v in arrays.items()}
        n = next(iter(host.values())).shape[0]
        for k, v in host.items():
            if v.shape[0] != n:
                raise ValueError(f"array {k!r} has {v.shape[0]} rows, "
                                 f"others have {n}")
        if chunk_size <= 0 or n % chunk_size:
            raise ValueError(f"chunk_size={chunk_size} must divide the "
                             f"example count {n}")
        chunks = [
            {k: np.ascontiguousarray(v[c * chunk_size:(c + 1) * chunk_size])
             for k, v in host.items()}
            for c in range(n // chunk_size)
        ]
        return cls(chunks, chunk_size)

    # ---- shape / layout ---------------------------------------------------

    @property
    def num_chunks(self) -> int:
        """Total host chunks (global index space = chunks x chunk_size)."""
        return len(self._chunks)

    @property
    def num_examples(self) -> int:
        """Total examples across all chunks."""
        return self.num_chunks * self.chunk_size

    @property
    def keys(self) -> tuple[str, ...]:
        """The per-example array names (dataset tree keys)."""
        return tuple(self._chunks[0].keys())

    def row_shape(self, key: str) -> tuple:
        """Trailing (per-row) shape of array `key`."""
        return self._chunks[0][key].shape[1:]

    def dtype(self, key: str) -> np.dtype:
        """Dtype of array `key`."""
        return self._chunks[0][key].dtype

    def nbytes(self) -> int:
        """Total host bytes across chunks (capacity accounting)."""
        return sum(v.nbytes for c in self._chunks for v in c.values())

    def shard_chunks(self, shard: int, n_shards: int) -> range:
        """The contiguous chunk range shard `shard` of `n_shards` owns."""
        if self.num_chunks % n_shards:
            raise ValueError(f"num_chunks={self.num_chunks} not divisible "
                             f"by {n_shards} shards")
        per = self.num_chunks // n_shards
        if not 0 <= shard < n_shards:
            raise ValueError(f"shard {shard} out of range({n_shards})")
        return range(shard * per, (shard + 1) * per)

    def owner_shard(self, chunk: int | np.ndarray, n_shards: int):
        """Which shard owns a chunk (vectorized over arrays)."""
        per = self.num_chunks // n_shards
        return chunk // per

    # ---- growth (serving-loop traffic ingest) -----------------------------

    def zeros_chunk(self) -> dict[str, np.ndarray]:
        """A fresh all-zero chunk matching this store's schema."""
        return {k: np.zeros((self.chunk_size,) + self.row_shape(k),
                            dtype=self.dtype(k)) for k in self.keys}

    def append_chunk(self, chunk: dict[str, np.ndarray] | None = None) -> int:
        """Append one chunk (default: zeros) and return its chunk id.

        The global index space extends stably — existing rows keep their
        indices.  Sharded runs must append *before* chunk ownership is
        laid out (shard ranges are contiguous slices of num_chunks, so
        growing the tail would remap every shard's range): the serving
        loop pre-reserves its traffic capacity up front and fills rows in
        place with `write_rows`."""
        chunk = chunk if chunk is not None else self.zeros_chunk()
        if set(chunk.keys()) != set(self.keys):
            raise ValueError(f"chunk keys {sorted(chunk)} != store keys "
                             f"{sorted(self.keys)}")
        for k, v in chunk.items():
            want = (self.chunk_size,) + self.row_shape(k)
            if v.shape != want or v.dtype != self.dtype(k):
                raise ValueError(
                    f"chunk array {k!r} is {v.shape}/{v.dtype}, expected "
                    f"{want}/{self.dtype(k)}")
        self._chunks.append({k: np.ascontiguousarray(v)
                             for k, v in chunk.items()})
        return self.num_chunks - 1

    def write_rows(self, global_idx: np.ndarray,
                   rows: Mapping[str, np.ndarray]) -> None:
        """Batched host write at arbitrary global indices (chunk-grouped,
        the scatter mirror of `fetch_rows`) — the traffic-ingest path."""
        gidx = np.asarray(global_idx).reshape(-1)
        if gidx.size and (gidx.min() < 0 or gidx.max() >= self.num_examples):
            bad = gidx[(gidx < 0) | (gidx >= self.num_examples)]
            raise IndexError(f"indices out of range [0, {self.num_examples})"
                             f": {bad[:8]}")
        cidx, off = index_to_chunk(gidx, self.chunk_size)
        for c in np.unique(cidx):
            sel = cidx == c
            chunk = self._chunks[int(c)]
            for k in self.keys:
                chunk[k][off[sel]] = np.asarray(rows[k])[sel]

    # ---- reads ------------------------------------------------------------

    def chunk(self, c: int) -> dict[str, np.ndarray]:
        """One chunk's array tree (zero-copy host view)."""
        return self._chunks[c]

    def iter_chunks(self, chunks: range | None = None
                    ) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        """Yield (chunk_id, chunk tree) over `chunks` (default: all)."""
        for c in (chunks if chunks is not None else range(self.num_chunks)):
            yield c, self._chunks[c]

    def fetch_rows(self, global_idx: np.ndarray) -> dict[str, np.ndarray]:
        """Batched host read at arbitrary global indices, grouped by chunk
        so each chunk is touched once (the paper's workers sweep chunk by
        chunk; random row reads only pay one fancy-index per *distinct*
        chunk).  Rows come back in the order of `global_idx`."""
        gidx = np.asarray(global_idx).reshape(-1)
        if gidx.size and (gidx.min() < 0 or gidx.max() >= self.num_examples):
            bad = gidx[(gidx < 0) | (gidx >= self.num_examples)]
            raise IndexError(f"indices out of range [0, {self.num_examples})"
                             f": {bad[:8]}")
        cidx, off = index_to_chunk(gidx, self.chunk_size)
        out = {k: np.empty((gidx.size,) + self.row_shape(k),
                           dtype=self.dtype(k)) for k in self.keys}
        for c in np.unique(cidx):
            sel = cidx == c
            chunk = self._chunks[int(c)]
            for k in self.keys:
                out[k][sel] = chunk[k][off[sel]]
        return out

    def stack_chunks(self, chunks: list[int] | np.ndarray
                     ) -> dict[str, np.ndarray]:
        """Concatenate whole chunks in the given order (window assembly)."""
        ids = [int(c) for c in chunks]
        return {k: np.concatenate([self._chunks[c][k] for c in ids], axis=0)
                for k in self.keys}

"""Streaming data plane: host-resident dataset, proposal-aware device window.

The third sharded resource after the WeightStore and the mesh.  The paper's
premise is that the training set is too large to sit next to the master:
workers sweep it for informative examples, the master touches only the
sampled minibatch.  `ArrayDataset` keeps every example device-resident,
which caps dataset size at device memory; this module lifts that cap:

  ChunkedExampleStore (data/store.py)
      examples live in host memory as fixed-size numpy chunks with a
      stable global index space, each data-axis shard owning a contiguous
      chunk range;

  StreamingDataPlane
      keeps a bounded device-resident **working-set window** of chunks per
      shard, resolves sampled indices with a *two-level gather* — an
      on-device hit for rows in hot chunks (the one-owner masked-psum
      gather of core/collectives.py over the window), a batched
      chunk-grouped host fetch for misses — and prefetches the next window
      double-buffered off the proposal distribution: the chunks carrying
      the most proposal mass are device-resident before they are drawn;

  StreamedISSGD
      the host driver.  The fused/async ISSGD step is split into three
      device programs, none of which ever takes the dataset as an input:

        scoring_step(θ_stale, store, t, score_slice_rows)   shard-local
        sample_step(store, t, rng) -> (idx, chunk_mass)     the draw
        master_step(..., store, t, rng, minibatch_rows)     the update

      Scoring sweeps *stream* chunk rows through each device round-robin
      (the schedule is `issgd._score_slice`, replayed on the host in
      numpy), so rescoring covers the full dataset without materializing
      it on device — the dataset-side mirror of the no-full-table
      guarantee for the f32[N] weight table.  The sampled indices are
      drawn on device from the store, synced to the host, resolved through
      the window, and the gathered minibatch is fed back in.

Bitwise invariant (pinned in tests/test_streaming.py): a streamed run is
same-seed *bitwise identical* to the device-resident run in every mode
(relaxed / fused / async, any mesh that divides the chunk layout).  The
scoring rows, the minibatch rows, and the sampled indices are the same
bits whether they arrive from the resident dataset, the window, or a host
fetch; which chunks happen to be hot changes only *where* rows come from,
never their values — so window policy is pure performance, not numerics.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.async_pipeline import score_trace_metrics
from repro.core.collectives import axis_info, gather_rows
from repro.core.issgd import (ISSGDConfig, StepMetrics, TrainState,
                              make_master_pass, make_scoring_pass,
                              scoring_layout)
from repro.core.sampler import chunk_proposal_mass, index_to_chunk
from repro.core.weight_store import (BufferedWeightStore, WeightStore,
                                     publish)
from repro.data.store import ChunkedExampleStore


def host_score_slice(step: int, w_loc: int, n_w: int, sb_w: int) -> np.ndarray:
    """Numpy twin of ``issgd._score_slice``: the local indices of step
    `step`'s round-robin scoring slice.  The host scheduler replays the
    device formula exactly so the streamed rows land at the indices the
    scoring pass will write."""
    base = (step * sb_w + np.arange(sb_w)) % n_w
    return (np.arange(w_loc)[:, None] * n_w + base[None, :]).reshape(-1)


# ---------------------------------------------------------------------------
# device programs
# ---------------------------------------------------------------------------

def make_streamed_steps(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer,
    cfg: ISSGDConfig,
    num_examples: int,
    chunk_size: int,
    aux_loss: Optional[Callable] = None,
    fused_score: Optional[Callable] = None,
    constrain_batch: Optional[Callable] = None,
    axes: tuple[str, ...] = (),
    model_axes: tuple[str, ...] = (),
    param_pspecs=None,
    async_mode: bool = False,
    monitor_traces: bool = True,
    monitors=None,
    gated: bool = False,
) -> tuple[Callable, Callable, Callable]:
    """The three device programs of the streamed ISSGD step.

    Returns ``(scoring_step, sample_step, master_step)``:

      scoring_step(score_params, store, step, score_rows)
          -> (store', fresh_scores, stale_slice, ScoreMetrics)
      sample_step(store, step, rng) -> (idx, chunk_mass)
      master_step(params, opt_state, stale_params, store, step, rng,
                  batch_rows[, fresh_scores, stale_slice])
          -> (params', opt_state', stale_params', store', step+1, rng',
              StepMetrics)

    None of the programs takes the dataset: ``score_rows`` is this step's
    pre-gathered round-robin slice, ``batch_rows`` the pre-gathered
    sampled minibatch.  ``sample_step`` performs the identical proposal
    read + two-stage draw the master will re-run, so host and device agree
    on the indices without a device→host→device round-trip inside the
    master program; it additionally buckets the proposal into per-chunk
    mass (one psum of a num_chunks-float vector) — the prefetch signal.

    In the sync composition (``async_mode=False``) the master receives the
    fresh scores for the fig-4 monitors, exactly like the fused step; in
    async mode (relaxed/uniform only) the monitors ride with the scoring
    step (``monitor_traces``), the master's traces come back NaN, and the
    two programs share no buffers — the AsyncPipeline discipline over the
    double-buffered store, with the fan-out's rows host-streamed.

    With a non-empty ``monitors`` (telemetry.MonitorSet) the master step
    grows one trailing ``{name: scalar}`` proposal-health output — see
    make_async_steps; ``master_step.with_monitors`` records the arity
    (capture before jax.jit, which drops function attributes).

    With ``gated=True`` (mode="relaxed" only) BOTH the sample step and
    the master step take one extra trailing ``use_is`` device-bool — the
    adaptive controller's uniform↔IS gate.  The two programs replay the
    same draw, so they must see the same gate value for a step; the
    driver (StreamedISSGD) appends the controller's scalar to both
    dispatches.  ``master_step.gated`` records the arity pre-jit.
    """
    if cfg.mode == "exact":
        raise ValueError(
            "mode='exact' rescores the full dataset every step, which "
            "requires it device-resident — streaming is pointless there; "
            "use the ArrayDataset path")
    if async_mode and cfg.mode not in ("relaxed", "uniform"):
        raise ValueError(
            "async streaming supports mode='relaxed'/'uniform' (fused "
            f"already merges the passes), got {cfg.mode!r}")
    if num_examples % chunk_size:
        raise ValueError(f"chunk_size={chunk_size} must divide "
                         f"num_examples={num_examples}")
    axes = tuple(axes)
    monitors = monitors or None
    n = num_examples
    sb = cfg.score_batch_size
    # the master reads the fresh scores only in the sync non-fused
    # composition; fused computes its own, async leaves them to scoring
    expect_scores = (not async_mode) and cfg.mode != "fused"
    traces_in_scoring = async_mode and monitor_traces

    scoring_pass = make_scoring_pass(scorer, cfg, n, constrain_batch, axes,
                                     streaming=True)
    master_pass = make_master_pass(per_example_loss, optimizer, cfg, n,
                                   aux_loss=aux_loss,
                                   fused_score=fused_score,
                                   constrain_batch=constrain_batch,
                                   axes=axes, model_axes=model_axes,
                                   param_pspecs=param_pspecs, streaming=True,
                                   monitors=monitors, gated=gated)

    def scoring_step(score_params, store: WeightStore, step, score_rows):
        store, fresh_scores, stale_slice = scoring_pass(
            score_params, store, step, score_rows)
        smetrics = score_trace_metrics(fresh_scores, stale_slice, axes,
                                       n_total=sb,
                                       monitor=traces_in_scoring)
        return store, fresh_scores, stale_slice, smetrics

    def _sample(store: WeightStore, step, rng, use_is):
        from repro.core.issgd import read_sampling_proposal, stage1_block_sums
        from repro.core.sampler import two_stage_sample
        _, k_sample = jax.random.split(rng)          # master's split, replayed
        _, n_dev = axis_info(axes)
        w_loc, n_w, _ = scoring_layout(cfg, n, n_dev)
        # the exact proposal the master samples from (incl. TTL decay and
        # dequantization) — the replay must transform it identically
        proposal = read_sampling_proposal(store, step, cfg, n_w)
        if cfg.mode == "uniform":
            idx = jax.random.randint(k_sample, (cfg.batch_size,), 0, n)
        elif gated:
            # replicate the gated master's selection bit-for-bit (issgd)
            idx_u = jax.random.randint(k_sample, (cfg.batch_size,), 0, n)
            idx_is = two_stage_sample(k_sample, proposal, cfg.batch_size,
                                      axes=axes, shards_per_device=w_loc,
                                      block_sums=stage1_block_sums(
                                          proposal, w_loc, cfg))
            idx = jnp.where(use_is, idx_is, idx_u)
        else:
            idx = two_stage_sample(k_sample, proposal, cfg.batch_size,
                                   axes=axes, shards_per_device=w_loc,
                                   block_sums=stage1_block_sums(
                                       proposal, w_loc, cfg))
        mass = chunk_proposal_mass(proposal, chunk_size, axes)
        return idx, mass

    if gated:
        def sample_step(store: WeightStore, step, rng, use_is):
            return _sample(store, step, rng, use_is)
    else:
        def sample_step(store: WeightStore, step, rng):
            return _sample(store, step, rng, None)

    def _run_master(params, opt_state, stale_params, store, step, rng,
                    batch_rows, fresh_scores=None, stale_slice=None,
                    use_is=None):
        rng, k_sample = jax.random.split(rng)
        params, opt_state, stale_params, store, metrics, *mon = \
            master_pass(params, opt_state, stale_params, store, step,
                        k_sample, batch_rows, fresh_scores, stale_slice,
                        use_is)
        out = (params, opt_state, stale_params, store, step + 1, rng,
               metrics)
        return out + (mon[0],) if monitors else out

    if expect_scores and gated:
        def master_step(params, opt_state, stale_params, store, step, rng,
                        batch_rows, fresh_scores, stale_slice, use_is):
            return _run_master(params, opt_state, stale_params, store, step,
                               rng, batch_rows, fresh_scores, stale_slice,
                               use_is)
    elif expect_scores:
        def master_step(params, opt_state, stale_params, store, step, rng,
                        batch_rows, fresh_scores, stale_slice):
            return _run_master(params, opt_state, stale_params, store, step,
                               rng, batch_rows, fresh_scores, stale_slice)
    elif gated:
        def master_step(params, opt_state, stale_params, store, step, rng,
                        batch_rows, use_is):
            return _run_master(params, opt_state, stale_params, store, step,
                               rng, batch_rows, use_is=use_is)
    else:
        def master_step(params, opt_state, stale_params, store, step, rng,
                        batch_rows):
            return _run_master(params, opt_state, stale_params, store, step,
                               rng, batch_rows)

    master_step.expect_scores = expect_scores
    master_step.with_monitors = bool(monitors)
    master_step.gated = bool(gated)
    sample_step.gated = bool(gated)
    return scoring_step, sample_step, master_step


# ---------------------------------------------------------------------------
# the data plane
# ---------------------------------------------------------------------------

class WindowStats(NamedTuple):
    """Cumulative two-level-gather counters (benchmarks read these)."""
    hits: int
    misses: int
    streamed_rows: int     # rows host-fetched for scoring sweeps
    swaps: int
    prefetches: int

    @property
    def hit_rate(self) -> float:
        """Fraction of sampled rows served from the device window."""
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


class StreamingDataPlane:
    """Bounded device window over a ChunkedExampleStore.

    Owns three responsibilities, all value-transparent (the bits of every
    row are identical whichever path serves it):

      * ``gather_global(idx)`` — the two-level gather.  Rows whose chunk
        is in the window are gathered on device (one-owner masked psum on
        a mesh, plain in-bounds gather on one device); the rest are
        fetched from the host store grouped by chunk and device_put once.
      * ``fetch_sharded(idx_per_shard)`` — the scoring stream: each
        shard's round-robin slice is read from host chunks and placed
        directly as the sharded score batch.  Scoring never goes through
        the window — it *is* the stream that sweeps the dataset.
      * ``prefetch(chunk_mass)`` / ``swap_window()`` — proposal-aware
        double-buffered window refresh.  ``prefetch`` assembles the next
        window (top-`window_chunks` chunks per shard by proposal mass,
        ties broken toward lower chunk ids) into a *pending* buffer while
        the current window keeps serving gathers; ``swap_window`` flips
        the buffers at a step boundary.  Eviction is implicit: a chunk
        not in the new top-K simply isn't in the next buffer.

    The window is one global device array tree of
    ``n_shards · window_chunks · chunk_size`` rows, example-axis-sharded
    on a mesh so shard d's slice holds the chunks d owns — the same
    contiguous layout the collectives assume, with the *slot* index space
    standing in for the example index space.
    """

    def __init__(self, store: ChunkedExampleStore, window_chunks: int,
                 mesh: Optional[Mesh] = None):
        from repro.dist import data_axes

        self.store = store
        self.mesh = mesh
        self.axes = data_axes(mesh) if mesh is not None else ()
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        if store.num_chunks % self.n_shards:
            raise ValueError(f"num_chunks={store.num_chunks} not divisible "
                             f"by {self.n_shards} shards")
        per_shard = store.num_chunks // self.n_shards
        if not 1 <= window_chunks <= per_shard:
            raise ValueError(f"window_chunks={window_chunks} must be in "
                             f"[1, {per_shard}] (chunks per shard)")
        self.window_chunks = int(window_chunks)
        self.chunk_size = store.chunk_size

        self._hits = self._misses = self._streamed = 0
        self._swaps = self._prefetches = 0
        self._pending: Optional[tuple[np.ndarray, dict]] = None
        self._combine = self._build_combine()

        # cold window: the first window_chunks chunks of each shard's range
        cold = np.stack([np.arange(self.window_chunks)
                         + store.shard_chunks(d, self.n_shards).start
                         for d in range(self.n_shards)])
        self._install_window(cold, self._put_sharded(
            store.stack_chunks(cold.reshape(-1))))

    # ---- placement --------------------------------------------------------

    def _put_sharded(self, host: dict) -> dict:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        from repro.dist.sharding import dim_spec
        spec = lambda v: P(dim_spec(self.axes), *([None] * (v.ndim - 1)))
        return {k: jax.device_put(v, NamedSharding(self.mesh, spec(v)))
                for k, v in host.items()}

    def _put_replicated(self, host):
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, host)
        return jax.tree.map(
            lambda v: jax.device_put(v, NamedSharding(self.mesh, P())), host)

    # ---- the two-level gather ---------------------------------------------

    def _build_combine(self) -> Callable:
        axes = self.axes

        def body(window, pos, hit, miss_rows):
            rows = gather_rows(window, pos, axes)    # hit rows, replicated
            def one(r, m):
                mask = hit.reshape((-1,) + (1,) * (r.ndim - 1))
                return jnp.where(mask, r, m)
            return jax.tree.map(one, rows, miss_rows)

        if self.mesh is None:
            return jax.jit(body)
        from repro.dist import shard_map
        from repro.dist.sharding import dim_spec
        win_specs = {k: P(dim_spec(axes),
                          *([None] * len(self.store.row_shape(k))))
                     for k in self.store.keys}
        rep = {k: P() for k in self.store.keys}
        return jax.jit(shard_map(
            body, mesh=self.mesh,
            in_specs=(win_specs, P(), P(), rep),
            out_specs=rep,
        ))

    def _sync_store_growth(self) -> None:
        """Pick up chunks appended to the store after construction: extend
        the chunk→slot table with -1 (not resident) so the new rows route
        through the host-fetch path until a prefetch admits their chunks.
        Sharded planes reject growth — chunk ownership is laid out as
        contiguous ranges at construction, so the serving loop must
        pre-reserve its capacity before the mesh placement instead."""
        grown = self.store.num_chunks - self._chunk_slot.size
        if grown <= 0:
            return
        if self.n_shards > 1:
            raise ValueError(
                f"store grew by {grown} chunks under a {self.n_shards}-shard "
                "plane; append reserve chunks before building the plane "
                "(growth would remap every shard's contiguous chunk range)")
        self._chunk_slot = np.concatenate(
            [self._chunk_slot, np.full((grown,), -1, np.int64)])

    def gather_global(self, idx: np.ndarray) -> dict:
        """Resolve global example indices into a replicated device batch:
        window hits on device, misses via one batched host fetch."""
        self._sync_store_growth()
        idx = np.asarray(idx).reshape(-1)
        cidx, off = index_to_chunk(idx, self.chunk_size)
        slot = self._chunk_slot[cidx]
        hit = slot >= 0
        pos = np.where(hit, slot * self.chunk_size + off, 0)
        miss_rows = {k: np.zeros((idx.size,) + self.store.row_shape(k),
                                 dtype=self.store.dtype(k))
                     for k in self.store.keys}
        n_miss = int((~hit).sum())
        if n_miss:
            fetched = self.store.fetch_rows(idx[~hit])
            for k in self.store.keys:
                miss_rows[k][~hit] = fetched[k]
        self._hits += int(hit.sum())
        self._misses += n_miss
        return self._combine(self._window,
                             self._put_replicated(jnp.asarray(pos, jnp.int32)),
                             self._put_replicated(jnp.asarray(hit)),
                             self._put_replicated(miss_rows))

    def fetch_sharded(self, idx_per_shard: np.ndarray) -> dict:
        """The scoring stream: (n_shards, rows) global indices → a sharded
        device batch of n_shards·rows examples, shard d's slice holding
        its rows.  Pure host fetch + one placement; never the window."""
        idx_per_shard = np.asarray(idx_per_shard)
        if idx_per_shard.shape[0] != self.n_shards:
            raise ValueError(f"expected {self.n_shards} shard rows, got "
                             f"{idx_per_shard.shape[0]}")
        self._streamed += idx_per_shard.size
        return self._put_sharded(
            self.store.fetch_rows(idx_per_shard.reshape(-1)))

    # ---- proposal-aware window refresh ------------------------------------

    def _install_window(self, ids: np.ndarray, arrays: dict) -> None:
        self._window_ids = ids
        self._window = arrays
        slot = np.full((self.store.num_chunks,), -1, np.int64)
        slot[ids.reshape(-1)] = np.arange(ids.size)
        self._chunk_slot = slot

    def prefetch(self, chunk_mass: np.ndarray) -> bool:
        """Assemble the next window off the proposal's per-chunk mass into
        the pending buffer (double-buffered: the live window is untouched
        until ``swap_window``).  Returns whether a new buffer was staged."""
        self._prefetches += 1
        self._sync_store_growth()
        mass = np.asarray(chunk_mass).reshape(-1)
        if mass.size < self.store.num_chunks and self.n_shards == 1:
            # store grew after the mass was computed (single-shard growth):
            # unseen chunks carry zero proposal mass until rescored
            mass = np.concatenate(
                [mass, np.zeros((self.store.num_chunks - mass.size,),
                                mass.dtype)])
        if mass.size != self.store.num_chunks:
            raise ValueError(f"chunk_mass has {mass.size} entries, store "
                             f"has {self.store.num_chunks} chunks")
        new_ids = np.empty_like(self._window_ids)
        for d in range(self.n_shards):
            r = self.store.shard_chunks(d, self.n_shards)
            order = np.argsort(-mass[r.start:r.stop], kind="stable")
            new_ids[d] = np.sort(order[:self.window_chunks]) + r.start
        if np.array_equal(new_ids, self._window_ids):
            self._pending = None     # nothing to change; drop stale pending
            return False
        self._pending = (new_ids, self._put_sharded(
            self.store.stack_chunks(new_ids.reshape(-1))))
        return True

    def swap_window(self) -> bool:
        """Flip in the prefetched buffer (call at a step boundary, before
        this step's gathers).  No-op when nothing is pending."""
        if self._pending is None:
            return False
        ids, arrays = self._pending
        self._pending = None
        self._install_window(ids, arrays)
        self._swaps += 1
        return True

    @property
    def window_ids(self) -> np.ndarray:
        """Copy of the live window's chunk ids, (n_shards, window_chunks)."""
        return self._window_ids.copy()

    @property
    def stats(self) -> WindowStats:
        """Cumulative hit/miss/stream/swap counters since reset."""
        return WindowStats(self._hits, self._misses, self._streamed,
                           self._swaps, self._prefetches)

    def reset_stats(self) -> None:
        """Zero the counters (benchmarks call this after warmup)."""
        self._hits = self._misses = self._streamed = 0
        self._swaps = self._prefetches = 0


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------

class StreamedISSGD:
    """Drive the streamed step: host schedule, window lifecycle, swap
    cadence.  ``step(state)`` — no dataset argument; the plane owns it.

    Per step: stream this step's round-robin scoring rows from the host
    store → flip in the window prefetched last step → run the scoring
    program (sync: into the store the master will read; async: into
    ``write_buf``) → draw the sampled indices on device and sync them to
    the host → two-level gather of the minibatch → master program →
    stage the next window off this step's per-chunk proposal mass.

    Async mode keeps the AsyncPipeline contract bit-for-bit: the master
    samples from ``read_buf`` while scoring writes ``write_buf``
    (donated), and ``publish`` swaps every ``swap_every`` steps — an async
    streamed run equals a non-streamed async run with the same cadence.
    Like AsyncPipeline, an instance is per-run (the swap/prefetch cadence
    rides on a host counter initialized from the first state's step).

    ``telemetry`` (telemetry.Telemetry) wraps each phase in a dispatch
    span (stream.fetch / scoring.dispatch / sample.dispatch /
    stream.gather / master.dispatch / store.publish / stream.prefetch /
    serve.tick) and emits the plane's hit-rate and swap counters at the
    telemetry cadence; monitor-built master steps land their dict on
    ``self.last_monitors``.

    Steps built ``gated=True`` need the adaptive ``controller``
    (core/controller.ProposalController): its ``gate()`` scalar is
    appended to both the sample and master dispatches of a step, and
    decided swap cadences apply via ``pipe.swap_every`` assignment.
    """

    def __init__(self, plane: StreamingDataPlane,
                 scoring_step: Callable, sample_step: Callable,
                 master_step: Callable, cfg: ISSGDConfig,
                 num_examples: int, *, async_mode: bool = False,
                 swap_every: int = 1, prefetch_every: int = 1,
                 jit: bool = True, serve_tick: Optional[Callable] = None,
                 telemetry=None, controller=None):
        if swap_every < 1 or prefetch_every < 1:
            raise ValueError("swap_every and prefetch_every must be >= 1")
        self.plane = plane
        # serve_tick(state) runs between the scoring and master dispatches
        # (the serving loop's decode slice of each train step)
        self.serve_tick = serve_tick
        self.cfg = cfg
        self.async_mode = bool(async_mode)
        self.swap_every = int(swap_every)
        self.prefetch_every = int(prefetch_every)
        self._expect_scores = getattr(master_step, "expect_scores",
                                      (not async_mode) and cfg.mode != "fused")
        # capture before jit — jax.jit drops function attributes
        self._with_monitors = bool(getattr(master_step, "with_monitors",
                                           False))
        self._gated = bool(getattr(master_step, "gated", False))
        self.controller = controller
        if self._gated and controller is None:
            raise ValueError("master_step was built gated=True; pass the "
                             "controller= that owns its use_is gate")
        if telemetry is None:
            from repro.telemetry import Telemetry
            telemetry = Telemetry.null()
        self.telemetry = telemetry
        self.last_monitors: Optional[dict] = None
        if jit:
            # async: write_buf (arg 1) is donated — in-place shard update,
            # mirroring AsyncPipeline; sync keeps the caller's store alive
            scoring_step = jax.jit(
                scoring_step, donate_argnums=(1,) if async_mode else ())
            sample_step = jax.jit(sample_step)
            master_step = jax.jit(master_step)
        self._scoring = scoring_step
        self._sample = sample_step
        self._master = master_step

        n_dev = plane.n_shards
        w_loc, n_w, sb_w = scoring_layout(cfg, num_examples, n_dev)
        self._layout = (w_loc, n_w, sb_w)
        self._n_local = num_examples // n_dev
        self._t: Optional[int] = None

    def _score_indices(self, t: int) -> np.ndarray:
        """(n_shards, rows) global indices of step t's scoring slices —
        the same rows ``issgd._score_slice`` addresses on each device."""
        w_loc, n_w, sb_w = self._layout
        local = host_score_slice(t, w_loc, n_w, sb_w)
        return (np.arange(self.plane.n_shards)[:, None] * self._n_local
                + local[None, :])

    def _tick(self, state: TrainState) -> int:
        if self._t is None:
            self._t = int(state.step)    # one host sync, at startup only
        return self._t

    def step(self, state: TrainState, data: Optional[dict] = None
             ) -> tuple[TrainState, StepMetrics]:
        """One streamed train step.  ``data`` is accepted (and ignored)
        only for drop-in signature parity with the resident step."""
        t = self._tick(state)
        tel = self.telemetry
        if self.cfg.mode == "fused":
            score_rows = None
        else:
            with tel.span("stream.fetch", step=t):
                score_rows = self.plane.fetch_sharded(self._score_indices(t))
        self.plane.swap_window()
        out = (self._step_async(state, score_rows)
               if self.async_mode else
               self._step_sync(state, score_rows))
        if tel.due(self._t):
            s = self.plane.stats
            tel.counter("stream.hit_rate", s.hit_rate, step=self._t)
            tel.counter("stream.hits", s.hits, step=self._t)
            tel.counter("stream.misses", s.misses, step=self._t)
            tel.counter("stream.streamed_rows", s.streamed_rows, step=self._t)
            tel.counter("stream.window_swaps", s.swaps, step=self._t)
            tel.counter("stream.prefetches", s.prefetches, step=self._t)
        return out

    def _unpack_master(self, out):
        if self._with_monitors:
            self.last_monitors = out[-1]
            return out[:-1]
        return out

    def _step_sync(self, state, score_rows):
        tel = self.telemetry
        t = self._t
        if self.cfg.mode == "fused":
            store, fresh, stale = state.store, None, None
        else:
            store, fresh, stale, _ = tel.timed(
                "scoring.dispatch", self._scoring, state.stale_params,
                state.store, state.step, score_rows, step=t)
        if self.serve_tick is not None:
            with tel.span("serve.tick", step=t):
                self.serve_tick(state)
        gate = (self.controller.gate(),) if self._gated else ()
        idx, mass = tel.timed("sample.dispatch", self._sample, store,
                              state.step, state.rng, *gate, step=t)
        with tel.span("stream.gather", step=t):
            batch = self.plane.gather_global(np.asarray(idx))
        margs = (state.params, state.opt_state, state.stale_params, store,
                 state.step, state.rng, batch)
        if self._expect_scores:
            margs += (fresh, stale)
        margs += gate
        params, opt_state, stale_params, store, step, rng, metrics = \
            self._unpack_master(tel.timed("master.dispatch", self._master,
                                          *margs, step=t))
        self._advance(mass)
        return (TrainState(params, opt_state, stale_params, store, step,
                           rng), metrics)

    def _step_async(self, state, score_rows):
        tel = self.telemetry
        t = self._t
        bs: BufferedWeightStore = state.store
        write_buf, _, _, smetrics = tel.timed(
            "scoring.dispatch", self._scoring, state.stale_params,
            bs.write_buf, state.step, score_rows, step=t)
        if self.serve_tick is not None:
            with tel.span("serve.tick", step=t):
                self.serve_tick(state)
        gate = (self.controller.gate(),) if self._gated else ()
        idx, mass = tel.timed("sample.dispatch", self._sample, bs.read_buf,
                              state.step, state.rng, *gate, step=t)
        with tel.span("stream.gather", step=t):
            batch = self.plane.gather_global(np.asarray(idx))
        params, opt_state, stale_params, _, step, rng, metrics = \
            self._unpack_master(tel.timed(
                "master.dispatch", self._master, state.params,
                state.opt_state, state.stale_params, bs.read_buf, state.step,
                state.rng, batch, *gate, step=t))
        bs = BufferedWeightStore(bs.read_buf, write_buf, bs.synced_at)
        self._advance(mass)
        if self._t % self.swap_every == 0:
            with tel.span("store.publish", step=self._t):
                bs = publish(bs, state.step)
        metrics = metrics._replace(trace_ideal=smetrics.trace_ideal,
                                   trace_stale=smetrics.trace_stale,
                                   trace_unif=smetrics.trace_unif)
        return (TrainState(params, opt_state, stale_params, bs, step, rng),
                metrics)

    def _advance(self, mass) -> None:
        if self._t % self.prefetch_every == 0:
            with self.telemetry.span("stream.prefetch", step=self._t):
                self.plane.prefetch(np.asarray(mass))
        self._t += 1

    def probe(self, state: TrainState, data: Optional[dict] = None
              ) -> TrainState:
        """Fused-mode coverage probe (the streamed make_score_step):
        rescore the current round-robin slice with θ_stale."""
        t = int(state.step)
        score_rows = self.plane.fetch_sharded(self._score_indices(t))
        store, _, _, _ = self._scoring(state.stale_params, state.store,
                                       state.step, score_rows)
        return state._replace(store=store)


def make_streamed_issgd(
    per_example_loss: Callable,
    scorer: Callable,
    optimizer,
    cfg: ISSGDConfig,
    dataset_arrays: dict,
    chunk_size: int,
    window_chunks: int,
    aux_loss: Optional[Callable] = None,
    fused_score: Optional[Callable] = None,
    async_mode: bool = False,
    swap_every: int = 1,
    prefetch_every: int = 1,
    monitor_traces: bool = True,
    jit: bool = True,
) -> StreamedISSGD:
    """Single-call constructor for the single-device streamed loop: chunk
    the arrays into a host store, stand up the plane, build the three
    programs with axes=().  (Mesh runs go through
    core.distributed.make_sharded_streamed_steps.)"""
    store = ChunkedExampleStore.from_arrays(dataset_arrays, chunk_size)
    plane = StreamingDataPlane(store, window_chunks)
    n = store.num_examples
    steps = make_streamed_steps(
        per_example_loss, scorer, optimizer, cfg, n, chunk_size,
        aux_loss=aux_loss, fused_score=fused_score,
        async_mode=async_mode, monitor_traces=monitor_traces)
    return StreamedISSGD(plane, *steps, cfg, n, async_mode=async_mode,
                         swap_every=swap_every,
                         prefetch_every=prefetch_every, jit=jit)

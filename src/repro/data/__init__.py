"""Data layer: resident array datasets, the chunked host-side example
store, and the streaming data plane that bridges the two."""
from repro.data.pipeline import (ArrayDataset, make_svhn_like,
                                 make_token_dataset, gather_batch,
                                 take_rows)
from repro.data.store import ChunkedExampleStore

__all__ = ["ArrayDataset", "make_svhn_like", "make_token_dataset",
           "gather_batch", "take_rows", "ChunkedExampleStore",
           "StreamingDataPlane", "StreamedISSGD", "make_streamed_issgd",
           "make_streamed_steps"]

_STREAMING = ("StreamingDataPlane", "StreamedISSGD", "make_streamed_issgd",
              "make_streamed_steps")


def __getattr__(name):
    # lazy: streaming pulls in core.issgd, which imports data.pipeline —
    # an eager import here would deadlock `import repro.core.issgd`
    if name in _STREAMING:
        from repro.data import streaming
        return getattr(streaming, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

from repro.data.pipeline import (ArrayDataset, make_svhn_like,
                                 make_token_dataset, gather_batch)

__all__ = ["ArrayDataset", "make_svhn_like", "make_token_dataset",
           "gather_batch"]

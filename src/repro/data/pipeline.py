"""Index-addressable datasets.

ISSGD needs *random access by example index* (the sampler draws indices
from the proposal), so datasets here are device-resident array trees with a
stable example axis, shardable over the data mesh axes.

`make_svhn_like` builds the synthetic stand-in for the paper's SVHN-2
experiment (offline container — see DESIGN.md §8): a permutation-invariant
classification problem whose examples have *heterogeneous* gradient norms
(cluster structure + noisy slices + label noise), the property ISSGD
exploits.  With homogeneous examples, importance sampling provably cannot
beat uniform (eq. 7 == eq. 8), so the benchmark would be vacuous.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


#: Gather modes for `take_rows`/`gather_batch`.  The hot paths (sampler
#: indices, round-robin scoring slices, window positions) are constructed
#: in-bounds, so they promise it and XLA skips the bounds handling;
#: "clip" is for callers that mask clamped rows afterwards (the one-owner
#: gathers of core/collectives.py); "fill" poisons out-of-range rows so a
#: schedule bug surfaces as NaN instead of a silently repeated example.
GATHER_MODES = ("promise_in_bounds", "clip", "fill")


def take_rows(array: jax.Array, indices: jax.Array,
              mode: str = "promise_in_bounds") -> jax.Array:
    """Row gather with an *explicit* out-of-bounds mode.

    The single gather primitive shared by `ArrayDataset.batch`, the
    streaming window of data/streaming.py, and the one-owner collectives —
    no call site relies on an implicit clamp/fill default.
    """
    if mode not in GATHER_MODES:
        raise ValueError(f"mode={mode!r} not in {GATHER_MODES}")
    return array.at[indices].get(mode=mode)


@dataclasses.dataclass
class ArrayDataset:
    """A tree of arrays with a common leading example axis."""
    arrays: dict[str, jax.Array]

    @property
    def size(self) -> int:
        """Number of examples (the common leading-axis length)."""
        return jax.tree.leaves(self.arrays)[0].shape[0]

    def batch(self, indices: jax.Array,
              mode: str = "promise_in_bounds") -> dict[str, jax.Array]:
        """Gather the rows at `indices` from every array (see take_rows)."""
        return gather_batch(self.arrays, indices, mode=mode)

    def slice(self, start: int, count: int) -> dict[str, jax.Array]:
        """Contiguous `count`-row window starting at `start`."""
        return {k: jax.lax.dynamic_slice_in_dim(v, start, count, 0)
                for k, v in self.arrays.items()}


def gather_batch(arrays: dict[str, jax.Array], indices: jax.Array,
                 mode: str = "promise_in_bounds") -> dict:
    """Row-gather every array of a dataset tree at `indices` (take_rows
    semantics per leaf; the scoring/master passes build batches with it)."""
    return {k: take_rows(v, indices, mode=mode) for k, v in arrays.items()}


def make_svhn_like(
    key: jax.Array,
    n: int = 65_536,
    dim: int = 3072,
    classes: int = 10,
    noisy_frac: float = 0.15,
    label_noise: float = 0.05,
    dtype=jnp.float32,
) -> tuple[ArrayDataset, ArrayDataset]:
    """Synthetic permutation-invariant SVHN clone. Returns (train, test)."""
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    n_test = max(n // 10, classes)

    means = jax.random.normal(k1, (classes, dim)) * 1.2

    def sample(key, m):
        ka, kb, kc, kd = jax.random.split(key, 4)
        y = jax.random.randint(ka, (m,), 0, classes)
        # heteroscedastic noise: a noisy slice of examples is much harder
        noisy = jax.random.uniform(kb, (m,)) < noisy_frac
        scale = jnp.where(noisy, 3.0, 0.7)[:, None]
        x = means[y] + jax.random.normal(kc, (m, dim)) * scale
        # label noise on a sub-slice: persistent high-gradient examples
        flip = jax.random.uniform(kd, (m,)) < label_noise
        y_obs = jnp.where(flip, (y + 1) % classes, y)
        return x.astype(dtype), y_obs.astype(jnp.int32)

    x_tr, y_tr = sample(k2, n)
    x_te, y_te = sample(k3, n_test)
    # standardize like pixel preprocessing
    mu = x_tr.mean(axis=0, keepdims=True)
    sd = x_tr.std(axis=0, keepdims=True) + 1e-6
    return (ArrayDataset({"x": (x_tr - mu) / sd, "y": y_tr}),
            ArrayDataset({"x": (x_te - mu) / sd, "y": y_te}))


def make_token_dataset(
    key: jax.Array,
    n: int = 4096,
    seq: int = 128,
    vocab: int = 512,
    num_patterns: int = 32,
) -> ArrayDataset:
    """Synthetic LM corpus: each example repeats one of `num_patterns`
    motifs with noise, so examples genuinely differ in difficulty."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    motif_len = 16
    motifs = jax.random.randint(k1, (num_patterns, motif_len), 0, vocab)
    which = jax.random.randint(k2, (n,), 0, num_patterns)
    reps = -(-seq // motif_len)
    base = jnp.tile(motifs[which], (1, reps))[:, :seq]
    # per-example corruption rate in [0, 0.5] — difficulty spectrum
    rate = jax.random.uniform(k3, (n, 1)) * 0.5
    noise = jax.random.randint(k4, (n, seq), 0, vocab)
    corrupt = jax.random.uniform(jax.random.fold_in(k3, 1), (n, seq)) < rate
    tokens = jnp.where(corrupt, noise, base)
    return ArrayDataset({"tokens": tokens.astype(jnp.int32)})

"""In-step proposal-health monitors — compiled into the master step.

The paper's whole argument is quantitative: importance sampling pays off
only while Tr(Σ) under the (stale) proposal beats uniform despite the
synchronization and staleness costs, and the failure modes are all
proposal-shape pathologies — a peaked proposal (B.3's "time bomb"), a
starved store, runaway staleness.  These monitors are the cheap in-program
observables of exactly those pathologies, computed from tensors the master
pass already holds (the store it sampled from and the smoothed proposal it
read), as *optional extra outputs* of the already-compiled step:

    ess               Kish effective sample size of the proposal / N
                      (1.0 = uniform; small = peaked, IS variance blowing up)
    entropy           Shannon entropy of the normalized proposal (nats)
    max_weight_frac   largest single proposal weight / total mass — the
                      sharpest peakedness alarm (one example dominating)
    empty_rows        count of reserved serving-capacity rows still EMPTY
                      (traffic headroom not yet ingested)
    staleness         observed proposal lag L(t): step − max(scored_at) of
                      the store the master sampled from — equals the PR 2
                      invariant's L(t) = t − K⌊t/K⌋ + 1 under swap cadence K

All reductions psum/pmax over the data axes, so the values are global and
replicated on every device; with axes=() they are exact local arithmetic.
Monitors off (``MonitorSet(())`` / None) is the *identity* code path: the
step program is HLO-identical to a build that never heard of telemetry,
and monitors on never perturbs the trajectory — both pinned in
tests/test_telemetry.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.collectives import pmax, psum
from repro.core.weight_store import EMPTY, WeightStore

MONITOR_NAMES = ("ess", "entropy", "max_weight_frac", "empty_rows",
                 "staleness")


@dataclasses.dataclass(frozen=True)
class MonitorSet:
    """Which proposal-health monitors the step compiles in.

    Falsy when empty, so ``monitors or None`` collapses "no monitors"
    and "empty set" onto the untouched pre-telemetry code path.
    """
    names: tuple[str, ...] = ()

    def __post_init__(self):
        unknown = [n for n in self.names if n not in MONITOR_NAMES]
        if unknown:
            raise ValueError(f"unknown monitor(s) {unknown}; available: "
                             f"{', '.join(MONITOR_NAMES)}")

    def __bool__(self) -> bool:
        return bool(self.names)

    @classmethod
    def all(cls) -> "MonitorSet":
        """Every available monitor."""
        return cls(MONITOR_NAMES)

    @classmethod
    def parse(cls, spec: str) -> "MonitorSet":
        """CLI form: ``"all"``, ``"none"``/``""``, or a comma list of
        monitor names (order-normalized to MONITOR_NAMES order)."""
        spec = (spec or "").strip().lower()
        if spec in ("", "none", "off"):
            return cls(())
        if spec == "all":
            return cls.all()
        asked = {s.strip() for s in spec.split(",") if s.strip()}
        unknown = asked - set(MONITOR_NAMES)
        if unknown:
            raise ValueError(f"unknown monitor(s) {sorted(unknown)}; "
                             f"available: {', '.join(MONITOR_NAMES)} "
                             f"(or 'all'/'none')")
        return cls(tuple(n for n in MONITOR_NAMES if n in asked))


def proposal_monitors(store: WeightStore, proposal: jax.Array,
                      step, axes: tuple[str, ...], num_examples: int,
                      monitors: MonitorSet,
                      sum_w=None) -> dict[str, jax.Array]:
    """The enabled monitors as a ``{name: scalar}`` dict (replicated).

    ``store`` and ``proposal`` are the (possibly shard-local) table and
    smoothed proposal the master pass just read — reserved EMPTY rows
    already carry zero proposal mass.  ``sum_w`` lets the master pass
    share its existing psum'd total instead of reducing again.
    """
    axes = tuple(axes)
    out: dict[str, jax.Array] = {}
    names = monitors.names
    if any(n in names for n in ("ess", "entropy", "max_weight_frac")):
        if sum_w is None:
            sum_w = psum(jnp.sum(proposal), axes)
        sum_w = jnp.maximum(sum_w, 1e-30)
    if "ess" in names:
        sum_w2 = psum(jnp.sum(jnp.square(proposal)), axes)
        out["ess"] = (jnp.square(sum_w) / jnp.maximum(sum_w2, 1e-30)
                      / num_examples)
    if "entropy" in names:
        # delegate to the one canonical entropy (core/importance.py) —
        # shard-decomposable, zero-mass rows contribute their limit 0
        from repro.core.importance import proposal_entropy
        out["entropy"] = proposal_entropy(proposal, axes, sum_w)
    if "max_weight_frac" in names:
        out["max_weight_frac"] = pmax(jnp.max(proposal), axes) / sum_w
    if "empty_rows" in names:
        out["empty_rows"] = psum(
            jnp.sum((store.scored_at <= EMPTY).astype(jnp.int32)), axes)
    if "staleness" in names:
        out["staleness"] = (jnp.asarray(step, jnp.int32)
                            - pmax(jnp.max(store.scored_at), axes))
    return out

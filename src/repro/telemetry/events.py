"""Streaming JSONL event sink — the host side of the telemetry plane.

One record per line, schema-versioned so downstream tooling
(`tools/metrics_report.py`, CI greps, future controllers) can evolve
without guessing.  Every record carries:

    v       int     schema version (SCHEMA_VERSION)
    kind    str     record type: "run" | "span" | "counter" | "metrics"
                    | "monitors" | "profile" | "run_end"
                    | "controller.config" | "controller.decision"
                    (the last two emitted by core/controller.py through
                    its sink tap; replayable via replay_decisions)
    t       float   host wall-clock (time.time()) at emit
    step    int?    train step the record belongs to, when one applies

plus kind-specific fields ("span": name, dur_s; "counter": name, value;
"metrics"/"monitors": the scalar payload).  Writes are host-side only and
buffered (``flush_every``), so emitting never forces a device sync — the
non-blocking discipline the async pipeline's overlap depends on lives in
`repro/telemetry/spans.py`; this module just never undoes it.
"""
from __future__ import annotations

import json
import time
from typing import IO, Optional

SCHEMA_VERSION = 1


def _jsonable(v):
    """Coerce numpy/JAX scalars (already host-side) to plain Python."""
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return v


class EventSink:
    """Append schema-versioned JSONL records to ``path``.

    The file is opened eagerly and a ``kind="run"`` header record is
    written first (schema version + whatever run metadata the caller
    passes), so a truncated file still identifies itself.  ``emit`` never
    raises on exotic values — everything non-JSON-serializable is
    stringified — because telemetry must not kill a training run.
    """

    def __init__(self, path: str, run: Optional[dict] = None,
                 flush_every: int = 32):
        self.path = path
        self._f: Optional[IO] = open(path, "w")
        self._since_flush = 0
        self.flush_every = max(int(flush_every), 1)
        self.emitted = 0
        self.emit("run", **(run or {}))
        self.flush()

    def emit(self, kind: str, step: Optional[int] = None, **fields) -> None:
        """Write one record: the envelope (v/kind/t/step) plus `fields`."""
        if self._f is None:
            return
        rec = {"v": SCHEMA_VERSION, "kind": kind, "t": time.time()}
        if step is not None:
            rec["step"] = int(step)
        for k, v in fields.items():
            rec[k] = _jsonable(v)
        try:
            line = json.dumps(rec)
        except TypeError:
            rec = {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                       else str(v)) for k, v in rec.items()}
            line = json.dumps(rec)
        self._f.write(line + "\n")
        self.emitted += 1
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def span(self, name: str, dur_s: float,
             step: Optional[int] = None) -> None:
        """A timing span record: phase `name` took `dur_s` seconds."""
        self.emit("span", step=step, name=name, dur_s=round(dur_s, 6))

    def counter(self, name: str, value, step: Optional[int] = None) -> None:
        """A named scalar counter/gauge sample."""
        self.emit("counter", step=step, name=name, value=_jsonable(value))

    def flush(self) -> None:
        """Flush buffered lines to disk (a host-side file flush only)."""
        if self._f is not None:
            self._f.flush()
            self._since_flush = 0

    def close(self) -> None:
        """Flush and close; idempotent (later emits become no-ops)."""
        if self._f is not None:
            self._f.flush()
            self._f.close()
            self._f = None

    def __enter__(self):
        """Context-manager support: ``with EventSink(p) as sink: ...``."""
        return self

    def __exit__(self, *exc):
        """Close on scope exit (exceptions propagate)."""
        self.close()
        return False


class NullSink:
    """The no-op sink: telemetry-off call sites keep the same code path
    with zero I/O.  Falsy, so ``if sink:`` gates optional work."""

    path = None
    emitted = 0

    def emit(self, kind, step=None, **fields):
        """Discard the record."""

    def span(self, name, dur_s, step=None):
        """Discard the span."""

    def counter(self, name, value, step=None):
        """Discard the counter."""

    def flush(self):
        """Nothing buffered, nothing flushed."""

    def close(self):
        """Nothing open, nothing closed."""

    def __bool__(self):
        return False


def read_events(path: str) -> list[dict]:
    """Parse a telemetry JSONL file back into records (malformed lines are
    skipped — a crashed run may leave a torn final line)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out

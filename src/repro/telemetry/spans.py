"""Phase timing spans with a non-blocking default.

The span taxonomy (documented in docs/ARCHITECTURE.md §8) names the
phases of one train step: ``scoring.dispatch``, ``master.dispatch``,
``store.publish``, ``serve.tick``, ``stream.prefetch``, ``stream.fetch``,
``stream.gather``, ``sample.dispatch``, ``train.step``.

The central design constraint: JAX dispatch is asynchronous, and the
async pipeline (PR 2) *depends* on the scoring and master computations
being in flight simultaneously.  A naive timer that calls
``block_until_ready`` around each phase would re-serialize exactly the
overlap it is trying to measure.  So:

  * the default (``block=False``) times only the host-side dispatch —
    the span ends when the call returns, while the device work is still
    in flight.  A dispatch span much shorter than the phase's true device
    time is the *witness* that the next phase started concurrently
    (pinned in tests/test_telemetry.py);
  * ``block=True`` (train.py ``--telemetry-blocking``) blocks on the
    phase's outputs before closing the span — accurate per-phase device
    wall-clock for sync runs and profiling sessions, at the cost of
    serializing the streams.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Optional


@contextmanager
def span(sink, name: str, step: Optional[int] = None):
    """Context manager measuring the host wall-clock of its block and
    emitting one ``kind="span"`` record.  Purely host-side: it never
    blocks on device values (whatever the block dispatched stays in
    flight)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        sink.span(name, time.perf_counter() - t0, step=step)


def timed(sink, name: str, fn: Callable, *args,
          step: Optional[int] = None, block: bool = False):
    """Call ``fn(*args)`` inside a span and return its result.

    With ``block=False`` (default) the span closes as soon as dispatch
    returns — the non-blocking mode async runs require.  With
    ``block=True`` the span additionally waits for every array in the
    result (``jax.block_until_ready``), measuring true device wall-clock.
    """
    t0 = time.perf_counter()
    out = fn(*args)
    if block:
        import jax
        out = jax.block_until_ready(out)
    sink.span(name, time.perf_counter() - t0, step=step)
    return out

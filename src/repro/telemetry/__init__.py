"""Telemetry: structured observability for the train/serve system.

Three planes, one package (docs/ARCHITECTURE.md §8):

  * **in-step monitors** (`monitors.py`) — proposal-health scalars (ESS,
    entropy, max-weight fraction, EMPTY-row count, observed staleness)
    compiled into the master step as optional extra outputs; off is the
    identity code path (HLO-pinned), on never perturbs the trajectory;
  * **events** (`events.py`) — a schema-versioned JSONL sink for spans,
    counters, and per-step metrics records, host-side and buffered;
  * **spans** (`spans.py`) — phase wall-clock timing with a non-blocking
    default so instrumenting an async run never re-serializes the
    scoring/master overlap.

`Telemetry` is the facade the host drivers (`AsyncPipeline`,
`StreamedISSGD`, `ServeLoop`, `launch/train.py`) carry: sink + span
timing + the periodic-counter cadence.  `Telemetry.null()` is the
always-available no-op instance, so pipeline code has exactly one path
whether telemetry is on or off.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.telemetry.events import SCHEMA_VERSION, EventSink, NullSink
from repro.telemetry.monitors import MONITOR_NAMES, MonitorSet
from repro.telemetry import spans as _spans

__all__ = ["EventSink", "NullSink", "MonitorSet", "MONITOR_NAMES",
           "SCHEMA_VERSION", "Telemetry"]


class Telemetry:
    """Facade handed to the host drivers: an event sink, span timing, and
    the cadence at which periodic counters fire.

    ``blocking=False`` (default) keeps every span dispatch-only — the
    async overlap contract; ``blocking=True`` waits on each timed call's
    outputs for true per-phase wall-clock (sync/profiling runs).
    """

    _null = None

    def __init__(self, sink, every: int = 10, blocking: bool = False):
        if every < 1:
            raise ValueError(f"telemetry cadence must be >= 1, got {every}")
        self.sink = sink
        self.every = int(every)
        self.blocking = bool(blocking)

    @classmethod
    def null(cls) -> "Telemetry":
        """The shared no-op instance (NullSink, nothing emitted)."""
        if cls._null is None:
            cls._null = cls(NullSink())
        return cls._null

    def __bool__(self) -> bool:
        return bool(self.sink)

    def timed(self, name: str, fn: Callable, *args,
              step: Optional[int] = None):
        """Run ``fn(*args)`` inside a span named `name` (see spans.timed);
        blocking per this instance's mode."""
        if not self.sink:
            return fn(*args)
        return _spans.timed(self.sink, name, fn, *args, step=step,
                            block=self.blocking)

    def span(self, name: str, step: Optional[int] = None):
        """Context manager: host wall-clock span around the block."""
        return _spans.span(self.sink, name, step=step)

    def counter(self, name: str, value, step: Optional[int] = None) -> None:
        """Emit one counter sample."""
        self.sink.counter(name, value, step=step)

    def emit(self, kind: str, step: Optional[int] = None, **fields) -> None:
        """Emit a raw record through the sink."""
        self.sink.emit(kind, step=step, **fields)

    def due(self, t: int) -> bool:
        """Whether periodic counters should fire at host step `t`."""
        return bool(self.sink) and t % self.every == 0

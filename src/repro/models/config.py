"""Unified architecture config covering all assigned architecture families.

One frozen dataclass describes dense / MoE / SSM / hybrid / VLM / audio
decoder backbones.  Layer heterogeneity (jamba's 1:7 mamba:attention
interleave, MoE-every-other-layer) is expressed as a *period*: a short list
of layer descriptors that tiles the depth; scan-over-layers runs over
period repetitions so mixed stacks still compile to a single rolled loop.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

AttnKind = Literal["gqa", "mla", "none"]
MixerKind = Literal["attn", "mamba"]
FFKind = Literal["mlp", "moe"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the period: a sequence mixer + a feed-forward."""
    mixer: MixerKind = "attn"
    ff: FFKind = "mlp"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    head_dim: int = 0                   # 0 → d_model // num_heads
    act: str = "silu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- attention variant ---
    attention: AttnKind = "gqa"
    sliding_window: int = 0             # 0 = full causal; >0 = window size
    # MLA (DeepSeek/MiniCPM3 style multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_every: int = 1                  # a layer is MoE if (i % moe_every == moe_offset)
    moe_offset: int = 0

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    d_inner: int = 0                    # 0 → 2*d_model
    conv_width: int = 4
    dt_rank: int = 0                    # 0 → ceil(d_model/16)
    attn_every: int = 0                 # hybrid: 1 attention layer per this many
    attn_offset: int = 0

    # --- modality frontend stub (VLM / audio conditioning) ---
    frontend: str = "none"              # none | vision | audio
    num_frontend_tokens: int = 0        # patches / frames prepended as embeds

    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logits_softcap: float = 0.0
    # chunk size (sequence positions) for the unembed+CE computation; 0 =
    # materialize full (B,S,V) logits (small models / ghost-tap path).
    # Production configs set this so the vocab logits never exist at once.
    loss_chunk: int = 0
    # query-chunk size for attention (flash-style jnp path)
    attn_chunk: int = 512
    # accumulation dtype of the SSM recurrence state (perf knob: bf16
    # halves the scan's HBM traffic at a measured accuracy cost)
    ssm_scan_dtype: str = "float32"
    # lax.scan unroll factor: keeps h in-register across `unroll` steps so
    # the recurrence's HBM round-trips drop ~unroll× (§Perf iteration)
    ssm_scan_unroll: int = 1

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.attn_every == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_every > 0

    def layer_specs(self) -> tuple[LayerSpec, ...]:
        """Descriptor per layer of one period (see module docstring)."""
        period = self.period_len()
        specs = []
        for i in range(period):
            if self.ssm_state > 0:
                if self.attn_every > 0 and i % self.attn_every == self.attn_offset:
                    mixer = "attn"
                else:
                    mixer = "mamba"
            else:
                mixer = "attn"
            if self.num_experts > 0 and i % self.moe_every == self.moe_offset:
                ff = "moe"
            else:
                ff = "mlp"
            specs.append(LayerSpec(mixer=mixer, ff=ff))
        return tuple(specs)

    def period_len(self) -> int:
        """Smallest layer pattern that tiles the stack."""
        import math
        p = 1
        if self.num_experts > 0:
            p = math.lcm(p, self.moe_every)
        if self.attn_every > 0:
            p = math.lcm(p, self.attn_every)
        # mamba-only and dense stacks have period 1
        assert self.num_layers % p == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"period {p}")
        return p

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period_len()

    # --------------------------------------------------------- param count
    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n = 0
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                if self.attention == "mla":
                    qr = self.q_lora_rank or d
                    n += d * qr + qr * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    n += d * (self.kv_lora_rank + self.qk_rope_dim)
                    n += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * d
                else:
                    n += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                    n += self.num_heads * hd * d
            else:  # mamba
                di, ds, dtr = self.resolved_d_inner, self.ssm_state, self.resolved_dt_rank
                n += d * 2 * di + di * self.conv_width + di * (dtr + 2 * ds)
                n += dtr * di + di * ds + 2 * di + di * d
            if self.d_ff > 0:
                if spec.ff == "moe":
                    n += self.num_experts * 3 * d * self.d_ff + d * self.num_experts
                else:
                    n += 3 * d * self.d_ff
                n += d  # ln2
            n += d  # ln1
        n *= self.num_periods
        n += n_embed + d  # embeddings + final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        per_expert = 3 * self.d_model * self.d_ff
        n_moe_layers = sum(
            1 for s in self.layer_specs() for _ in [0] if s.ff == "moe"
        ) * self.num_periods
        inactive = (self.num_experts - self.num_experts_per_tok) * per_expert * n_moe_layers
        return full - inactive

"""Mamba-1 block (falcon-mamba / jamba mixer).

Channel dimension d_inner is tensor-parallel over the `model` mesh axis
(the scan is independent per channel); the sequence recurrence runs through
either the chunked Pallas kernel (TPU) or the pure-jnp sequential oracle
(CPU validation / dry-run lowering) — selected by `mode`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.config import ModelConfig
from repro.models.layers import Params, Tape, _dense_init, tapped_linear


class MambaState(NamedTuple):
    """Decode-time recurrent state (the SSM's 'KV cache')."""
    conv: jax.Array   # (B, conv_width-1, d_inner) trailing inputs
    h: jax.Array      # (B, d_inner, d_state)


def init_mamba(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    d, di = cfg.d_model, cfg.resolved_d_inner
    ds, dtr, w = cfg.ssm_state, cfg.resolved_dt_rank, cfg.conv_width
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": _dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (w, di), jnp.float32) * (w ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": _dense_init(ks[3], dtr, di, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform dt init
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[5], di, d, dtype),
    }


def specs_mamba() -> Params:
    # in_proj projects to the CONCATENATED [x | z] pair (d, 2*d_inner): a
    # contiguous column shard of it does not align with the per-channel
    # split (device 0 of a 2-way mesh would hold all of W_x and none of
    # W_z), so it stays replicated; the channel-parallel entry point is
    # the slice of its output instead (see `mamba`).
    return {
        "in_proj": ("embed", None),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "a_log": ("inner", None),
        "d_skip": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. x: (B,S,di), w: (W,di)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # unrolled taps (width is 4): avoids conv lowering corner cases
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(width))
    return y + b[None, None]


def mamba_shard_info(params: Params, cfg: ModelConfig) -> tuple[bool, int]:
    """(sharded, local_d_inner) for a mamba parameter tree.

    All channel-indexed parameters (conv, dt, A, D, the x_proj rows and
    out_proj rows) shard the same d_inner dimension, so the divisibility
    fallback hits them all or none; in_proj must stay replicated (see
    `specs_mamba`).  An inconsistent mix raises naming `d_inner`."""
    di = cfg.resolved_d_inner
    di_l = params["a_log"].shape[0]
    if di_l == di and params["out_proj"].shape[0] == di:
        return False, di
    consistent = (params["out_proj"].shape[0] == di_l
                  and params["x_proj"].shape[0] == di_l
                  and params["conv_w"].shape[1] == di_l
                  and params["dt_proj"].shape[1] == di_l
                  and params["in_proj"].shape[-1] == 2 * di)
    if not consistent or di % di_l:
        raise ValueError(
            f"mamba is inconsistently model-sharded (a_log rows={di_l}, "
            f"out_proj rows={params['out_proj'].shape[0]}, d_inner={di}): "
            f"the model-parallel degree must divide d_inner "
            f"({di}; config field d_inner, default 2*d_model)")
    return True, di_l


def mamba(params: Params, x: jax.Array, cfg: ModelConfig,
          tape: Optional[Tape] = None, prefix: str = "mamba",
          mode: str = "ref", collector: Optional[dict] = None,
          model_axes: tuple[str, ...] = (),
          pad_mask: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence mamba mixer. x: (B,S,D) → (B,S,D).

    ``pad_mask`` (B,S) bool marks real (non-pad) positions of a
    right-padded batch: Δ is zeroed at pad positions, which makes each
    pad step the exact identity on the recurrent state (h_t =
    exp(Δ·A)·h_{t-1} + Δ·B·x is h_{t-1} at Δ=0), so the collected decode
    state matches the unpadded run bitwise; the conv window is gathered
    from each row's true tail.  ``pad_mask=None`` is the unmasked
    dataflow, unchanged.

    With ``model_axes`` set and channel-sharded weights (inside
    shard_map), the selective scan is embarrassingly parallel over
    channels: the replicated [x|z] projection is sliced to this device's
    channel block (its `psum_backward` wrap restores the replicated
    cotangent), conv/Δ/A/D and the recurrence run on local channels, the
    row-sharded x_proj and out_proj produce partial outputs that
    `psum_forward` reduces.  The prefill collector then holds local
    channel slices — serving runs outside the model-sharded path."""
    from repro.core.collectives import (axis_info, psum_backward,
                                        psum_forward)
    model_axes = tuple(model_axes)
    di, ds, dtr = cfg.resolved_d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    sharded, di_l = (mamba_shard_info(params, cfg) if model_axes
                     else (False, di))

    xz = tapped_linear(x, params["in_proj"], f"{prefix}.in_proj", tape)
    if sharded:
        xz = psum_backward(xz, model_axes)
    x_in, z = jnp.split(xz, 2, axis=-1)
    if sharded:
        dev, _ = axis_info(model_axes)
        x_in = jax.lax.dynamic_slice_in_dim(x_in, dev * di_l, di_l, -1)
        z = jax.lax.dynamic_slice_in_dim(z, dev * di_l, di_l, -1)
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]))

    proj = tapped_linear(x_c, params["x_proj"], f"{prefix}.x_proj", tape)
    if sharded:
        # psum_forward reduces the row-parallel partials into the full
        # (Δ-rank, B, C) projection; unlike the residual outputs its
        # consumers are NOT replicated — each device feeds it back into
        # its own channel block — so the partial cotangents must be
        # psum'd too (psum_backward) before they reach x_proj/x_c.
        proj = psum_backward(psum_forward(proj, model_axes), model_axes)
    dt_r = proj[..., :dtr]
    b_mat = proj[..., dtr:dtr + ds]
    c_mat = proj[..., dtr + ds:]
    delta = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])
    if pad_mask is not None:
        delta = delta * pad_mask[..., None].astype(delta.dtype)
    a = -jnp.exp(params["a_log"])

    if collector is not None:  # prefill: recurrent state for decode
        y, h_final = ref.selective_scan_ref(x_c, delta, a, b_mat, c_mat,
                                            params["d_skip"], return_state=True)
        w = params["conv_w"].shape[0]
        if pad_mask is None:
            collector[f"{prefix}.conv"] = x_in[:, -(w - 1):, :]
        else:
            # per-row gather of the last w-1 *real* inputs (left-zero-pad
            # rows shorter than the window, matching _causal_conv)
            tl = jnp.sum(pad_mask.astype(jnp.int32), axis=1)       # (B,)
            idx = tl[:, None] - (w - 1) + jnp.arange(w - 1)[None]  # (B,w-1)
            got = jnp.take_along_axis(
                x_in, jnp.clip(idx, 0, x_in.shape[1] - 1)[..., None], axis=1)
            collector[f"{prefix}.conv"] = jnp.where(
                (idx >= 0)[..., None], got, jnp.zeros_like(got))
        collector[f"{prefix}.h"] = h_final
    elif mode == "pallas":
        y = ops.selective_scan(x_c, delta.astype(x_c.dtype), a, b_mat, c_mat,
                               params["d_skip"])
    else:
        y = ref.selective_scan_ref(x_c, delta, a, b_mat, c_mat,
                                   params["d_skip"],
                                   scan_dtype=jnp.dtype(cfg.ssm_scan_dtype),
                                   unroll=cfg.ssm_scan_unroll)

    y = y * jax.nn.silu(z)
    out = tapped_linear(y, params["out_proj"], f"{prefix}.out_proj", tape)
    return psum_forward(out, model_axes) if sharded else out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    di = cfg.resolved_d_inner
    return MambaState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    )


def mamba_decode(params: Params, x: jax.Array, cfg: ModelConfig,
                 state: MambaState,
                 model_axes: tuple[str, ...] = ()) -> tuple[jax.Array, MambaState]:
    """One-token decode. x: (B,D) → (B,D), updated state.

    With ``model_axes`` and channel-sharded weights the state buffers are
    local channel blocks; the replicated in_proj output is sliced to this
    device's block and the row-parallel x_proj / out_proj partial outputs
    are `psum_forward`-reduced (decode is forward-only, so no backward
    collectives are needed)."""
    from repro.core.collectives import axis_info, psum_forward
    di, ds, dtr = cfg.resolved_d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    sharded, di_l = (mamba_shard_info(params, cfg) if model_axes
                     else (False, di))
    w = params["conv_w"].shape[0]

    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                     # (B,di)
    if sharded:
        dev, _ = axis_info(model_axes)
        x_in = jax.lax.dynamic_slice_in_dim(x_in, dev * di_l, di_l, -1)
        z = jax.lax.dynamic_slice_in_dim(z, dev * di_l, di_l, -1)
    window = jnp.concatenate([state.conv, x_in[:, None]], axis=1)  # (B,W,di)
    x_c = jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"]
    x_c = jax.nn.silu(x_c)

    proj = x_c @ params["x_proj"]
    if sharded:
        proj = psum_forward(proj, model_axes)
    dt_r, b_t, c_t = proj[..., :dtr], proj[..., dtr:dtr + ds], proj[..., dtr + ds:]
    delta = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    h, y = ref.selective_scan_step_ref(state.h, x_c, delta, a, b_t, c_t,
                                       params["d_skip"])
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if sharded:
        out = psum_forward(out, model_axes)
    return out, MambaState(conv=window[:, 1:], h=h)

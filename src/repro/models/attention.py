"""Attention mixers: GQA (with sliding-window option) and MLA.

Train/prefill paths chunk the query dimension (lax.map over query blocks)
so the S×S logits matrix is never materialized — the pure-jnp analogue of
flash attention that lowers on every backend; on TPU the decode path swaps
in the Pallas flash-decode kernel via the kernel policy.

MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style) implements
both the materialized train path and the *absorbed* decode path where the
KV cache stores only the compressed latent (kv_lora_rank + rope dims) and
the query is projected into the latent space — the serving memory win that
makes MLA interesting.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, Tape, _dense_init, init_rmsnorm, rmsnorm, rope, specs_rmsnorm, tapped_linear

_NEG = -1e30


# ===================================================================== GQA
def init_attn(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _dense_init(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": _dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": _dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": _dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def specs_attn() -> Params:
    return {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
            "wv": ("embed", "kv"), "wo": ("heads", "embed")}


def attn_shard_info(params: Params, cfg: ModelConfig) -> tuple[bool, int, int]:
    """(sharded, local_heads, local_kv_heads) for a GQA parameter tree.

    Shard-ness is detected from the shapes (the divisibility fallback in
    `logical_to_pspec` replicates dims that don't divide the model axis,
    so it is per-parameter, not per-run).  A *partially* sharded layer —
    wq split but wk/wv replicated, a split that lands mid-head, or a
    local head count that breaks the GQA grouping — cannot run under
    shard_map and raises with the config field to fix."""
    hd = cfg.resolved_head_dim
    q_cols = params["wq"].shape[-1]
    k_cols = params["wk"].shape[-1]
    q_sharded = q_cols != cfg.num_heads * hd
    k_sharded = k_cols != cfg.num_kv_heads * hd
    if not q_sharded and not k_sharded:
        return False, cfg.num_heads, cfg.num_kv_heads
    if q_sharded != k_sharded:
        raise ValueError(
            f"attention is only partially model-sharded (wq cols={q_cols}, "
            f"wk cols={k_cols}): the model-parallel degree must divide "
            f"both num_heads ({cfg.num_heads}) and num_kv_heads "
            f"({cfg.num_kv_heads})")
    if q_cols % hd or k_cols % hd:
        raise ValueError(
            f"model-axis shard splits mid-head (local wq cols={q_cols}, "
            f"wk cols={k_cols}, head_dim={hd}): the model-parallel degree "
            f"must divide num_heads ({cfg.num_heads}) and num_kv_heads "
            f"({cfg.num_kv_heads}), not just their flattened projections")
    h_l, hkv_l = q_cols // hd, k_cols // hd
    if h_l % hkv_l or params["wo"].shape[0] != q_cols:
        raise ValueError(
            f"model-axis shard breaks the GQA grouping (local heads "
            f"{h_l}, local kv heads {hkv_l}, wo rows "
            f"{params['wo'].shape[0]}): num_heads ({cfg.num_heads}) and "
            f"num_kv_heads ({cfg.num_kv_heads}) must both be divisible by "
            f"the model-parallel degree")
    return True, h_l, hkv_l


def _causal_window_mask(q_pos, k_pos, window: int):
    """(..., Q, K) boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return m


def _chunked_attention(q, k, v, q_pos, k_pos, window: int, q_chunk: int):
    """q:(B,Sq,Hkv,rep,hd) k,v:(B,Sk,Hkv,hd). Returns (B,Sq,Hkv,rep,hd)."""
    bsz, sq, hkv, rep, hd = q.shape
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    pad = (-sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
    nc = (sq + pad) // q_chunk
    qs = q.reshape(bsz, nc, q_chunk, hkv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(bsz, nc, q_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one_chunk(args):
        qc, qp = args  # (B,qc,Hkv,rep,hd), (B,qc)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qc.astype(jnp.float32) * scale,
                            k.astype(jnp.float32))
        mask = _causal_window_mask(qp, k_pos, window)  # (B,qc,Sk)
        logits = jnp.where(mask[:, None, None], logits, _NEG)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
        return out.astype(q.dtype)

    outs = jax.lax.map(one_chunk, (qs, qps))  # (nc,B,qc,Hkv,rep,hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(bsz, sq + pad, hkv, rep, hd)
    return out[:, :sq]


def attn(params: Params, x: jax.Array, cfg: ModelConfig,
         positions: jax.Array, tape: Optional[Tape] = None,
         prefix: str = "attn", q_chunk: int = 512,
         collector: Optional[dict] = None,
         impl: str = "ref",
         model_axes: tuple[str, ...] = (),
         attn_scores: Optional[str] = None) -> jax.Array:
    """Full training/prefill GQA self-attention. x: (B,S,D).

    impl="pallas" uses the flash-attention kernel (forward-only — the
    serving-prefill hot path); "flash" is the same kernel made trainable
    through the FlashAttention-2 backward (custom_vjp); "ref" is the
    chunked-jnp path (training, autodiff-friendly, lowers on every
    backend).

    ``attn_scores`` (requires impl="flash") swaps the wq/wk/wv ghost taps
    for ONE (B,) score tap at the attention interface: the tap's
    cotangent is the per-example ||dQ||²+||dK||²+||dV||² of the post-rope
    flash-attention operands.  "fused" reads it from the backward
    kernels' epilogues (no extra HBM sweep); "separate" recomputes it
    from the materialized gradients via `make_qkv_score_probe` — the
    bitwise reference/benchmark baseline.  The wo tap is unaffected.

    With ``model_axes`` set and head-sharded weights (inside shard_map),
    the layer runs Megatron-style: `psum_backward` on the replicated
    input, QKV on this device's whole-head column shards (attention is
    head-independent, so the softmax/context math is purely local), and
    the row-sharded output projection's partial result is `psum_forward`-
    reduced.  Ghost taps see the LOCAL head slices (wq/wk/wv) and the
    local-rows/full-dY pair (wo), so per-example contributions are
    model-axis partial sums.  The collector (prefill KV capture) then
    holds this device's head slice — the serving engine runs outside the
    model-sharded shard_map path and never passes both."""
    from repro.core.collectives import psum_backward, psum_forward
    model_axes = tuple(model_axes)
    if attn_scores is not None:
        if attn_scores not in ("fused", "separate"):
            raise ValueError(f"attn_scores must be 'fused', 'separate' or "
                             f"None, got {attn_scores!r}")
        if impl != "flash":
            raise ValueError(
                f"attn_scores={attn_scores!r} needs the trainable flash "
                f"kernel (impl='flash'), got impl={impl!r}")
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    sharded, h, hkv = (attn_shard_info(params, cfg) if model_axes
                       else (False, cfg.num_heads, cfg.num_kv_heads))
    rep = h // hkv

    xi = psum_backward(x, model_axes) if sharded else x
    # with a score tap active, the fused attention-interface score
    # replaces the wq/wk/wv ghost Gram terms — suppress those taps
    qkv_tape = None if attn_scores is not None else tape
    q = tapped_linear(xi, params["wq"], f"{prefix}.wq", qkv_tape)
    k = tapped_linear(xi, params["wk"], f"{prefix}.wk", qkv_tape)
    v = tapped_linear(xi, params["wv"], f"{prefix}.wv", qkv_tape)
    q = rope(q.reshape(bsz, s, h, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(bsz, s, hkv, hd), positions, cfg.rope_theta)
    v = v.reshape(bsz, s, hkv, hd)
    if collector is not None:  # prefill: roped K and V feed the KV cache
        collector[f"{prefix}.k"] = k
        collector[f"{prefix}.v"] = v

    if impl == "pallas":
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, window=cfg.sliding_window)
        out = out.reshape(bsz, s, h * hd)
    elif impl == "flash":
        from repro.kernels import ops
        if attn_scores is not None:
            tap = (tape.score_tap(f"{prefix}.qkv_scores", bsz)
                   if tape is not None else jnp.zeros((bsz,), jnp.float32))
            if attn_scores == "fused":
                fa = ops.make_flash_attention_trainable(
                    window=cfg.sliding_window, with_scores=True)
                out = fa(q, k, v, tap)
            else:
                probe = ops.make_qkv_score_probe()
                q, k, v = probe(q, k, v, tap)
                fa = ops.make_flash_attention_trainable(
                    window=cfg.sliding_window)
                out = fa(q, k, v)
        else:
            fa = ops.make_flash_attention_trainable(
                window=cfg.sliding_window)
            out = fa(q, k, v)
        out = out.reshape(bsz, s, h * hd)
    else:
        qg = q.reshape(bsz, s, hkv, rep, hd)
        out = _chunked_attention(qg, k, v, positions, positions,
                                 cfg.sliding_window, q_chunk)
        out = out.reshape(bsz, s, h * hd)
    y = tapped_linear(out, params["wo"], f"{prefix}.wo", tape)
    return psum_forward(y, model_axes) if sharded else y


def attn_decode(params: Params, x: jax.Array, cfg: ModelConfig,
                k_cache: jax.Array, v_cache: jax.Array,
                cache_positions: jax.Array, lengths: jax.Array,
                decode_kernel=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B,D); caches (B,W,Hkv,hd) with absolute
    positions `cache_positions` (B,W) (supports ring buffers); `lengths`
    (B,) = number of valid cache slots *including* the new token's slot.

    Returns (out (B,D), k_new, v_new) — cache writing is the caller's job
    (the serving engine owns the layout).
    """
    bsz, _ = x.shape
    hd = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    pos = lengths - 1  # absolute position of the new token... caller overrides

    q = (x @ params["wq"]).reshape(bsz, h, hd)
    k_new = (x @ params["wk"]).reshape(bsz, hkv, hd)
    v_new = (x @ params["wv"]).reshape(bsz, hkv, hd)
    return q, k_new, v_new  # projection only; engine runs the kernel


# ===================================================================== MLA
def init_mla(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    h = cfg.num_heads
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    qr = cfg.q_lora_rank or cfg.d_model
    p = {
        "wkv_a": _dense_init(ks[0], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank, dtype),
        "wkv_b": _dense_init(ks[1], cfg.kv_lora_rank,
                             h * (cfg.qk_nope_dim + cfg.v_head_dim), dtype),
        "wo": _dense_init(ks[2], h * cfg.v_head_dim, cfg.d_model, dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = _dense_init(ks[3], cfg.d_model, qr, dtype)
        p["q_norm"] = init_rmsnorm(qr, dtype)
        p["wq_b"] = _dense_init(ks[4], qr, h * qk_dim, dtype)
    else:
        p["wq"] = _dense_init(ks[5], cfg.d_model, h * qk_dim, dtype)
    return p


def specs_mla(cfg: ModelConfig) -> Params:
    p = {"wkv_a": ("embed", "rank"), "kv_norm": specs_rmsnorm(),
         "wkv_b": ("rank", "heads"), "wo": ("heads", "embed")}
    if cfg.q_lora_rank:
        p["wq_a"] = ("embed", "rank")
        p["q_norm"] = specs_rmsnorm()
        p["wq_b"] = ("rank", "heads")
    else:
        p["wq"] = ("embed", "heads")
    return p


def mla_shard_info(params: Params, cfg: ModelConfig) -> tuple[bool, int]:
    """(sharded, local_heads) for an MLA parameter tree.

    The latent projections (wq_a / wkv_a) are always replicated (their
    "rank" axis maps to no mesh axis); the per-head expansions (wq or
    wq_b, wkv_b) and the output projection wo shard whole heads.  A split
    that is inconsistent across the three, or lands mid-head, raises with
    the config field to fix."""
    h = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    o_rows = params["wo"].shape[0]
    kvb_cols = params["wkv_b"].shape[-1]
    q_cols = (params["wq_b"] if cfg.q_lora_rank else params["wq"]).shape[-1]
    if o_rows == h * vdim and kvb_cols == h * (nope + vdim) \
            and q_cols == h * (nope + rdim):
        return False, h
    if o_rows % vdim or kvb_cols % (nope + vdim) or q_cols % (nope + rdim):
        raise ValueError(
            f"MLA model-axis shard splits mid-head (wo rows={o_rows}, "
            f"wkv_b cols={kvb_cols}, wq cols={q_cols}): the model-parallel "
            f"degree must divide num_heads ({cfg.num_heads})")
    h_l = o_rows // vdim
    if kvb_cols != h_l * (nope + vdim) or q_cols != h_l * (nope + rdim):
        raise ValueError(
            f"MLA is only partially model-sharded (local heads: wo "
            f"{o_rows // vdim}, wkv_b {kvb_cols // (nope + vdim)}, wq "
            f"{q_cols // (nope + rdim)}): the model-parallel degree must "
            f"divide num_heads ({cfg.num_heads}) for every per-head "
            f"projection")
    return True, h_l


def _mla_qkv(params, x, cfg: ModelConfig, positions, tape, prefix,
             model_axes: tuple[str, ...] = (), h: Optional[int] = None):
    """Shared projections. Returns q_nope,q_rope,k_nope,k_rope,v, latent.

    `h` is the (possibly local) head count; with ``model_axes`` set the
    replicated latent/query inputs of the head-sharded expansions are
    wrapped in `psum_backward` so their input gradients stay exact."""
    from repro.core.collectives import psum_backward
    model_axes = tuple(model_axes)
    bsz, s, _ = x.shape
    if h is None:
        h = cfg.num_heads
    sharded = bool(model_axes) and h != cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        qa = tapped_linear(x, params["wq_a"], f"{prefix}.wq_a", tape)
        qa = rmsnorm(params["q_norm"], qa, cfg.norm_eps)
        if sharded:
            qa = psum_backward(qa, model_axes)
        q = tapped_linear(qa, params["wq_b"], f"{prefix}.wq_b", tape)
    else:
        xq = psum_backward(x, model_axes) if sharded else x
        q = tapped_linear(xq, params["wq"], f"{prefix}.wq", tape)
    q = q.reshape(bsz, s, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = tapped_linear(x, params["wkv_a"], f"{prefix}.wkv_a", tape)
    latent, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    latent = rmsnorm(params["kv_norm"], latent, cfg.norm_eps)
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    if sharded:
        # the rope key is shared by every head, so under head sharding
        # each device's cotangent for it is only its local heads' partial
        k_rope = psum_backward(k_rope, model_axes)

    lat_in = psum_backward(latent, model_axes) if sharded else latent
    kv = tapped_linear(lat_in, params["wkv_b"], f"{prefix}.wkv_b", tape)
    kv = kv.reshape(bsz, s, h, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    return q_nope, q_rope, k_nope, k_rope, v, latent


def mla(params: Params, x: jax.Array, cfg: ModelConfig,
        positions: jax.Array, tape: Optional[Tape] = None,
        prefix: str = "attn", q_chunk: int = 512,
        collector: Optional[dict] = None,
        model_axes: tuple[str, ...] = ()) -> jax.Array:
    """Materialized MLA for train/prefill. x: (B,S,D).

    With ``model_axes`` and head-sharded expansions, the per-head math is
    local (the shared latent is replicated) and the row-sharded wo's
    partial output is `psum_forward`-reduced — same contract as `attn`."""
    from repro.core.collectives import psum_forward
    model_axes = tuple(model_axes)
    bsz, s, _ = x.shape
    sharded, h = (mla_shard_info(params, cfg) if model_axes
                  else (False, cfg.num_heads))
    q_nope, q_rope, k_nope, k_rope, v, latent = _mla_qkv(
        params, x, cfg, positions, tape, prefix,
        model_axes=model_axes if sharded else (), h=h)
    if collector is not None:  # prefill: the *compressed* MLA cache
        collector[f"{prefix}.latent"] = latent
        collector[f"{prefix}.rope"] = k_rope[:, :, 0, :]
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    q_chunk = min(q_chunk, s)
    pad = (-s) % q_chunk
    qn = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qp = jnp.pad(positions, ((0, 0), (0, pad)))
    nc = (s + pad) // q_chunk

    @jax.checkpoint
    def one_chunk(args):
        qn_c, qr_c, qp_c = args
        lg = jnp.einsum("bqhd,bkhd->bhqk", qn_c.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
        lg += jnp.einsum("bqhd,bkxd->bhqk", qr_c.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
        lg *= scale
        mask = _causal_window_mask(qp_c, positions, cfg.sliding_window)
        lg = jnp.where(mask[:, None], lg, _NEG)
        p = jax.nn.softmax(lg, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)

    def split(a, i):
        return a.reshape(bsz, nc, q_chunk, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    outs = jax.lax.map(one_chunk, (split(qn, 0), split(qr, 1),
                                   qp.reshape(bsz, nc, q_chunk).transpose(1, 0, 2)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(bsz, s + pad, h, cfg.v_head_dim)[:, :s]
    out = out.reshape(bsz, s, h * cfg.v_head_dim)
    y = tapped_linear(out, params["wo"], f"{prefix}.wo", tape)
    return psum_forward(y, model_axes) if sharded else y


def mla_decode(params: Params, x: jax.Array, cfg: ModelConfig,
               latent_cache: jax.Array, rope_cache: jax.Array,
               position: jax.Array, lengths: jax.Array,
               slot: Optional[jax.Array] = None,
               model_axes: tuple[str, ...] = ()) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed one-token MLA decode over the *compressed* cache.

    latent_cache: (B, W, kv_lora), rope_cache: (B, W, qk_rope_dim);
    position: (B,) absolute position of the new token; lengths: (B,) valid
    slots including the new one.  ``slot`` (B,) is where the new token is
    written (defaults to ``lengths - 1``, the linear layout; the engine
    passes the ring slot ``position mod W``).  Slot order never affects
    the output — the attention logits sum over cache slots and validity
    is tracked by ``lengths`` alone.  With ``model_axes`` the per-head
    expansions run on local heads and the wo output is psum-reduced; the
    returned latent/rope rows are head-independent, hence replicated.
    Returns (out (B,D), latent_new, rope_new)."""
    from repro.core.collectives import psum_forward
    bsz, _ = x.shape
    sharded, h = (mla_shard_info(params, cfg) if model_axes
                  else (False, cfg.num_heads))
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = (nope + rdim) ** -0.5

    xs = x[:, None, :]  # (B,1,D)
    pos = position[:, None]
    q_nope, q_rope, _, k_rope_new, _, latent_new = _mla_qkv(
        params, xs, cfg, pos, None, "decode",
        model_axes=model_axes if sharded else (), h=h)
    # absorb W_kv_b's key half into the query:  q_c = q_nope @ W_k^T (per head)
    wkv_b = params["wkv_b"].reshape(cfg.kv_lora_rank, h, nope + vdim)
    if slot is None:
        slot = lengths - 1
    w_k = wkv_b[..., :nope]              # (r, h, nope)
    w_v = wkv_b[..., nope:]              # (r, h, vdim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                     w_k.astype(jnp.float32))        # (B,h,r)

    # write the new token into the cache view (caller persists it)
    lc = latent_cache.at[jnp.arange(bsz), slot].set(latent_new[:, 0].astype(latent_cache.dtype))
    rc = rope_cache.at[jnp.arange(bsz), slot].set(k_rope_new[:, 0, 0].astype(rope_cache.dtype))

    lg = jnp.einsum("bhr,bkr->bhk", q_c, lc.astype(jnp.float32))
    lg += jnp.einsum("bhd,bkd->bhk", q_rope[:, 0].astype(jnp.float32),
                     rc.astype(jnp.float32))
    lg *= scale
    mask = jnp.arange(lc.shape[1])[None] < lengths[:, None]
    lg = jnp.where(mask[:, None], lg, _NEG)
    p = jax.nn.softmax(lg, axis=-1)
    ctx = jnp.einsum("bhk,bkr->bhr", p, lc.astype(jnp.float32))   # (B,h,r)
    out_h = jnp.einsum("bhr,rhd->bhd", ctx, w_v.astype(jnp.float32))  # (B,h,v)
    out = out_h.reshape(bsz, h * vdim).astype(x.dtype) @ params["wo"]
    if sharded:
        out = psum_forward(out, model_axes)
    return out, latent_new[:, 0], k_rope_new[:, 0, 0]

"""Decoder stack: scan-over-periods, remat, ghost-tape threading, decode.

The depth is organized as `num_periods` repetitions of a short layer
*period* (see ModelConfig.layer_specs) so heterogeneous stacks (jamba's
mamba/attention interleave, MoE-every-other-layer) still compile to one
rolled lax.scan.  Ghost taps enter as scan xs (stacked over periods) and
activation records leave as scan ys, which is what lets the scorer compute
exact per-example gradient norms through the scanned stack.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (Params, Tape, embed, init_embed, init_mlp,
                                 init_rmsnorm, mlp, rmsnorm, specs_embed,
                                 specs_mlp, specs_rmsnorm, unembed)


class Aux(NamedTuple):
    aux_loss: jax.Array                 # MoE load-balance loss (0 for dense)
    records: Optional[dict] = None      # name -> stacked activations (P,...)
    cache: Optional[dict] = None        # name -> stacked decode caches (P,...)


# ------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, spec) -> Params:
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {
        "ln1": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    if spec.mixer == "attn":
        p["mixer"] = (attn_mod.init_mla(k1, cfg) if cfg.attention == "mla"
                      else attn_mod.init_attn(k1, cfg))
    else:
        p["mixer"] = ssm_mod.init_mamba(k1, cfg)
    if cfg.d_ff > 0:  # pure-SSM stacks (falcon-mamba) have no FF sub-layer
        p["ln2"] = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
        p["ff"] = (moe_mod.init_moe(k2, cfg) if spec.ff == "moe"
                   else init_mlp(k2, cfg))
    return p


def _layer_specs_tree(cfg: ModelConfig, spec) -> Params:
    p: dict[str, Any] = {"ln1": specs_rmsnorm()}
    if spec.mixer == "attn":
        p["mixer"] = (attn_mod.specs_mla(cfg) if cfg.attention == "mla"
                      else attn_mod.specs_attn())
    else:
        p["mixer"] = ssm_mod.specs_mamba()
    if cfg.d_ff > 0:
        p["ln2"] = specs_rmsnorm()
        p["ff"] = moe_mod.specs_moe() if spec.ff == "moe" else specs_mlp()
    return p


def init_transformer(key, cfg: ModelConfig) -> Params:
    specs = cfg.layer_specs()
    k_embed, k_layers, k_final = jax.random.split(key, 3)

    def init_period(k):
        ks = jax.random.split(k, len(specs))
        return {f"l{i}": _init_layer(ks[i], cfg, s) for i, s in enumerate(specs)}

    period_keys = jax.random.split(k_layers, cfg.num_periods)
    layers = jax.vmap(init_period)(period_keys)  # leading period axis

    return {
        "embed": init_embed(k_embed, cfg),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


def transformer_specs(cfg: ModelConfig) -> Params:
    """Logical-axis tree matching init_transformer (period axis is first,
    expressed as a leading None in repro.dist.sharding)."""
    specs = cfg.layer_specs()
    return {
        "embed": specs_embed(cfg),
        "layers": {f"l{i}": _layer_specs_tree(cfg, s)
                   for i, s in enumerate(specs)},
        "final_norm": specs_rmsnorm(),
    }


# ---------------------------------------------------------------- forward
def _apply_layer(lp: Params, h: jax.Array, cfg: ModelConfig, spec,
                 positions: jax.Array, tape: Optional[Tape], prefix: str,
                 ssm_mode: str,
                 collector: Optional[dict] = None,
                 attn_impl: str = "ref") -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            mix = attn_mod.mla(lp["mixer"], hn, cfg, positions, tape,
                               prefix=f"{prefix}.attn", collector=collector)
        else:
            mix = attn_mod.attn(lp["mixer"], hn, cfg, positions, tape,
                                prefix=f"{prefix}.attn", collector=collector,
                                impl=attn_impl, q_chunk=cfg.attn_chunk)
    else:
        mix = ssm_mod.mamba(lp["mixer"], hn, cfg, tape,
                            prefix=f"{prefix}.mamba", mode=ssm_mode,
                            collector=collector)
    h = h + mix
    if cfg.d_ff == 0:
        return h, aux
    hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
    if spec.ff == "moe":
        out = moe_mod.moe(lp["ff"], hn, cfg, tape, prefix=f"{prefix}.moe")
        ff_y, aux = out.y, out.aux_loss
    else:
        ff_y = mlp(lp["ff"], hn, cfg, tape, prefix=f"{prefix}.mlp")
    return h + ff_y, aux


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                      # (B, S_text) int32
    *,
    embeds: Optional[jax.Array] = None,     # (B, N_front, D) frontend stub
    positions: Optional[jax.Array] = None,
    taps: Optional[dict] = None,            # name -> (P, ...) stacked taps
    collect: bool = False,
    collect_cache: bool = False,
    ssm_mode: str = "ref",
    attn_impl: str = "ref",                 # "pallas" = flash kernel (fwd-only)
    return_hidden: bool = False,            # skip unembed, return final h
) -> tuple[jax.Array, Aux]:
    """Returns logits (B, S_total, vocab) and Aux.

    collect_cache=True additionally returns, in Aux.cache, the per-layer
    decode caches (roped K/V, MLA latents, mamba states) stacked over
    periods — the prefill path of the serving engine.
    """
    from repro.dist.context import constrain_batch_dim as _cbd
    specs = cfg.layer_specs()
    h = embed(params["embed"], tokens, cfg)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    h = _cbd(h)
    bsz, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))

    # the unembed tap lives outside the scan (no period axis)
    head_tap = None
    if taps is not None and "unembed" in taps:
        taps = dict(taps)
        head_tap = taps.pop("unembed")

    from repro.dist.context import constrain_batch_dim

    def period_body(carry, per):
        h, aux_acc = carry
        h = constrain_batch_dim(h)
        pp, ptaps = per
        tape = Tape(taps=ptaps, records={} if collect else None)
        cache = {} if collect_cache else None
        for i, spec in enumerate(specs):
            h, aux = _apply_layer(pp[f"l{i}"], h, cfg, spec, positions,
                                  tape, f"l{i}", ssm_mode, collector=cache,
                                  attn_impl=attn_impl)
            aux_acc = aux_acc + aux
        ys = (tape.records if collect else 0,
              cache if collect_cache else 0)
        return (h, aux_acc), ys

    if cfg.remat:
        period_body = jax.checkpoint(period_body)

    if taps is None:
        # feed dummy zero-leaf xs so the scan signature is stable
        taps_xs = jnp.zeros((cfg.num_periods,), jnp.float32)
        per_xs = (params["layers"], taps_xs)

        def body(carry, per):
            pp, _ = per
            return period_body(carry, (pp, None))
    else:
        per_xs = (params["layers"], taps)
        body = period_body

    (h, aux_loss), (records, cache) = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), per_xs)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, Aux(aux_loss=aux_loss,
                      records=records if collect else None,
                      cache=cache if collect_cache else None)
    head_tape = Tape(taps={"unembed": head_tap} if head_tap is not None else None,
                     records={} if collect else None)
    logits = unembed(params["embed"], h, cfg, tape=head_tape)
    if collect:
        records = dict(records)
        records.update(head_tape.records)
    return logits, Aux(aux_loss=aux_loss,
                       records=records if collect else None,
                       cache=cache if collect_cache else None)


def tap_structure(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs (with the leading period axis) for every tap."""
    specs = cfg.layer_specs()
    h = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))
    positions = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    # shapes only — use eval_shape with abstract params from init structure
    layer0 = jax.eval_shape(
        lambda k: {f"l{i}": _init_layer(k, cfg, s)
                   for i, s in enumerate(specs)}, jax.random.key(0))

    tap_shapes: dict = {}

    def run(h, positions, layers0):
        tape = Tape(tap_shapes=tap_shapes)
        hh = h
        for i, spec in enumerate(specs):
            hh, _ = _apply_layer(layers0[f"l{i}"], hh, cfg, spec, positions,
                                 tape, f"l{i}", "ref")
        return hh

    jax.eval_shape(run, h, positions, layer0)
    out = {
        name: jax.ShapeDtypeStruct((cfg.num_periods,) + sds.shape, sds.dtype)
        for name, sds in tap_shapes.items()
    }
    out["unembed"] = jax.ShapeDtypeStruct((batch, seq, cfg.vocab_size),
                                          jnp.float32)
    return out


# ------------------------------------------------------------------- loss
def lm_head_metrics(params, cfg: ModelConfig, h: jax.Array,
                    targets: jax.Array,
                    mask: Optional[jax.Array] = None):
    """Chunked unembed + CE: per-example (mean_nll, logit_grad_norm).

    Never materializes the full (B,S,V) logits — each sequence chunk is
    projected, reduced, and rematerialized in the backward pass
    (jax.checkpoint).  This is what lets the 100k+-vocab configs train.

    logit_grad_norm is ||∂L_n/∂logits||₂ of the *mean* per-example loss —
    the forward-only scoring proxy (see core/scorer.py).
    """
    bsz, s, _ = h.shape
    chunk = cfg.loss_chunk if cfg.loss_chunk > 0 else s
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((bsz, s), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((bsz, s), jnp.float32)
    nc = (s + pad) // chunk

    def split(a):
        return a.reshape(bsz, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def one(args):
        h_c, t_c, m_c = args
        logits = unembed(params["embed"], h_c, cfg).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, t_c[..., None], -1)[..., 0]
        p = jnp.exp(lp)
        p_y = jnp.take_along_axis(p, t_c[..., None], -1)[..., 0]
        gsq = jnp.sum(jnp.square(p), -1) - 2.0 * p_y + 1.0
        return (jnp.sum(nll * m_c, -1), jnp.sum(gsq * m_c, -1))

    nll_c, gsq_c = jax.lax.map(one, (split(h), split(targets), split(mask)))
    count = jnp.maximum(jnp.sum(mask, -1), 1.0)
    mean_nll = jnp.sum(nll_c, 0) / count
    grad_norm = jnp.sqrt(jnp.sum(gsq_c, 0)) / count
    return mean_nll, grad_norm


def per_example_loss(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    taps: Optional[dict] = None,
    collect: bool = False,
    ssm_mode: str = "ref",
) -> tuple[jax.Array, Aux]:
    """Mean next-token CE per example. batch: {tokens (B,S), [embeds]}.

    Frontend embeds (if any) are prepended; loss is computed on the token
    region only.
    """
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    n_front = embeds.shape[1] if embeds is not None else 0
    targets = tokens[:, 1:]
    if cfg.loss_chunk > 0 and taps is None:
        h, aux = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                         collect=collect, ssm_mode=ssm_mode,
                         return_hidden=True)
        h = h[:, n_front:]
        mask = batch.get("mask")
        mean_nll, _ = lm_head_metrics(params, cfg, h, targets,
                                      None if mask is None else
                                      mask[:, 1:].astype(jnp.float32))
        return mean_nll, aux
    logits, aux = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                          taps=taps, collect=collect, ssm_mode=ssm_mode)
    logits = logits[:, n_front:]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m, axis=-1) / jnp.maximum(jnp.sum(m, -1), 1.0)
    else:
        loss = jnp.mean(nll, axis=-1)
    return loss, aux


def per_example_loss_and_score(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    ssm_mode: str = "ref",
) -> tuple[jax.Array, jax.Array]:
    """Fused-mode objective: (losses (B,), logit-grad scores (B,)) from a
    SINGLE forward pass — the scores the paper's workers compute in a
    separate pass come for free from the head computation (see
    core/issgd.py mode='fused')."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    n_front = embeds.shape[1] if embeds is not None else 0
    h, _ = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                   ssm_mode=ssm_mode, return_hidden=True)
    mask = batch.get("mask")
    mean_nll, grad_norm = lm_head_metrics(
        params, cfg, h[:, n_front:], tokens[:, 1:],
        None if mask is None else mask[:, 1:].astype(jnp.float32))
    return mean_nll, grad_norm

"""Decoder stack: scan-over-periods, remat, ghost-tape threading, decode.

The depth is organized as `num_periods` repetitions of a short layer
*period* (see ModelConfig.layer_specs) so heterogeneous stacks (jamba's
mamba/attention interleave, MoE-every-other-layer) still compile to one
rolled lax.scan.  Ghost taps enter as scan xs (stacked over periods) and
activation records leave as scan ys, which is what lets the scorer compute
exact per-example gradient norms through the scanned stack.

With ``model_axes`` the whole stack runs tensor-parallel inside shard_map
(head-sharded attention, ffn-sharded MLP/MoE, channel-sharded mamba,
vocab-parallel embed/unembed), each sub-layer detecting its own
shardedness from the local parameter shapes; ``seq_shard=True`` makes the
RMSNorm segments sequence-parallel (Megatron-SP style) so no gathered
full-sequence activation exists in those segments.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (Params, Tape, embed, init_embed, init_mlp,
                                 init_rmsnorm, mlp, rmsnorm, specs_embed,
                                 specs_mlp, specs_rmsnorm, unembed)


class Aux(NamedTuple):
    aux_loss: jax.Array                 # MoE load-balance loss (0 for dense)
    records: Optional[dict] = None      # name -> stacked activations (P,...)
    cache: Optional[dict] = None        # name -> stacked decode caches (P,...)


# ------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, spec) -> Params:
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {
        "ln1": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    if spec.mixer == "attn":
        p["mixer"] = (attn_mod.init_mla(k1, cfg) if cfg.attention == "mla"
                      else attn_mod.init_attn(k1, cfg))
    else:
        p["mixer"] = ssm_mod.init_mamba(k1, cfg)
    if cfg.d_ff > 0:  # pure-SSM stacks (falcon-mamba) have no FF sub-layer
        p["ln2"] = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
        p["ff"] = (moe_mod.init_moe(k2, cfg) if spec.ff == "moe"
                   else init_mlp(k2, cfg))
    return p


def _layer_specs_tree(cfg: ModelConfig, spec) -> Params:
    p: dict[str, Any] = {"ln1": specs_rmsnorm()}
    if spec.mixer == "attn":
        p["mixer"] = (attn_mod.specs_mla(cfg) if cfg.attention == "mla"
                      else attn_mod.specs_attn())
    else:
        p["mixer"] = ssm_mod.specs_mamba()
    if cfg.d_ff > 0:
        p["ln2"] = specs_rmsnorm()
        p["ff"] = moe_mod.specs_moe() if spec.ff == "moe" else specs_mlp()
    return p


def init_transformer(key, cfg: ModelConfig) -> Params:
    specs = cfg.layer_specs()
    k_embed, k_layers, k_final = jax.random.split(key, 3)

    def init_period(k):
        ks = jax.random.split(k, len(specs))
        return {f"l{i}": _init_layer(ks[i], cfg, s) for i, s in enumerate(specs)}

    period_keys = jax.random.split(k_layers, cfg.num_periods)
    layers = jax.vmap(init_period)(period_keys)  # leading period axis

    return {
        "embed": init_embed(k_embed, cfg),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


def transformer_specs(cfg: ModelConfig) -> Params:
    """Logical-axis tree matching init_transformer (period axis is first,
    expressed as a leading None in repro.dist.sharding)."""
    specs = cfg.layer_specs()
    return {
        "embed": specs_embed(cfg),
        "layers": {f"l{i}": _layer_specs_tree(cfg, s)
                   for i, s in enumerate(specs)},
        "final_norm": specs_rmsnorm(),
    }


# ---------------------------------------------------------------- forward
def _sp_active(h: jax.Array, model_axes: tuple[str, ...],
               seq_shard: bool) -> bool:
    """Whether the sequence-parallel norm segment applies: requested, a
    real model axis, and a sequence length the axis divides (static)."""
    if not (seq_shard and model_axes):
        return False
    from repro.core.collectives import axis_info
    _, n_model = axis_info(tuple(model_axes))
    return h.shape[1] % n_model == 0


def _norm_segment(ln: Params, h: jax.Array, cfg: ModelConfig,
                  model_axes: tuple[str, ...], seq_shard: bool) -> jax.Array:
    """RMSNorm, optionally as a sequence-parallel segment.

    With sequence parallelism active the replicated residual is
    `scatter_seq`-sliced so each model device normalizes 1/M of the
    positions (the Megatron-SP LayerNorm segment: the only full-sequence
    activation here is the residual itself, never a gathered intermediate),
    then `all_gather_replicated` over the sequence dim rebuilds the exact
    replicated input for the sharded mixer/FFN.  The norm scale is wrapped
    in `psum_backward` so its per-slice partial gradients reduce to the
    replicated exact gradient — keeping every parameter gradient
    replicated over the model axes, which the master pass relies on."""
    from repro.core.collectives import (all_gather_replicated, psum_backward,
                                        scatter_seq)
    if not _sp_active(h, model_axes, seq_shard):
        return rmsnorm(ln, h, cfg.norm_eps)
    axes = tuple(model_axes)
    hs = scatter_seq(h, axes, axis=1)
    sc = {"scale": psum_backward(ln["scale"], axes)}
    return all_gather_replicated(rmsnorm(sc, hs, cfg.norm_eps), axes, axis=1)


def _apply_layer(lp: Params, h: jax.Array, cfg: ModelConfig, spec,
                 positions: jax.Array, tape: Optional[Tape], prefix: str,
                 ssm_mode: str,
                 collector: Optional[dict] = None,
                 attn_impl: str = "ref",
                 model_axes: tuple[str, ...] = (),
                 seq_shard: bool = False,
                 attn_scores: Optional[str] = None,
                 pad_mask: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    hn = _norm_segment(lp["ln1"], h, cfg, model_axes, seq_shard)
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            if attn_scores is not None:
                raise ValueError(
                    "attn_scores (the fused flash-bwd score tap) is a GQA "
                    "flash-kernel feature; attention='mla' has no flash "
                    "backward — use the default ghost taps instead")
            mix = attn_mod.mla(lp["mixer"], hn, cfg, positions, tape,
                               prefix=f"{prefix}.attn", collector=collector,
                               model_axes=model_axes)
        else:
            mix = attn_mod.attn(lp["mixer"], hn, cfg, positions, tape,
                                prefix=f"{prefix}.attn", collector=collector,
                                impl=attn_impl, q_chunk=cfg.attn_chunk,
                                model_axes=model_axes,
                                attn_scores=attn_scores)
    else:
        mix = ssm_mod.mamba(lp["mixer"], hn, cfg, tape,
                            prefix=f"{prefix}.mamba", mode=ssm_mode,
                            collector=collector, model_axes=model_axes,
                            pad_mask=pad_mask)
    h = h + mix
    if cfg.d_ff == 0:
        return h, aux
    hn = _norm_segment(lp["ln2"], h, cfg, model_axes, seq_shard)
    if spec.ff == "moe":
        out = moe_mod.moe(lp["ff"], hn, cfg, tape, prefix=f"{prefix}.moe",
                          model_axes=model_axes)
        ff_y, aux = out.y, out.aux_loss
    else:
        ff_y = mlp(lp["ff"], hn, cfg, tape, prefix=f"{prefix}.mlp",
                   model_axes=model_axes)
    return h + ff_y, aux


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                      # (B, S_text) int32
    *,
    embeds: Optional[jax.Array] = None,     # (B, N_front, D) frontend stub
    positions: Optional[jax.Array] = None,
    taps: Optional[dict] = None,            # name -> (P, ...) stacked taps
    collect: bool = False,
    collect_cache: bool = False,
    ssm_mode: str = "ref",
    attn_impl: str = "ref",                 # "pallas" fwd-only | "flash" trainable
    return_hidden: bool = False,            # skip unembed, return final h
    model_axes: tuple[str, ...] = (),       # mesh axes the params are
    # tensor-sharded over when running inside shard_map; () = replicated
    seq_shard: bool = False,                # sequence-parallel norm segments
    attn_scores: Optional[str] = None,      # "fused"/"separate" score taps
    pad_mask: Optional[jax.Array] = None,   # (B,S) bool: real positions of a
    # right-padded batch (bucketed prefill); only the mamba scan needs it —
    # causal attention is pad-exact for real rows by construction
) -> tuple[jax.Array, Aux]:
    """Returns logits (B, S_total, vocab) and Aux.

    collect_cache=True additionally returns, in Aux.cache, the per-layer
    decode caches (roped K/V, MLA latents, mamba states) stacked over
    periods — the prefill path of the serving engine.

    With ``model_axes`` set the stack is model-axis-aware end to end
    (vocab-parallel embed/unembed, head-sharded attention, ffn-sharded
    MLP/MoE experts, channel-sharded mamba — each detecting its own
    shardedness from the local shapes); ``seq_shard=True`` additionally
    runs the RMSNorm segments sequence-parallel.  Both are exact: outputs
    match the replicated run up to psum reassociation.
    """
    from repro.dist.context import constrain_batch_dim as _cbd
    model_axes = tuple(model_axes)
    specs = cfg.layer_specs()
    h = embed(params["embed"], tokens, cfg, model_axes=model_axes)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    h = _cbd(h)
    bsz, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))

    # the unembed tap lives outside the scan (no period axis)
    head_tap = None
    if taps is not None and "unembed" in taps:
        taps = dict(taps)
        head_tap = taps.pop("unembed")

    from repro.dist.context import constrain_batch_dim

    def period_body(carry, per):
        h, aux_acc = carry
        h = constrain_batch_dim(h)
        pp, ptaps = per
        tape = Tape(taps=ptaps, records={} if collect else None)
        cache = {} if collect_cache else None
        for i, spec in enumerate(specs):
            h, aux = _apply_layer(pp[f"l{i}"], h, cfg, spec, positions,
                                  tape, f"l{i}", ssm_mode, collector=cache,
                                  attn_impl=attn_impl, model_axes=model_axes,
                                  seq_shard=seq_shard,
                                  attn_scores=attn_scores,
                                  pad_mask=pad_mask)
            aux_acc = aux_acc + aux
        ys = (tape.records if collect else 0,
              cache if collect_cache else 0)
        return (h, aux_acc), ys

    if cfg.remat:
        period_body = jax.checkpoint(period_body)

    if taps is None:
        # feed dummy zero-leaf xs so the scan signature is stable
        taps_xs = jnp.zeros((cfg.num_periods,), jnp.float32)
        per_xs = (params["layers"], taps_xs)

        def body(carry, per):
            pp, _ = per
            return period_body(carry, (pp, None))
    else:
        per_xs = (params["layers"], taps)
        body = period_body

    (h, aux_loss), (records, cache) = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), per_xs)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, Aux(aux_loss=aux_loss,
                      records=records if collect else None,
                      cache=cache if collect_cache else None)
    head_tape = Tape(taps={"unembed": head_tap} if head_tap is not None else None,
                     records={} if collect else None)
    logits = unembed(params["embed"], h, cfg, tape=head_tape,
                     model_axes=model_axes)
    if collect:
        records = dict(records)
        records.update(head_tape.records)
    return logits, Aux(aux_loss=aux_loss,
                       records=records if collect else None,
                       cache=cache if collect_cache else None)


def tap_structure(cfg: ModelConfig, batch: int, seq: int,
                  attn_impl: str = "ref",
                  attn_scores: Optional[str] = None) -> dict:
    """ShapeDtypeStructs (with the leading period axis) for every tap.

    ``attn_impl``/``attn_scores`` must match the forward the taps feed:
    an active score tap replaces the wq/wk/wv taps with one (B,) score
    tap per attention layer."""
    specs = cfg.layer_specs()
    h = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))
    positions = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    # shapes only — use eval_shape with abstract params from init structure
    layer0 = jax.eval_shape(
        lambda k: {f"l{i}": _init_layer(k, cfg, s)
                   for i, s in enumerate(specs)}, jax.random.key(0))

    tap_shapes: dict = {}

    def run(h, positions, layers0):
        tape = Tape(tap_shapes=tap_shapes)
        hh = h
        for i, spec in enumerate(specs):
            hh, _ = _apply_layer(layers0[f"l{i}"], hh, cfg, spec, positions,
                                 tape, f"l{i}", "ref", attn_impl=attn_impl,
                                 attn_scores=attn_scores)
        return hh

    jax.eval_shape(run, h, positions, layer0)
    out = {
        name: jax.ShapeDtypeStruct((cfg.num_periods,) + sds.shape, sds.dtype)
        for name, sds in tap_shapes.items()
    }
    out["unembed"] = jax.ShapeDtypeStruct((batch, seq, cfg.vocab_size),
                                          jnp.float32)
    return out


def tap_structure_from_params(params: Params, cfg: ModelConfig, batch: int,
                              seq: int, model_axes: tuple[str, ...] = (),
                              ssm_mode: str = "ref",
                              attn_impl: str = "ref",
                              attn_scores: Optional[str] = None) -> dict:
    """Tap ShapeDtypeStructs derived from the CONCRETE parameter tree.

    `tap_structure` assumes full (replicated) parameter shapes; inside a
    model-parallel shard_map the column-sharded layers' taps carry only
    this device's dY slice, so the shapes must come from the local params.
    One abstract trace of the period body (with ``model_axes`` threaded,
    the same per-layer shard detection the real forward runs) yields every
    tap shape; the unembed tap is the gathered full-vocab logits."""
    specs = cfg.layer_specs()
    layers0 = jax.tree.map(lambda a: a[0], params["layers"])
    tap_shapes: dict = {}
    h = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))
    positions = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    def run(h, positions):
        tape = Tape(tap_shapes=tap_shapes)
        hh = h
        for i, spec in enumerate(specs):
            hh, _ = _apply_layer(layers0[f"l{i}"], hh, cfg, spec, positions,
                                 tape, f"l{i}", ssm_mode,
                                 model_axes=model_axes,
                                 attn_impl=attn_impl,
                                 attn_scores=attn_scores)
        return hh

    jax.eval_shape(run, h, positions)
    out = {
        name: jax.ShapeDtypeStruct((cfg.num_periods,) + sds.shape, sds.dtype)
        for name, sds in tap_shapes.items()
    }
    out["unembed"] = jax.ShapeDtypeStruct((batch, seq, cfg.vocab_size),
                                          jnp.float32)
    return out


def sharded_tap_names(params: Params, cfg: ModelConfig,
                      attn_scores: Optional[str] = None) -> set:
    """Tap names whose ghost contributions are model-axis PARTIAL sums.

    Column-sharded layers tap this device's dY slice, row-sharded layers
    record this device's input slice — either way the per-example squared
    norm computed locally is a partial term the scorer psums over the
    model axes.  Replicated layers (the router, the latent projections,
    in_proj, and the unembed term — computed redundantly from full
    operands on every model device) are NOT in the set; the scorer counts
    those once by pre-dividing by the axis size.  Detection mirrors the
    forward's own shape-based shard checks, so divisibility fallbacks
    classify correctly per layer."""
    from repro.models import attention as attn_mod
    from repro.models import ssm as ssm_mod
    specs = cfg.layer_specs()
    layers0 = jax.tree.map(lambda a: a[0], params["layers"])
    names: set = set()
    for i, spec in enumerate(specs):
        lp = layers0[f"l{i}"]
        if spec.mixer == "attn":
            if cfg.attention == "mla":
                sharded, _ = attn_mod.mla_shard_info(lp["mixer"], cfg)
                if sharded:
                    names |= {f"l{i}.attn.wkv_b", f"l{i}.attn.wo",
                              (f"l{i}.attn.wq_b" if cfg.q_lora_rank
                               else f"l{i}.attn.wq")}
            else:
                sharded, _, _ = attn_mod.attn_shard_info(lp["mixer"], cfg)
                if sharded:
                    # the fused score tap replaces the wq/wk/wv taps; its
                    # (B,) score is computed from this device's LOCAL
                    # head gradients, so it is a model-axis partial too
                    names |= ({f"l{i}.attn.qkv_scores", f"l{i}.attn.wo"}
                              if attn_scores is not None else
                              {f"l{i}.attn.wq", f"l{i}.attn.wk",
                               f"l{i}.attn.wv", f"l{i}.attn.wo"})
        else:
            sharded, _ = ssm_mod.mamba_shard_info(lp["mixer"], cfg)
            if sharded:
                names |= {f"l{i}.mamba.x_proj", f"l{i}.mamba.out_proj"}
        if cfg.d_ff > 0 and spec.ff == "mlp" \
                and lp["ff"]["w_in"].shape[-1] != cfg.d_ff:
            names |= {f"l{i}.mlp.w_in", f"l{i}.mlp.w_gate",
                      f"l{i}.mlp.w_out"}
        # MoE: only the (replicated) router is tapped — never partial
    return names


# ------------------------------------------------------------------- loss
def lm_head_metrics(params, cfg: ModelConfig, h: jax.Array,
                    targets: jax.Array,
                    mask: Optional[jax.Array] = None,
                    model_axes: tuple[str, ...] = ()):
    """Chunked unembed + CE: per-example (mean_nll, logit_grad_norm).

    Never materializes the full (B,S,V) logits — each sequence chunk is
    projected, reduced, and rematerialized in the backward pass
    (jax.checkpoint).  This is what lets the 100k+-vocab configs train.

    logit_grad_norm is ||∂L_n/∂logits||₂ of the *mean* per-example loss —
    the forward-only scoring proxy (see core/scorer.py).
    """
    bsz, s, _ = h.shape
    chunk = cfg.loss_chunk if cfg.loss_chunk > 0 else s
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((bsz, s), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((bsz, s), jnp.float32)
    nc = (s + pad) // chunk

    def split(a):
        return a.reshape(bsz, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def one(args):
        h_c, t_c, m_c = args
        logits = unembed(params["embed"], h_c, cfg,
                         model_axes=model_axes).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, t_c[..., None], -1)[..., 0]
        p = jnp.exp(lp)
        p_y = jnp.take_along_axis(p, t_c[..., None], -1)[..., 0]
        gsq = jnp.sum(jnp.square(p), -1) - 2.0 * p_y + 1.0
        return (jnp.sum(nll * m_c, -1), jnp.sum(gsq * m_c, -1))

    nll_c, gsq_c = jax.lax.map(one, (split(h), split(targets), split(mask)))
    count = jnp.maximum(jnp.sum(mask, -1), 1.0)
    mean_nll = jnp.sum(nll_c, 0) / count
    grad_norm = jnp.sqrt(jnp.sum(gsq_c, 0)) / count
    return mean_nll, grad_norm


def per_example_loss(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    taps: Optional[dict] = None,
    collect: bool = False,
    ssm_mode: str = "ref",
    model_axes: tuple[str, ...] = (),
    seq_shard: bool = False,
    attn_impl: str = "ref",
    attn_scores: Optional[str] = None,
) -> tuple[jax.Array, Aux]:
    """Mean next-token CE per example. batch: {tokens (B,S), [embeds]}.

    Frontend embeds (if any) are prepended; loss is computed on the token
    region only.  ``model_axes``/``seq_shard`` thread through `forward`
    for model-parallel execution inside shard_map; so do
    ``attn_impl``/``attn_scores`` (the trainable flash kernel and its
    fused ghost-score tap, see models/attention.attn).
    """
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    n_front = embeds.shape[1] if embeds is not None else 0
    targets = tokens[:, 1:]
    if cfg.loss_chunk > 0 and taps is None:
        h, aux = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                         collect=collect, ssm_mode=ssm_mode,
                         return_hidden=True, model_axes=model_axes,
                         seq_shard=seq_shard, attn_impl=attn_impl,
                         attn_scores=attn_scores)
        h = h[:, n_front:]
        mask = batch.get("mask")
        mean_nll, _ = lm_head_metrics(params, cfg, h, targets,
                                      None if mask is None else
                                      mask[:, 1:].astype(jnp.float32),
                                      model_axes=model_axes)
        return mean_nll, aux
    logits, aux = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                          taps=taps, collect=collect, ssm_mode=ssm_mode,
                          model_axes=model_axes, seq_shard=seq_shard,
                          attn_impl=attn_impl, attn_scores=attn_scores)
    logits = logits[:, n_front:]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m, axis=-1) / jnp.maximum(jnp.sum(m, -1), 1.0)
    else:
        loss = jnp.mean(nll, axis=-1)
    return loss, aux


def per_example_loss_and_score(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    ssm_mode: str = "ref",
    model_axes: tuple[str, ...] = (),
    seq_shard: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused-mode objective: (losses (B,), logit-grad scores (B,)) from a
    SINGLE forward pass — the scores the paper's workers compute in a
    separate pass come for free from the head computation (see
    core/issgd.py mode='fused').  The score is closed-form from the
    gathered (replicated) logits, so under ``model_axes`` it needs no
    extra reduction — it is exact and replicated as-is."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    n_front = embeds.shape[1] if embeds is not None else 0
    h, _ = forward(params, cfg, tokens[:, :-1], embeds=embeds,
                   ssm_mode=ssm_mode, return_hidden=True,
                   model_axes=model_axes, seq_shard=seq_shard)
    mask = batch.get("mask")
    mean_nll, grad_norm = lm_head_metrics(
        params, cfg, h[:, n_front:], tokens[:, 1:],
        None if mask is None else mask[:, 1:].astype(jnp.float32),
        model_axes=model_axes)
    return mean_nll, grad_norm

"""The paper's own model: a permutation-invariant MLP classifier
(4 hidden layers × 2048 units, ReLU, softmax) — section 5.1.

This is the faithful-reproduction path: per-example gradient norms come
from Proposition 1 exactly (rank-1 Goodfellow trick), covering *all*
parameters of the model, so ISSGD here is the paper's exact algorithm.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Params, Tape


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str = "mlp_svhn"
    arch_type: str = "mlp"
    input_dim: int = 3072           # 32x32x3, flattened (permutation-invariant)
    num_classes: int = 10
    hidden: tuple = (2048, 2048, 2048, 2048)
    dtype: str = "float32"


def init_mlp_classifier(key, cfg: MLPConfig) -> Params:
    dims = (cfg.input_dim, *cfg.hidden, cfg.num_classes)
    ks = jax.random.split(key, len(dims) - 1)
    dtype = jnp.dtype(cfg.dtype)
    return {
        f"fc{i}": {
            "w": (jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
                  * (2.0 / dims[i]) ** 0.5).astype(dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i in range(len(dims) - 1)
    }


def mlp_specs(cfg: MLPConfig) -> Params:
    n = len(cfg.hidden) + 1
    return {f"fc{i}": {"w": ("embed", "ffn"), "b": ("ffn",)} for i in range(n)}


def mlp_dims(cfg: MLPConfig) -> tuple:
    return (cfg.input_dim, *cfg.hidden, cfg.num_classes)


def layer_is_sharded(params: Params, cfg: MLPConfig, i: int) -> bool:
    """Whether layer i's weight arrived as a model-axis column shard (its
    trailing dim is narrower than the config's full dim).  Uneven dims
    fall back to replication in `logical_to_pspec`, so shardedness is
    per-layer, not per-run."""
    return params[f"fc{i}"]["w"].shape[-1] != mlp_dims(cfg)[i + 1]


def mlp_forward(params: Params, x: jax.Array, cfg: MLPConfig,
                tape: Optional[Tape] = None,
                model_axes: tuple[str, ...] = ()) -> jax.Array:
    """x: (B, input_dim) → logits (B, num_classes).

    With ``model_axes`` set (inside shard_map on a mesh with a model axis)
    each column-sharded layer runs Megatron-style: the replicated input is
    wrapped in `psum_backward` (exact input-gradients), the matmul uses
    only the local weight columns, and the local output slice is
    all-gathered for the replicated consumer.  Ghost taps land on the
    *local* slice — the tap cotangent is this device's dY columns, so the
    per-layer ghost contributions are model-axis partial sums that the
    scorer psums into the exact per-example grad-norm.  Layers whose dims
    fell back to replication (see `logical_to_pspec`) skip all three
    wrappers.  With model_axes=() the path is byte-identical to before.
    """
    from repro.core.collectives import all_gather_replicated, psum_backward
    n = len(cfg.hidden) + 1
    h = x
    for i in range(n):
        p = params[f"fc{i}"]
        sharded = model_axes and layer_is_sharded(params, cfg, i)
        if sharded:
            h = psum_backward(h, model_axes)
        y = h @ p["w"] + p["b"]
        if tape is not None:
            y = tape.linear(f"fc{i}", h, y)
        if sharded:
            y = all_gather_replicated(y, model_axes, axis=-1)
        h = jax.nn.relu(y) if i < n - 1 else y
    return h


def per_example_loss(params: Params, batch: dict, cfg: MLPConfig,
                     tape: Optional[Tape] = None,
                     model_axes: tuple[str, ...] = ()) -> jax.Array:
    """Cross-entropy per example. batch: {x (B,D), y (B,)}."""
    logits = mlp_forward(params, batch["x"], cfg, tape, model_axes=model_axes)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.take_along_axis(lp, batch["y"][:, None], axis=-1)[:, 0]


def per_example_loss_and_score(params: Params, batch: dict, cfg: MLPConfig,
                               model_axes: tuple[str, ...] = ()
                               ) -> tuple[jax.Array, jax.Array]:
    """Fused-mode objective: (CE losses, logit-grad norms) in one forward.
    The score is closed-form from the (gathered, replicated) logits, so no
    model-axis reduction is needed — it is exact and replicated as-is."""
    logits = mlp_forward(params, batch["x"], cfg, model_axes=model_axes)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(lp, batch["y"][:, None], axis=-1)[:, 0]
    p = jnp.exp(lp)
    p_y = jnp.take_along_axis(p, batch["y"][:, None], -1)[:, 0]
    score = jnp.sqrt(jnp.sum(jnp.square(p), -1) - 2.0 * p_y + 1.0)
    return nll, score


def accuracy(params: Params, batch: dict, cfg: MLPConfig) -> jax.Array:
    logits = mlp_forward(params, batch["x"], cfg)
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

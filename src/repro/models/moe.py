"""Mixture-of-experts feed-forward with sort-based (megablocks-style) dispatch.

TPU adaptation: instead of the dense one-hot dispatch einsum (O(T·E·C)
memory) we sort token-replicas by expert id, place them into a
capacity-bounded (E, C, d) buffer with a single scatter, run the grouped
SwiGLU einsum on the MXU, and gather/combine back.  Tokens beyond capacity
are dropped (contribute zero), standard practice with capacity_factor ≥ 1.25.

Expert weights carry logical axes ("expert", "embed", "ffn") so storage is
FSDP over data and tensor-parallel over model; the scatter/gather pair is
what XLA turns into all-to-alls when the token and expert shardings differ.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, Tape, _dense_init, activation, tapped_linear


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array          # load-balance loss (Switch-style)
    dropped_frac: jax.Array      # monitoring


def init_moe(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    return {
        "router": _dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "w_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5)).astype(dtype),
    }


def specs_moe() -> Params:
    return {
        "router": ("embed", None),
        "w_in": ("expert", "embed", "ffn"),
        "w_gate": ("expert", "embed", "ffn"),
        "w_out": ("expert", "ffn", "embed"),
    }


def moe(params: Params, x: jax.Array, cfg: ModelConfig,
        tape: Optional[Tape] = None, prefix: str = "moe",
        dropless: bool = False,
        model_axes: tuple[str, ...] = ()) -> MoEOut:
    """x: (B,S,D) → MoEOut with y: (B,S,D).

    dropless=True sets capacity = all token replicas (exact, used at decode
    where T is tiny); training uses the capacity factor (tokens past
    capacity are dropped, standard for capacity-based MoE).

    With ``model_axes`` set and ffn-sharded expert weights (inside
    shard_map), the router — and therefore the gates, the aux loss, and
    the sort-based dispatch — stays fully replicated, so every model
    device routes identically; each expert's SwiGLU then runs the
    Megatron column/row pair on its local ffn slice (`psum_backward` on
    the dispatched buffer, `psum_forward` on the router-weighted partial
    outputs *before* the gate multiply, which keeps the router's
    cotangent — hence its gradient — replicated)."""
    from repro.core.collectives import psum_backward, psum_forward
    model_axes = tuple(model_axes)
    sharded = bool(model_axes) and params["w_in"].shape[-1] != cfg.d_ff
    bsz, s, d = x.shape
    t = bsz * s
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    act = activation(cfg.act)

    xf = x.reshape(t, d)
    logits = tapped_linear(xf, params["router"].astype(x.dtype),
                           f"{prefix}.router", tape).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T,E)
    gates, eidx = jax.lax.top_k(probs, k)                      # (T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch Transformer eq. 4-6)
    me = jnp.mean(probs, axis=0)                               # mean router prob
    one_hot_top = jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top, axis=0)                         # top-1 load
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    # ---- sort-based dispatch
    tk = t * k
    if dropless:
        cap = tk
    else:
        cap = max(int(cfg.moe_capacity_factor * tk / e + 0.5), 1)
    eflat = eidx.reshape(tk)
    token_of = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(eflat)                                 # stable
    sorted_e = eflat[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(tk) - first
    keep = pos_in_e < cap
    dst = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB → dropped

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[dst].set(xf[token_of[order]], mode="drop")
    buf = buf.reshape(e, cap, d)
    if sharded:
        buf = psum_backward(buf, model_axes)

    h_in = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    y_buf = jnp.einsum("ecf,efd->ecd", act(h_gate) * h_in, params["w_out"])

    y_sorted = jnp.take(y_buf.reshape(e * cap, d), dst, axis=0,
                        mode="fill", fill_value=0)             # (Tk, d)
    inv = jnp.argsort(order)
    y_flat = y_sorted[inv].reshape(t, k, d)
    if sharded:
        y_flat = psum_forward(y_flat, model_axes)
    y = jnp.sum(y_flat * gates[..., None].astype(x.dtype), axis=1)

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return MoEOut(y.reshape(bsz, s, d), aux, dropped)

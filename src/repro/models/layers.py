"""Shared neural-net layers: plain-pytree params, explicit init/apply/specs.

Conventions
-----------
* ``init_*(key, cfg, ...) -> params``  nested dicts of jnp arrays.
* ``specs_*(cfg) -> same tree`` of *logical axis* tuples (strings) that
  ``repro.dist.sharding`` maps onto the production mesh.
* Ghost-tape protocol: layers route every shared linear through
  :func:`tapped_linear`.  When a ``Tape`` is threaded, the layer input is
  recorded and a per-call "tap" (a zeros array added to the output) is
  injected so ∂L/∂tap recovers dL/dY for the ghost-norm scorer without
  touching parameter gradients.  With ``tape=None`` this is a plain matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Any


# ---------------------------------------------------------------- ghost tape
@dataclasses.dataclass
class Tape:
    """Mutable trace-time container for ghost scoring (see core/scorer.py)."""
    taps: Optional[dict] = None         # name -> array to ADD at linear output
    records: Optional[dict] = None      # name -> linear INPUT (set if not None)
    tap_shapes: Optional[dict] = None   # name -> ShapeDtypeStruct (collect mode)

    def linear(self, name: str, x: jax.Array, y: jax.Array) -> jax.Array:
        if self.records is not None:
            self.records[name] = x
        if self.tap_shapes is not None:
            self.tap_shapes[name] = jax.ShapeDtypeStruct(y.shape, jnp.float32)
        if self.taps is not None and name in self.taps:
            y = y + self.taps[name].astype(y.dtype)
        return y


def tapped_linear(x: jax.Array, w: jax.Array, name: str,
                  tape: Optional[Tape]) -> jax.Array:
    """y = x @ w with ghost-tape routing. x: (..., din), w: (din, dout)."""
    y = jnp.einsum("...i,io->...o", x, w)
    if tape is not None:
        y = tape.linear(name, x, y)
    return y


# ------------------------------------------------------------------- inits
def _dense_init(key, din, dout, dtype, scale: float | None = None):
    scale = scale if scale is not None else din ** -0.5
    return (jax.random.normal(key, (din, dout), jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def specs_rmsnorm() -> Params:
    return {"scale": ()}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., H, hd) with positions broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # add head axis
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- activation
def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": _dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
        "w_gate": _dense_init(k2, cfg.d_model, cfg.d_ff, dtype),
        "w_out": _dense_init(k3, cfg.d_ff, cfg.d_model, dtype),
    }


def specs_mlp() -> Params:
    return {
        "w_in": ("embed", "ffn"),
        "w_gate": ("embed", "ffn"),
        "w_out": ("ffn", "embed"),
    }


def mlp(params: Params, x: jax.Array, cfg: ModelConfig,
        tape: Optional[Tape] = None, prefix: str = "mlp") -> jax.Array:
    act = activation(cfg.act)
    h_in = tapped_linear(x, params["w_in"], f"{prefix}.w_in", tape)
    h_gate = tapped_linear(x, params["w_gate"], f"{prefix}.w_gate", tape)
    h = act(h_gate) * h_in
    return tapped_linear(h, params["w_out"], f"{prefix}.w_out", tape)


# --------------------------------------------------------------- embeddings
def init_embed(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"tokens": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def specs_embed(cfg: ModelConfig) -> Params:
    p = {"tokens": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    return p


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return params["tokens"][tokens]


def unembed(params: Params, h: jax.Array, cfg: ModelConfig,
            tape: Optional[Tape] = None) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, params["tokens"])
        if tape is not None:
            logits = tape.linear("unembed", h, logits)
    else:
        logits = tapped_linear(h, params["unembed"], "unembed", tape)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits

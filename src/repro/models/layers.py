"""Shared neural-net layers: plain-pytree params, explicit init/apply/specs.

Conventions
-----------
* ``init_*(key, cfg, ...) -> params``  nested dicts of jnp arrays.
* ``specs_*(cfg) -> same tree`` of *logical axis* tuples (strings) that
  ``repro.dist.sharding`` maps onto the production mesh.
* Ghost-tape protocol: layers route every shared linear through
  :func:`tapped_linear`.  When a ``Tape`` is threaded, the layer input is
  recorded and a per-call "tap" (a zeros array added to the output) is
  injected so ∂L/∂tap recovers dL/dY for the ghost-norm scorer without
  touching parameter gradients.  With ``tape=None`` this is a plain matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Any


# ---------------------------------------------------------------- ghost tape
@dataclasses.dataclass
class Tape:
    """Mutable trace-time container for ghost scoring (see core/scorer.py)."""
    taps: Optional[dict] = None         # name -> array to ADD at linear output
    records: Optional[dict] = None      # name -> linear INPUT (set if not None)
    tap_shapes: Optional[dict] = None   # name -> ShapeDtypeStruct (collect mode)

    def linear(self, name: str, x: jax.Array, y: jax.Array) -> jax.Array:
        if self.records is not None:
            self.records[name] = x
        if self.tap_shapes is not None:
            self.tap_shapes[name] = jax.ShapeDtypeStruct(y.shape, jnp.float32)
        if self.taps is not None and name in self.taps:
            y = y + self.taps[name].astype(y.dtype)
        return y

    def score_tap(self, name: str, batch: int) -> jax.Array:
        """Register a (B,) float32 SCORE side-channel tap and return it.

        Unlike `linear` taps (zeros added to a layer output, whose
        cotangent is dL/dY), a score tap is an input of a custom-vjp op
        whose backward rule RETURNS a finished per-example score as the
        tap's cotangent (see kernels/ops.make_flash_attention_trainable
        with_scores).  The record entry is a (B, 0) placeholder so the
        scorer's record walk sees the name; it dispatches on the
        ``.qkv_scores`` suffix and uses the tap cotangent directly."""
        if self.records is not None:
            self.records[name] = jnp.zeros((batch, 0), jnp.float32)
        if self.tap_shapes is not None:
            self.tap_shapes[name] = jax.ShapeDtypeStruct((batch,),
                                                         jnp.float32)
        if self.taps is not None and name in self.taps:
            return self.taps[name].astype(jnp.float32)
        return jnp.zeros((batch,), jnp.float32)


def tapped_linear(x: jax.Array, w: jax.Array, name: str,
                  tape: Optional[Tape]) -> jax.Array:
    """y = x @ w with ghost-tape routing. x: (..., din), w: (din, dout)."""
    y = jnp.einsum("...i,io->...o", x, w)
    if tape is not None:
        y = tape.linear(name, x, y)
    return y


# ------------------------------------------------------------------- inits
def _dense_init(key, din, dout, dtype, scale: float | None = None):
    scale = scale if scale is not None else din ** -0.5
    return (jax.random.normal(key, (din, dout), jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def specs_rmsnorm() -> Params:
    return {"scale": ()}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., H, hd) with positions broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # add head axis
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- activation
def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": _dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
        "w_gate": _dense_init(k2, cfg.d_model, cfg.d_ff, dtype),
        "w_out": _dense_init(k3, cfg.d_ff, cfg.d_model, dtype),
    }


def specs_mlp() -> Params:
    return {
        "w_in": ("embed", "ffn"),
        "w_gate": ("embed", "ffn"),
        "w_out": ("ffn", "embed"),
    }


def mlp(params: Params, x: jax.Array, cfg: ModelConfig,
        tape: Optional[Tape] = None, prefix: str = "mlp",
        model_axes: tuple[str, ...] = ()) -> jax.Array:
    """SwiGLU feed-forward.  With ``model_axes`` set (inside shard_map on
    a mesh with a model axis) and the weights arriving as model shards,
    this runs the Megatron column/row pair: `psum_backward` on the
    replicated input, w_in/w_gate on local ffn columns, w_out on the
    matching local ffn rows, and `psum_forward` reduces the partial
    output.  Ghost taps land on the LOCAL slices, so the scorer's
    per-example contributions are model-axis partial sums (see
    core/scorer.py).  Sharded-ness is detected from the shapes so the
    divisibility fallback (replicated weights) keeps the plain path."""
    from repro.core.collectives import psum_backward, psum_forward
    model_axes = tuple(model_axes)
    sharded = bool(model_axes) and params["w_in"].shape[-1] != cfg.d_ff
    act = activation(cfg.act)
    xi = psum_backward(x, model_axes) if sharded else x
    h_in = tapped_linear(xi, params["w_in"], f"{prefix}.w_in", tape)
    h_gate = tapped_linear(xi, params["w_gate"], f"{prefix}.w_gate", tape)
    h = act(h_gate) * h_in
    y = tapped_linear(h, params["w_out"], f"{prefix}.w_out", tape)
    return psum_forward(y, model_axes) if sharded else y


# --------------------------------------------------------------- embeddings
def init_embed(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"tokens": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def specs_embed(cfg: ModelConfig) -> Params:
    p = {"tokens": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    return p


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig,
          model_axes: tuple[str, ...] = ()) -> jax.Array:
    """Token embedding lookup.  When the table arrives vocab-sharded over
    ``model_axes`` (row shard of the (V, D) table), each device looks up
    the ids it owns (clipped + masked to exact zeros elsewhere) and the
    one-owner partials are `psum_forward`-reduced into the replicated
    embedding — the backward hands every device the replicated cotangent,
    which the mask routes onto its own table rows only."""
    table = params["tokens"]
    model_axes = tuple(model_axes)
    if model_axes and table.shape[0] != cfg.vocab_size:
        from repro.core.collectives import axis_info, psum_forward
        dev, _ = axis_info(model_axes)
        v_local = table.shape[0]
        lidx = tokens - dev * v_local
        mine = (lidx >= 0) & (lidx < v_local)
        rows = jnp.take(table, jnp.clip(lidx, 0, v_local - 1), axis=0)
        rows = jnp.where(mine[..., None], rows, jnp.zeros_like(rows))
        return psum_forward(rows, model_axes)
    return table[tokens]


def unembed(params: Params, h: jax.Array, cfg: ModelConfig,
            tape: Optional[Tape] = None,
            model_axes: tuple[str, ...] = ()) -> jax.Array:
    """Project hidden states to vocab logits (tied or untied head).

    With ``model_axes`` and a vocab-sharded table/head, the projection is
    column-parallel: `psum_backward` on the replicated input, a local
    matmul producing this device's vocab slice, and
    `all_gather_replicated` over the vocab dim so the softmax downstream
    sees full logits.  The ghost tap is added to the *gathered* logits
    (full-vocab dY), so its contribution is the full-table term computed
    redundantly on every model device — the scorer counts it once."""
    from repro.core.collectives import all_gather_replicated, psum_backward
    model_axes = tuple(model_axes)
    if cfg.tie_embeddings:
        table = params["tokens"]
        if model_axes and table.shape[0] != cfg.vocab_size:
            hb = psum_backward(h, model_axes)
            logits = jnp.einsum("...d,vd->...v", hb, table)
            logits = all_gather_replicated(logits, model_axes, axis=-1)
        else:
            logits = jnp.einsum("...d,vd->...v", h, table)
        if tape is not None:
            logits = tape.linear("unembed", h, logits)
    else:
        w = params["unembed"]
        if model_axes and w.shape[-1] != cfg.vocab_size:
            hb = psum_backward(h, model_axes)
            logits = jnp.einsum("...i,io->...o", hb, w)
            logits = all_gather_replicated(logits, model_axes, axis=-1)
            if tape is not None:
                logits = tape.linear("unembed", h, logits)
        else:
            logits = tapped_linear(h, w, "unembed", tape)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits

"""Activation-sharding context.

Model code calls :func:`constrain_batch_dim` on the residual stream so the
SPMD partitioner keeps activations batch-sharded over the data axes instead
of replicating them.  Outside a launcher-installed context it is a no-op,
which is what lets the same forward run un-meshed in unit tests.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STACK: list = []


@contextmanager
def activation_sharding(mesh: Mesh, batch_axes):
    """Install (mesh, batch_axes) for constrain_batch_dim inside the body."""
    _STACK.append((mesh, tuple(batch_axes) if batch_axes else ()))
    try:
        yield
    finally:
        _STACK.pop()


def constrain_batch_dim(h: jax.Array) -> jax.Array:
    """Shard the leading (batch) dim of `h` over the active batch axes."""
    if not _STACK:
        return h
    mesh, axes = _STACK[-1]
    if not axes:
        return h
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if h.shape[0] % total != 0:
        return h
    spec = P(axes if len(axes) > 1 else axes[0], *([None] * (h.ndim - 1)))
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))

"""Distribution layer: sharding rules, activation-sharding context, and a
version-robust `shard_map` entry point shared by every SPMD module."""
from repro.dist.compat import shard_map
from repro.dist.sharding import (data_axes, logical_to_pspec, model_axes,
                                 param_pspecs, rules_for)

__all__ = ["shard_map", "data_axes", "logical_to_pspec", "model_axes",
           "param_pspecs", "rules_for"]

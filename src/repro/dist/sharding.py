"""Logical-axis → mesh-axis sharding rules.

Layer ``specs_*`` functions annotate every parameter with *logical* axis
names (``("embed", "heads")`` …).  This module maps those names onto the
physical mesh: tensor-parallel axes go to ``model``, everything else is
replicated, and any dimension that does not divide its mesh axis falls back
to replication (uneven vocab, odd head counts in smoke configs).

Stacked layer parameters (the scan-over-periods leading axis) carry one
more array dimension than their logical spec; the extra leading dims are
replicated (``None``), which is what keeps one spec tree valid for both the
per-layer and the period-stacked trees.
"""
from __future__ import annotations

import warnings

import jax
from jax.sharding import Mesh, PartitionSpec as P

# Logical-name → preferred mesh axis.  `None` = always replicate.
_RULES = {
    "embed": None,      # activations/residual dim: replicated (data-parallel)
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "inner": "model",   # mamba expanded inner dim
    "rank": None,       # MLA latent rank: small, replicated
    "expert": None,     # expert axis: replicated (ffn dim inside is sharded)
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of `mesh` (everything but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axes(mesh: Mesh) -> tuple[str, ...]:
    """The tensor-parallel axes of `mesh`: ("model",) when the mesh carries
    a non-trivial model axis, () otherwise (a size-1 model axis is the
    data-only special case — params replicate and every model-axis
    collective degenerates to the identity)."""
    if "model" in mesh.axis_names and mesh.shape["model"] > 1:
        return ("model",)
    return ()


def dim_spec(axes: tuple[str, ...]):
    """The PartitionSpec entry sharding ONE dim over `axes`: the bare axis
    name for a single axis, the tuple for several (P-element convention)."""
    return axes if len(axes) > 1 else axes[0]


def rules_for(mesh: Mesh) -> dict:
    """The logical→mesh rules restricted to axes that exist in `mesh`."""
    names = set(mesh.axis_names)
    return {k: (v if v in names else None) for k, v in _RULES.items()}


# (param name, logical axis, mesh axis, dim) combinations already warned
# about — the divisibility fallback fires once per distinct cause, not per
# trace (jit re-lowers would otherwise repeat it every compile).
_warned_fallbacks: set = set()


def logical_to_pspec(logical: tuple, shape: tuple, mesh: Mesh,
                     name: str = "") -> P:
    """PartitionSpec for one parameter.

    `logical` annotates the TRAILING dims of `shape`; leading unannotated
    dims (the stacked period axis) are replicated.  A mesh axis is used at
    most once per spec and only when it divides the dimension — when it
    does not, the dim falls back to replication with a one-time warning
    (an uneven vocab or odd head count silently replicating would
    otherwise be indistinguishable from a working model-parallel config).
    """
    rules = rules_for(mesh)
    offset = len(shape) - len(logical)
    if offset < 0:
        raise ValueError(f"spec {logical} longer than shape {shape}")
    parts: list = [None] * offset
    used: set = set()
    for lname, dim in zip(logical, shape[offset:]):
        ax = rules.get(lname) if lname is not None else None
        if ax is None or ax in used:
            parts.append(None)
        elif dim % mesh.shape[ax] != 0:
            # key includes the axis SIZE: retrying with a different (still
            # non-dividing) mesh must warn again, not stay deduped
            key = (name, lname, ax, mesh.shape[ax], dim)
            if key not in _warned_fallbacks:
                _warned_fallbacks.add(key)
                warnings.warn(
                    f"parameter {name or '<unnamed>'}: logical axis "
                    f"{lname!r} (dim {dim}) is not divisible by mesh axis "
                    f"{ax!r} (size {mesh.shape[ax]}); replicating this "
                    f"dim instead of sharding it", stacklevel=2)
            parts.append(None)
        else:
            parts.append(ax)
            used.add(ax)
    return P(*parts)


def param_pspecs(specs, params, mesh: Mesh):
    """Map a logical-spec tree (tuple leaves) + matching param tree (array
    or ShapeDtypeStruct leaves) to a tree of PartitionSpecs.  Leaves are
    visited with their tree path so the divisibility-fallback warning can
    name the parameter."""
    import jax.tree_util as jtu
    return jtu.tree_map_with_path(
        lambda path, lg, p: logical_to_pspec(lg, p.shape, mesh,
                                             name=jtu.keystr(path)),
        specs, params,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, P))

"""Logical-axis → mesh-axis sharding rules.

Layer ``specs_*`` functions annotate every parameter with *logical* axis
names (``("embed", "heads")`` …).  This module maps those names onto the
physical mesh: tensor-parallel axes go to ``model``, everything else is
replicated, and any dimension that does not divide its mesh axis falls back
to replication (uneven vocab, odd head counts in smoke configs).

Stacked layer parameters (the scan-over-periods leading axis) carry one
more array dimension than their logical spec; the extra leading dims are
replicated (``None``), which is what keeps one spec tree valid for both the
per-layer and the period-stacked trees.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

# Logical-name → preferred mesh axis.  `None` = always replicate.
_RULES = {
    "embed": None,      # activations/residual dim: replicated (data-parallel)
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "inner": "model",   # mamba expanded inner dim
    "rank": None,       # MLA latent rank: small, replicated
    "expert": None,     # expert axis: replicated (ffn dim inside is sharded)
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of `mesh` (everything but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dim_spec(axes: tuple[str, ...]):
    """The PartitionSpec entry sharding ONE dim over `axes`: the bare axis
    name for a single axis, the tuple for several (P-element convention)."""
    return axes if len(axes) > 1 else axes[0]


def rules_for(mesh: Mesh) -> dict:
    """The logical→mesh rules restricted to axes that exist in `mesh`."""
    names = set(mesh.axis_names)
    return {k: (v if v in names else None) for k, v in _RULES.items()}


def logical_to_pspec(logical: tuple, shape: tuple, mesh: Mesh) -> P:
    """PartitionSpec for one parameter.

    `logical` annotates the TRAILING dims of `shape`; leading unannotated
    dims (the stacked period axis) are replicated.  A mesh axis is used at
    most once per spec and only when it divides the dimension.
    """
    rules = rules_for(mesh)
    offset = len(shape) - len(logical)
    if offset < 0:
        raise ValueError(f"spec {logical} longer than shape {shape}")
    parts: list = [None] * offset
    used: set = set()
    for name, dim in zip(logical, shape[offset:]):
        ax = rules.get(name) if name is not None else None
        if (ax is None or ax in used or dim % mesh.shape[ax] != 0):
            parts.append(None)
        else:
            parts.append(ax)
            used.add(ax)
    return P(*parts)


def param_pspecs(specs, params, mesh: Mesh):
    """Map a logical-spec tree (tuple leaves) + matching param tree (array
    or ShapeDtypeStruct leaves) to a tree of PartitionSpecs."""
    return jax.tree.map(
        lambda lg, p: logical_to_pspec(lg, p.shape, mesh),
        specs, params,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, P))

"""shard_map across jax versions.

Newer jax exposes `jax.shard_map` (with `check_vma`); the 0.4.x line only
has `jax.experimental.shard_map.shard_map` (with `check_rep`).  Every SPMD
module goes through this wrapper so the call sites stay uniform.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """Version-portable shard_map (maps check_rep onto check_vma)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_rep)
        except TypeError:
            pass  # older signature without check_vma
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep)

"""Minimal optimizer library (no optax in this environment).

Optimizers are (init, update) pairs over plain pytrees.  State trees mirror
the param tree, so the ZeRO sharding rules of repro.dist apply to them
unchanged (the roofline perf pass relies on this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float, norm=None):
    """Clip `tree` to `max_norm`.  Pass `norm` when the caller already has
    the global norm (e.g. the model-axis-aware psum'd norm of a sharded
    grad tree) so the clipping semantics live in exactly one place."""
    n = global_norm(tree) if norm is None else norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def sgd(lr: float | Callable, momentum: float = 0.0) -> Optimizer:
    """Plain SGD (the paper's optimizer), optional heavy-ball momentum."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return _tree_zeros_like(params, jnp.float32)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, new_m)
        return new_params, new_m

    return Optimizer(init, update)


def adam(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """AdamW with f32 moments (ZeRO-shardable alongside the params)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": _tree_zeros_like(params, jnp.float32),
                "v": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, total_steps - warmup, final_frac)
    def fn(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return fn

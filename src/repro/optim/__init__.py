from repro.optim.optimizers import (Optimizer, adam, apply_updates, sgd,
                                    global_norm, clip_by_global_norm,
                                    cosine_schedule, warmup_cosine)

__all__ = ["Optimizer", "adam", "sgd", "apply_updates", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "warmup_cosine"]

"""Pallas TPU flash-decode kernel: one query token vs. a long KV cache.

Serving hot spot for the decode_32k / long_500k shapes.  GQA: the rep =
H/Hkv query heads sharing a KV head are processed together as the matmul
M-dim.  Online-softmax over KV blocks (sequence innermost grid dim) keeps
the running (m, l, o) statistics in VMEM scratch; only the final
normalized output ever hits HBM.

Grid: (B, Hkv, S_blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_acc, l_acc, o_acc, *,
            block_s: int, scale: float):
    sc = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(sc == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, _NEG)
        l_acc[...] = jnp.zeros_like(l_acc)
        o_acc[...] = jnp.zeros_like(o_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (rep, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (bs, hd)

    s_ij = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (rep, bs)
    pos = sc * block_s + jax.lax.broadcasted_iota(jnp.int32, s_ij.shape, 1)
    mask = pos < len_ref[0]
    s_ij = jnp.where(mask, s_ij, _NEG)

    m_prev = m_acc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s_ij - m_new[:, None]) * mask.astype(jnp.float32)
    l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=-1)
    o_acc[...] = o_acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_acc[...] = m_new

    @pl.when(sc == ns - 1)
    def _emit():
        denom = jnp.maximum(l_acc[...], 1e-20)
        o_ref[0, 0] = (o_acc[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, S, Hkv, hd)
    v: jax.Array,        # (B, S, Hkv, hd)
    lengths: jax.Array,  # (B,) int32 valid prefix per sequence
    *,
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode GQA attention. Returns (B, H, hd) in q.dtype."""
    bsz, h, hd = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    block_s = min(block_s, s)
    pad_s = (-s) % block_s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    qg = q.reshape(bsz, hkv, rep, hd)

    grid = (bsz, hkv, (s + pad_s) // block_s)
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, sc: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, sc: (b, g, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, g, sc: (b, sc, g, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, g, sc: (b, sc, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, sc: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(bsz, h, hd)

"""Pallas TPU causal flash-attention (forward) for GQA prefill.

The §Roofline table shows every dense prefill/train pair is memory-bound
through the jnp attention lowering (S×S logits at fusion boundaries).
This kernel keeps the logits tile in VMEM: online softmax over KV blocks,
one (block_q × hd) output write per query tile.

Grid: (B, H, num_q_blocks, num_k_blocks) — KV innermost so the running
(m, l, o) statistics stay in VMEM scratch.  Causal + optional
sliding-window masking; KV blocks entirely in the future are skipped
(their loads still stream, masking keeps the math exact).

Forward-only: serving prefill is inference, so no backward pass is needed;
training keeps the chunked-jnp path (remat-friendly autodiff).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_acc, l_acc, o_acc, *,
            block_q: int, block_k: int, scale: float, window: int,
            seq_len: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, _NEG)
        l_acc[...] = jnp.zeros_like(l_acc)
        o_acc[...] = jnp.zeros_like(o_acc)

    q_pos = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    mask &= k_pos < seq_len  # padded tail

    # skip blocks fully in the future (or beyond the window)
    live = kb * block_k <= qb * block_q + block_q - 1
    if window > 0:
        live &= (kb + 1) * block_k - 1 >= qb * block_q - (window - 1)

    @pl.when(live)
    def _attend():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_acc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=-1)
        o_acc[...] = o_acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_acc[...] = m_new

    @pl.when(kb == nk - 1)
    def _emit():
        denom = jnp.maximum(l_acc[...], 1e-20)
        o_ref[0, :, 0, :] = (o_acc[...] / denom[:, None]).astype(o_ref.dtype)
        # logsumexp residual for the backward pass
        lse_ref[0, 0, :] = m_acc[...] + jnp.log(denom)


def flash_attention(
    q: jax.Array,   # (B, S, H, hd)   RoPE already applied
    k: jax.Array,   # (B, S, Hkv, hd)
    v: jax.Array,   # (B, S, Hkv, hd)
    *,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Causal GQA flash attention. Returns (B, S, H, hd) in q.dtype
    (plus the (B, H, S) f32 logsumexp residual when return_lse)."""
    bsz, s, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad_q = (-s) % block_q
    pad_k = (-s) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    grid = (bsz, h, (s + pad_q) // block_q, (s + pad_k) // block_k)
    out, lse = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, window=window, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, hh, qb, kb: (b, qb, hh, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hh, qb, kb, rep=rep: (b, kb, hh // rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hh, qb, kb, rep=rep: (b, kb, hh // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, hh, qb, kb: (b, qb, hh, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, hh, qb, kb: (b, hh, qb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s + pad_q, h, hd), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, s + pad_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    if return_lse:
        return out[:, :s], lse[:, :, :s]
    return out[:, :s]

"""Flash-attention backward (FlashAttention-2 style) in Pallas.

With the forward's per-row logsumexp L = m + log ℓ saved as the residual,
the backward recomputes P = exp(QKᵀ·scale − L) tile by tile:

    D  = rowsum(dO ∘ O)                    (precomputed, cheap)
    dV = Pᵀ dO
    dS = P ∘ (dO Vᵀ − D)
    dQ = scale · dS K          (kernel B2: grid over q blocks, k inner)
    dK = scale · dSᵀ Q         (kernel B1: grid over k blocks, q inner)

Together with flash_attention (forward) this forms the custom-vjp op in
ops.flash_attention_trainable — attention without S×S HBM traffic in
either direction.  GQA: dK/dV of a KV head sum over its `rep` query heads
(accumulated via the output BlockSpec revisiting the same block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _mask(qb, kb, block_q, block_k, window, seq_len):
    q_pos = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = k_pos <= q_pos
    if window > 0:
        m &= (q_pos - k_pos) < window
    m &= (k_pos < seq_len) & (q_pos < seq_len)
    return m


def _p_tile(q, k, lse, qb, kb, block_q, block_k, scale, window, seq_len):
    s = jax.lax.dot_general(q.astype(jnp.float32) * scale,
                            k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m = _mask(qb, kb, block_q, block_k, window, seq_len)
    s = jnp.where(m, s, _NEG)
    return jnp.exp(s - lse[:, None]) * m.astype(jnp.float32)


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                 *refs, block_q, block_k, scale, window, seq_len, rep,
                 with_scores=False):
    """Grid (B, Hkv, nk, nq·rep): the innermost axis walks (q block,
    group-local head), so the accumulator covers all rep GQA heads of the
    KV head before the (b, kb, g) output block is left.

    With ``with_scores`` an extra (B,) output rides along: at each tile
    emit, the squared Frobenius norms of the finished dK/dV accumulator
    tiles are added into the per-example score block (the fused ghost-score
    epilogue — the tiles are already in VMEM, so the score costs one
    reduction, not an extra HBM sweep)."""
    if with_scores:
        dk_ref, dv_ref, skv_ref, dk_acc, dv_acc = refs
    else:
        dk_ref, dv_ref, dk_acc, dv_acc = refs
    g = pl.program_id(1)
    kb = pl.program_id(2)
    inner = pl.program_id(3)
    n_inner = pl.num_programs(3)
    qb = inner // rep

    if with_scores:
        @pl.when((g == 0) & (kb == 0) & (inner == 0))
        def _init_scores():
            skv_ref[...] = jnp.zeros_like(skv_ref)

    @pl.when(inner == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    live = (qb + 1) * block_q - 1 >= kb * block_k
    if window > 0:
        live &= qb * block_q <= (kb + 1) * block_k - 1 + (window - 1)

    @pl.when(live)
    def _accum():
        q = q_ref[0, :, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        dvec = dvec_ref[0, 0, :]
        p = _p_tile(q, k, lse, qb, kb, block_q, block_k, scale, window,
                    seq_len)                                   # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (bk, hd)
        dp = jax.lax.dot_general(
            do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (bq, bk)
        ds = p * (dp - dvec[:, None])
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (bk, hd)

    @pl.when(inner == n_inner - 1)
    def _emit():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)
        if with_scores:
            skv_ref[...] += (jnp.sum(dk_acc[...] * dk_acc[...])
                             + jnp.sum(dv_acc[...] * dv_acc[...]))


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref, *refs,
               block_q, block_k, scale, window, seq_len, with_scores=False):
    if with_scores:
        dq_ref, sq_ref, dq_acc = refs
    else:
        dq_ref, dq_acc = refs
    hh = pl.program_id(1)
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nk = pl.num_programs(3)

    if with_scores:
        @pl.when((hh == 0) & (qb == 0) & (kb == 0))
        def _init_scores():
            sq_ref[...] = jnp.zeros_like(sq_ref)

    @pl.when(kb == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    live = kb * block_k <= (qb + 1) * block_q - 1
    if window > 0:
        live &= (kb + 1) * block_k - 1 >= qb * block_q - (window - 1)

    @pl.when(live)
    def _accum():
        q = q_ref[0, :, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        dvec = dvec_ref[0, 0, :]
        p = _p_tile(q, k, lse, qb, kb, block_q, block_k, scale, window,
                    seq_len)
        dp = jax.lax.dot_general(
            do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None])
        dq_acc[...] += scale * jax.lax.dot_general(
            ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _emit():
        dq_ref[0, :, 0, :] = dq_acc[...].astype(dq_ref.dtype)
        if with_scores:
            sq_ref[...] += jnp.sum(dq_acc[...] * dq_acc[...])


def flash_attention_bwd(
    q, k, v, o, lse, do, *,
    window: int = 0, scale: float | None = None,
    block_q: int = 256, block_k: int = 256,
    with_scores: bool = False, interpret: bool = False,
):
    """Returns (dq, dk, dv). Shapes as the forward; lse: (B, H, S) f32.

    With ``with_scores=True`` additionally returns a (B,) float32 score
    tap: ``scores[n] = ||dQ_n||² + ||dK_n||² + ||dV_n||²`` accumulated in
    the kernels' epilogues from the f32 VMEM accumulator tiles (before the
    output-dtype cast), so it costs no extra HBM sweep over the gradients.
    Padded rows are masked to exact zeros in the tiles and contribute
    exactly 0.0.  `attn_score_sweep` is the separate-pass twin with
    bitwise-identical accumulation order (for f32 operands)."""
    bsz, s, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad_q = (-s) % block_q
    pad_k = (-s) % block_k

    dvec = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                   axis=-1).transpose(0, 2, 1)                 # (B,H,S)
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)),
                   constant_values=_NEG)
    dvecp = jnp.pad(dvec, ((0, 0), (0, 0), (0, pad_q)))
    nq = (s + pad_q) // block_q
    nk = (s + pad_k) // block_k

    # ---- dK/dV: grid (B, Hkv, kb, nq·rep) — (q block, group head) innermost
    def _qh(b, g, kb, inner):
        return (b, inner // rep, g * rep + inner % rep, 0)

    def _lseh(b, g, kb, inner):
        return (b, g * rep + inner % rep, inner // rep)

    kv_out_specs = [
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, g, kb, inner: (b, kb, g, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, g, kb, inner: (b, kb, g, 0)),
    ]
    kv_out_shape = [
        jax.ShapeDtypeStruct((bsz, s + pad_k, hkv, hd), jnp.float32),
        jax.ShapeDtypeStruct((bsz, s + pad_k, hkv, hd), jnp.float32),
    ]
    if with_scores:
        kv_out_specs.append(
            pl.BlockSpec((1,), lambda b, g, kb, inner: (b,)))
        kv_out_shape.append(jax.ShapeDtypeStruct((bsz,), jnp.float32))
    kv_res = pl.pallas_call(
        functools.partial(_dkdv_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, window=window, seq_len=s, rep=rep,
                          with_scores=with_scores),
        grid=(bsz, hkv, nk, nq * rep),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), _qh),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, g, kb, inner: (b, kb, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, g, kb, inner: (b, kb, g, 0)),
            pl.BlockSpec((1, block_q, 1, hd), _qh),
            pl.BlockSpec((1, 1, block_q), _lseh),
            pl.BlockSpec((1, 1, block_q), _lseh),
        ],
        out_specs=kv_out_specs,
        out_shape=kv_out_shape,
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dvecp)
    dk, dv = kv_res[0], kv_res[1]

    # ---- dQ: grid (B, H, qb, kb) — k innermost
    q_out_specs = pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, hh, qb, kb: (b, qb, hh, 0))
    q_out_shape = jax.ShapeDtypeStruct((bsz, s + pad_q, h, hd), jnp.float32)
    if with_scores:
        q_out_specs = [q_out_specs,
                       pl.BlockSpec((1,), lambda b, hh, qb, kb: (b,))]
        q_out_shape = [q_out_shape,
                       jax.ShapeDtypeStruct((bsz,), jnp.float32)]
    q_res = pl.pallas_call(
        functools.partial(_dq_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, window=window, seq_len=s,
                          with_scores=with_scores),
        grid=(bsz, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, hh, qb, kb: (b, qb, hh, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hh, qb, kb, rep=rep: (b, kb, hh // rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hh, qb, kb, rep=rep: (b, kb, hh // rep, 0)),
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, hh, qb, kb: (b, qb, hh, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, hh, qb, kb: (b, hh, qb)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, hh, qb, kb: (b, hh, qb)),
        ],
        out_specs=q_out_specs,
        out_shape=q_out_shape,
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dvecp)
    dq = q_res[0] if with_scores else q_res

    grads = (dq[:, :s].astype(q.dtype), dk[:, :s].astype(k.dtype),
             dv[:, :s].astype(v.dtype))
    if with_scores:
        return grads + (kv_res[2] + q_res[1],)
    return grads


# ------------------------------------------------- separate-pass score twin
def _sweep_kv_kernel(dk_ref, dv_ref, out_ref):
    g = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when((g == 0) & (kb == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dk_t = dk_ref[0, :, 0, :].astype(jnp.float32)
    dv_t = dv_ref[0, :, 0, :].astype(jnp.float32)
    out_ref[...] += jnp.sum(dk_t * dk_t) + jnp.sum(dv_t * dv_t)


def _sweep_q_kernel(dq_ref, out_ref):
    hh = pl.program_id(1)
    qb = pl.program_id(2)

    @pl.when((hh == 0) & (qb == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dq_t = dq_ref[0, :, 0, :].astype(jnp.float32)
    out_ref[...] += jnp.sum(dq_t * dq_t)


def attn_score_sweep(dq, dk, dv, *, block_q: int = 256, block_k: int = 256,
                     interpret: bool = False):
    """(B,) per-example ||dQ||²+||dK||²+||dV||² from materialized grads.

    The separate-pass twin of ``flash_attention_bwd(with_scores=True)``:
    same tile shapes, same grid iteration order ((kv head, k block) then
    (head, q block)), same per-tile reduction expressions — so for f32
    gradients the result is BITWISE-identical to the fused epilogue (the
    parity contract pinned in tests/test_kernels.py).  The extra cost it
    pays, and the fused path does not, is one full HBM re-read of
    dQ/dK/dV."""
    bsz, s, h, hd = dq.shape
    hkv = dk.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad_q = (-s) % block_q
    pad_k = (-s) % block_k
    dqp = jnp.pad(dq, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    dkp = jnp.pad(dk, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    dvp = jnp.pad(dv, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (s + pad_q) // block_q
    nk = (s + pad_k) // block_k

    skv = pl.pallas_call(
        _sweep_kv_kernel,
        grid=(bsz, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, block_k, 1, hd), lambda b, g, kb: (b, kb, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, g, kb: (b, kb, g, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, g, kb: (b,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=interpret,
    )(dkp, dvp)

    sq = pl.pallas_call(
        _sweep_q_kernel,
        grid=(bsz, h, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, hh, qb: (b, qb, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, hh, qb: (b,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=interpret,
    )(dqp)
    return skv + sq

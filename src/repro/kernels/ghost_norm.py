"""Pallas TPU kernel for the ghost-norm extension (beyond-paper, see DESIGN §4).

For a linear layer shared across S sequence positions, the per-example
gradient is G_n = X_nᵀ D_n with X_n ∈ R^{S×din}, D_n ∈ R^{S×dout}, and

    ||G_n||²_F = <X_n X_nᵀ, D_n D_nᵀ>_F = Σ_{s,t} (x_s·x_t)(d_s·d_t).

The kernel never materializes G_n nor the full S×S Gram matrices in HBM:
it tiles the (s,t) plane into (bs×bs) blocks, accumulates the two block
Grams over feature-block grid steps on the MXU, multiplies them
elementwise, and reduces to one scalar per example.

Grid: (B, S_blocks_i, S_blocks_j, feature_blocks) — feature innermost so
the Gram accumulators stay resident in VMEM scratch.

`symmetric=True` exploits <A,B> symmetry in (i,j): blocks with j<i are
skipped (their MXU work is gated out) and off-diagonal contributions are
counted twice.  This halves the matmul FLOPs; it is the optimized variant
recorded in EXPERIMENTS.md §Perf (baseline = symmetric=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xi_ref, xj_ref, di_ref, dj_ref, out_ref, a_acc, b_acc, *,
            nkx: int, nkd: int, symmetric: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    nk = max(nkx, nkd)

    @pl.when(jnp.logical_and(jnp.logical_and(i == 0, j == 0), k == 0))
    def _zero_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    live = jnp.logical_or(jnp.logical_not(symmetric), j >= i)

    @pl.when(jnp.logical_and(live, k == 0))
    def _init():
        a_acc[...] = jnp.zeros_like(a_acc)
        b_acc[...] = jnp.zeros_like(b_acc)

    @pl.when(jnp.logical_and(live, k < nkx))
    def _accum_a():
        xi = xi_ref[0].astype(jnp.float32)
        xj = xj_ref[0].astype(jnp.float32)
        a_acc[...] += jax.lax.dot_general(
            xi, xj, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(live, k < nkd))
    def _accum_b():
        di = di_ref[0].astype(jnp.float32)
        dj = dj_ref[0].astype(jnp.float32)
        b_acc[...] += jax.lax.dot_general(
            di, dj, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(live, k == nk - 1))
    def _emit():
        contrib = jnp.sum(a_acc[...] * b_acc[...])
        if symmetric:
            contrib = jnp.where(j > i, 2.0 * contrib, contrib)
        out_ref[...] += contrib


def ghost_norm(
    x: jax.Array,
    d: jax.Array,
    *,
    block_s: int = 128,
    block_k: int = 512,
    symmetric: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """||X_nᵀD_n||²_F per example. x:(B,S,din) d:(B,S,dout) → f32[B]."""
    assert x.ndim == 3 and d.ndim == 3
    assert x.shape[:2] == d.shape[:2]
    b, s, din = x.shape
    dout = d.shape[2]

    bs = min(block_s, s)
    pad_s = (-s) % bs
    nkx = pl.cdiv(din, block_k)
    nkd = pl.cdiv(dout, block_k)
    nk = max(nkx, nkd)

    # zero padding is exact: padded rows contribute zero inner products
    xp = jnp.pad(x, ((0, 0), (0, pad_s), (0, (-din) % block_k)))
    dp = jnp.pad(d, ((0, 0), (0, pad_s), (0, (-dout) % block_k)))
    ns = (s + pad_s) // bs

    grid = (b, ns, ns, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nkx=nkx, nkd=nkd, symmetric=symmetric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, block_k),
                         lambda bi, i, j, k: (bi, i, jnp.minimum(k, nkx - 1))),
            pl.BlockSpec((1, bs, block_k),
                         lambda bi, i, j, k: (bi, j, jnp.minimum(k, nkx - 1))),
            pl.BlockSpec((1, bs, block_k),
                         lambda bi, i, j, k: (bi, i, jnp.minimum(k, nkd - 1))),
            pl.BlockSpec((1, bs, block_k),
                         lambda bi, i, j, k: (bi, j, jnp.minimum(k, nkd - 1))),
        ],
        out_specs=pl.BlockSpec((1,), lambda bi, i, j, k: (bi,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bs, bs), jnp.float32),
            pltpu.VMEM((bs, bs), jnp.float32),
        ],
        interpret=interpret,
    )(xp, xp, dp, dp)
    return out

"""Pallas TPU kernel for paper Proposition 1 (rank-1 / fully-connected case).

Computes, per minibatch row n:
    out[n] = ||x[n,:]||² · ||d[n,:]||²  (+ ||d[n,:]||²  for the bias term)
without ever materializing per-example gradients — the paper's recipe for
making importance weights affordable (§3.3).

Tiling: grid (batch_blocks, feature_blocks).  The feature dimension is the
reduction; partial row sums live in VMEM scratch across the feature grid
steps (innermost), the product is emitted on the last feature block.
x and d may have different widths; the wrapper pads both to the common
feature-block grid with zeros (exact for sums of squares).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, d_ref, out_ref, xs_acc, ds_acc, *, nkx: int, nkd: int,
            with_bias: bool):
    k = pl.program_id(1)
    nk = max(nkx, nkd)

    @pl.when(k == 0)
    def _init():
        xs_acc[...] = jnp.zeros_like(xs_acc)
        ds_acc[...] = jnp.zeros_like(ds_acc)

    @pl.when(k < nkx)
    def _accum_x():
        xb = x_ref[...].astype(jnp.float32)
        xs_acc[...] += jnp.sum(xb * xb, axis=-1)

    @pl.when(k < nkd)
    def _accum_d():
        db = d_ref[...].astype(jnp.float32)
        ds_acc[...] += jnp.sum(db * db, axis=-1)

    @pl.when(k == nk - 1)
    def _emit():
        res = xs_acc[...] * ds_acc[...]
        if with_bias:
            res = res + ds_acc[...]
        out_ref[...] = res


def per_example_sqnorm(
    x: jax.Array,
    d: jax.Array,
    *,
    with_bias: bool = True,
    block_b: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """out[n] = ||x[n]||²·||d[n]||² (+||d[n]||²). x:(B,din) d:(B,dout) → f32[B]."""
    assert x.ndim == 2 and d.ndim == 2 and x.shape[0] == d.shape[0]
    b, din = x.shape
    dout = d.shape[1]

    bb = min(block_b, b)
    pad_b = (-b) % bb
    nkx = pl.cdiv(din, block_k)
    nkd = pl.cdiv(dout, block_k)
    nk = max(nkx, nkd)

    xp = jnp.pad(x, ((0, pad_b), (0, (-din) % block_k)))
    dp = jnp.pad(d, ((0, pad_b), (0, (-dout) % block_k)))

    grid = (pl.cdiv(b + pad_b, bb), nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nkx=nkx, nkd=nkd, with_bias=with_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, block_k), lambda i, k: (i, jnp.minimum(k, nkx - 1))),
            pl.BlockSpec((bb, block_k), lambda i, k: (i, jnp.minimum(k, nkd - 1))),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((b + pad_b,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, dp)
    return out[:b]


# ----------------------------------------------------------- fused multi-tap
def _multi_kernel(x_ref, d_ref, out_ref, xs_acc, ds_acc, *, nkx: int,
                  nkd: int, with_bias: bool):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        xs_acc[...] = jnp.zeros_like(xs_acc)
        ds_acc[...] = jnp.zeros_like(ds_acc)

    @pl.when(k < nkx)
    def _accum_x():
        xb = x_ref[0].astype(jnp.float32)
        xs_acc[...] += jnp.sum(xb * xb, axis=-1)

    @pl.when(k < nkd)
    def _accum_d():
        db = d_ref[0].astype(jnp.float32)
        ds_acc[...] += jnp.sum(db * db, axis=-1)

    # per-tap rows are STORED (same expression as the single-tap kernel),
    # not accumulated in-place: an in-kernel `out += xs·ds` lets the
    # compiler form an FMA (one rounding), which would break bitwise
    # parity with "sum of single-tap launches"; the wrapper chains the
    # tap adds outside, where no multiply is available to fuse.
    @pl.when(k == nk - 1)
    def _emit():
        res = xs_acc[...] * ds_acc[...]
        if with_bias:
            res = res + ds_acc[...]
        out_ref[0] = res


def per_example_sqnorm_multi(
    xs: tuple,
    ds: tuple,
    *,
    with_bias: bool = True,
    block_b: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sum of T rank-1 tap contributions in ONE grid sweep.

    ``out[n] = Σ_t ||xs[t][n]||²·||ds[t][n]||² (+||ds[t][n]||²)`` — the
    per-kernel-launch alternative to T separate `per_example_sqnorm` calls
    when the ghost scorer walks many tapped linears.  Taps are zero-padded
    to the widest tap's feature-block grid and stacked on a leading tap
    axis; the grid is (batch_blocks, taps, feature_blocks) — ONE sweep
    over all taps' operands instead of T kernel launches.  Zero padding
    is exact for sums of squares and the per-block reduction expressions
    match the single-tap kernel, so each tap's row of the (T, B) kernel
    output is bitwise-equal to its single-tap launch; the wrapper then
    chains the tap adds in order, making the result BITWISE-identical to
    summing T single-tap launches (same block sizes) in the same order."""
    assert len(xs) == len(ds) and len(xs) >= 1
    b = xs[0].shape[0]
    assert all(x.ndim == 2 and x.shape[0] == b for x in xs)
    assert all(d.ndim == 2 and d.shape[0] == b for d in ds)
    n_taps = len(xs)

    bb = min(block_b, b)
    pad_b = (-b) % bb
    nkx = max(pl.cdiv(x.shape[1], block_k) for x in xs)
    nkd = max(pl.cdiv(d.shape[1], block_k) for d in ds)
    nk = max(nkx, nkd)
    kx, kd = nkx * block_k, nkd * block_k

    # upcast before stacking (exact) so heterogeneous tap dtypes coexist
    xstk = jnp.stack([
        jnp.pad(x.astype(jnp.float32), ((0, pad_b), (0, kx - x.shape[1])))
        for x in xs])
    dstk = jnp.stack([
        jnp.pad(d.astype(jnp.float32), ((0, pad_b), (0, kd - d.shape[1])))
        for d in ds])

    grid = (pl.cdiv(b + pad_b, bb), n_taps, nk)
    out = pl.pallas_call(
        functools.partial(_multi_kernel, nkx=nkx, nkd=nkd,
                          with_bias=with_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, block_k),
                         lambda i, t, k: (t, i, jnp.minimum(k, nkx - 1))),
            pl.BlockSpec((1, bb, block_k),
                         lambda i, t, k: (t, i, jnp.minimum(k, nkd - 1))),
        ],
        out_specs=pl.BlockSpec((1, bb), lambda i, t, k: (t, i)),
        out_shape=jax.ShapeDtypeStruct((n_taps, b + pad_b), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=interpret,
    )(xstk, dstk)
    res = out[0]
    for t in range(1, n_taps):
        res = res + out[t]
    return res[:b]

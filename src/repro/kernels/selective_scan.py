"""Pallas TPU kernel for the Mamba-1 selective scan (chunked recurrence).

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ u_t) ⊗ B_t
    y_t = (h_t · C_t) + D ⊙ u_t

TPU adaptation (DESIGN §2): instead of a monolithic O(S) associative scan
that materializes (B,S,d_inner,d_state) states in HBM, the sequence is cut
into VMEM-sized chunks; the inter-chunk state h (d_block × d_state) is
carried in VMEM scratch across grid steps (sequence innermost), and the
channel dim is blocked so the kernel parallelizes over (batch × channel
blocks) — the natural sharding when d_inner is tensor-parallel over the
`model` mesh axis.

Grid: (B, d_inner_blocks, S_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dl_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_acc, *,
            chunk: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        h_acc[...] = jnp.zeros_like(h_acc)

    a = a_ref[...].astype(jnp.float32)            # (bd, ds)
    dvec = d_ref[...].astype(jnp.float32)         # (bd,)
    u = u_ref[0].astype(jnp.float32)              # (chunk, bd)
    dl = dl_ref[0].astype(jnp.float32)            # (chunk, bd)
    bmat = b_ref[0].astype(jnp.float32)           # (chunk, ds)
    cmat = c_ref[0].astype(jnp.float32)           # (chunk, ds)

    def body(t, carry):
        h = carry                                  # (bd, ds)
        dl_t = jax.lax.dynamic_slice_in_dim(dl, t, 1, 0)[0]   # (bd,)
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)[0]     # (bd,)
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, 0)[0]  # (ds,)
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, 0)[0]  # (ds,)
        da = jnp.exp(dl_t[:, None] * a)                       # (bd, ds)
        h = h * da + (dl_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=-1) + dvec * u_t  # (bd,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_acc[...] = jax.lax.fori_loop(0, chunk, body, h_acc[...])


def selective_scan(
    u: jax.Array,      # (B, S, d_inner)
    delta: jax.Array,  # (B, S, d_inner)
    a: jax.Array,      # (d_inner, d_state)
    b: jax.Array,      # (B, S, d_state)
    c: jax.Array,      # (B, S, d_state)
    d: jax.Array,      # (d_inner,)
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Chunked selective scan. Returns y: (B, S, d_inner) in u.dtype."""
    bsz, s, di = u.shape
    ds = a.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, "wrapper pads seq to a chunk multiple"
    bd = min(block_d, di)
    assert di % bd == 0, "wrapper pads channels to a block multiple"

    grid = (bsz, di // bd, s // chunk)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, dj, sc: (bi, sc, dj)),
            pl.BlockSpec((1, chunk, bd), lambda bi, dj, sc: (bi, sc, dj)),
            pl.BlockSpec((bd, ds), lambda bi, dj, sc: (dj, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, dj, sc: (bi, sc, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, dj, sc: (bi, sc, 0)),
            pl.BlockSpec((bd,), lambda bi, dj, sc: (dj,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda bi, dj, sc: (bi, sc, dj)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), u.dtype),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(u, delta, a, b, c, d)
    return y

"""Jitted public wrappers around the Pallas kernels.

Responsibilities:
  * interpret-mode selection: on CPU backends the kernels run with
    interpret=True (Python emulation, used for validation); on TPU they
    lower to Mosaic.
  * padding to kernel-friendly shapes (done inside the kernel modules).
  * algorithm selection for the ghost norm: the blocked Gram kernel costs
    O(S²(din+dout)) while the direct per-example einsum costs
    O(S·din·dout); we pick per layer shape (mixed ghost-norm strategy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_attention
from repro.kernels.flash_attention_bwd import attn_score_sweep as _attn_score_sweep
from repro.kernels.ghost_norm import ghost_norm as _ghost_norm
from repro.kernels.per_example_sqnorm import per_example_sqnorm as _per_example_sqnorm
from repro.kernels.per_example_sqnorm import per_example_sqnorm_multi as _per_example_sqnorm_multi
from repro.kernels.selective_scan import selective_scan as _selective_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------------ Prop. 1
@functools.partial(jax.jit, static_argnames=("with_bias",))
def per_example_sqnorm(x, d, with_bias: bool = True):
    """Paper Prop. 1: (B,din),(B,dout) → f32[B] squared grad-norm."""
    return _per_example_sqnorm(x, d, with_bias=with_bias, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("with_bias",))
def per_example_sqnorm_multi(xs, ds, with_bias: bool = True):
    """Fused multi-tap Prop. 1: Σ_t ||xs[t]||²·||ds[t]||² in one sweep.

    Bitwise-identical to summing single-tap `per_example_sqnorm` launches
    over the taps in order (same block sizes, zero padding exact for sums
    of squares) — the kernel-launch batching the ghost scorer uses when it
    walks many rank-1 tapped linears."""
    return _per_example_sqnorm_multi(tuple(xs), tuple(ds),
                                     with_bias=with_bias,
                                     interpret=_interpret())


# --------------------------------------------------------------- ghost norm
def ghost_cost(s: int, din: int, dout: int) -> float:
    """FLOPs of the Gram path per example."""
    return float(s) * s * (din + dout)


def direct_cost(s: int, din: int, dout: int) -> float:
    """FLOPs of the materialized per-example gradient path."""
    return float(s) * din * dout


@functools.partial(jax.jit, static_argnames=("symmetric", "force"))
def ghost_norm(x, d, symmetric: bool = True, force: str | None = None):
    """||X_nᵀD_n||²_F per example, x:(B,S,din) d:(B,S,dout) → f32[B].

    Picks the cheaper of the Gram kernel and the direct einsum unless
    `force` in {"gram", "direct"} pins the path.
    """
    _, s, din = x.shape
    dout = d.shape[2]
    # the FLOP model targets TPU; in interpret mode (CPU validation) the
    # Gram kernel is Python-emulated, so auto-select never picks it there
    use_gram = (not _interpret()
                and ghost_cost(s, din, dout) <= direct_cost(s, din, dout))
    if force == "gram":
        use_gram = True
    elif force == "direct":
        use_gram = False
    if use_gram:
        return _ghost_norm(x, d, symmetric=symmetric, interpret=_interpret())
    return ref.ghost_norm_direct_ref(x, d)


# ----------------------------------------------------------- selective scan
@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def selective_scan(u, delta, a, b, c, d, chunk: int = 128, block_d: int = 512):
    """Mamba-1 chunked selective scan, padding seq/channels as needed."""
    bsz, s, di = u.shape
    chunk = min(chunk, s)
    pad_s = (-s) % chunk
    bd = min(block_d, di)
    pad_d = (-di) % bd
    if pad_s or pad_d:
        u_p = jnp.pad(u, ((0, 0), (0, pad_s), (0, pad_d)))
        # pad delta with ones so exp(Δ·A) stays finite; padded channels are
        # discarded below anyway
        dl_p = jnp.pad(delta, ((0, 0), (0, pad_s), (0, pad_d)),
                       constant_values=1.0)
        a_p = jnp.pad(a, ((0, pad_d), (0, 0)))
        b_p = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
        c_p = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
        d_p = jnp.pad(d, ((0, pad_d),))
    else:
        u_p, dl_p, a_p, b_p, c_p, d_p = u, delta, a, b, c, d
    y = _selective_scan(u_p, dl_p, a_p, b_p, c_p, d_p,
                        chunk=chunk, block_d=bd, interpret=_interpret())
    return y[:, :s, :di]


# --------------------------------------------------------- decode attention
@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k, v, lengths, block_s: int = 512):
    """Flash-decode GQA attention over a (possibly partial) KV cache."""
    return _decode_attention(q, k, v, lengths, block_s=block_s,
                             interpret=_interpret())


# ---------------------------------------------------------- flash attention
@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k"))
def flash_attention(q, k, v, window: int = 0, block_q: int = 256,
                    block_k: int = 256):
    """Causal GQA flash attention (forward; the prefill hot path)."""
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, window=window, block_q=block_q, block_k=block_k,
               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attn_grad_sqnorm(dq, dk, dv, block_q: int = 256, block_k: int = 256):
    """(B,) per-example ||dQ||²+||dK||²+||dV||² via the separate-pass
    Pallas sweep (`attn_score_sweep`) — bitwise twin of the fused
    `with_scores` epilogue for f32 gradients."""
    return _attn_score_sweep(dq, dk, dv, block_q=block_q, block_k=block_k,
                             interpret=_interpret())


def make_flash_attention_trainable(window: int = 0, block_q: int = 256,
                                   block_k: int = 256,
                                   with_scores: bool = False):
    """Differentiable flash attention: forward + FlashAttention-2-style
    backward kernels wired through jax.custom_vjp.  Neither direction
    materializes the S×S attention matrix in HBM.

    With ``with_scores=True`` the returned op takes a fourth (B,) float32
    ``score_tap`` argument (ignored by the primal) whose cotangent is the
    fused per-example score ``||dQ_n||²+||dK_n||²+||dV_n||²`` emitted by
    the backward kernels' epilogues — pulling the vjp of a loss w.r.t. the
    tap yields the ghost score of the attention interface at near-zero
    extra cost (see core/scorer.py, strategy 'ghost' with attn_scores)."""
    from repro.kernels.flash_attention import flash_attention as _fa
    from repro.kernels.flash_attention_bwd import flash_attention_bwd as _fb

    if with_scores:
        @jax.custom_vjp
        def fa_s(q, k, v, score_tap):
            return _fa(q, k, v, window=window, block_q=block_q,
                       block_k=block_k, interpret=_interpret())

        def fwd_s(q, k, v, score_tap):
            o, lse = _fa(q, k, v, window=window, block_q=block_q,
                         block_k=block_k, interpret=_interpret(),
                         return_lse=True)
            return o, (q, k, v, o, lse)

        def bwd_s(res, do):
            q, k, v, o, lse = res
            dq, dk, dv, scores = _fb(q, k, v, o, lse, do, window=window,
                                     block_q=block_q, block_k=block_k,
                                     with_scores=True,
                                     interpret=_interpret())
            return dq, dk, dv, scores

        fa_s.defvjp(fwd_s, bwd_s)
        return fa_s

    @jax.custom_vjp
    def fa(q, k, v):
        return _fa(q, k, v, window=window, block_q=block_q, block_k=block_k,
                   interpret=_interpret())

    def fwd(q, k, v):
        o, lse = _fa(q, k, v, window=window, block_q=block_q,
                     block_k=block_k, interpret=_interpret(),
                     return_lse=True)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        return _fb(q, k, v, o, lse, do, window=window, block_q=block_q,
                   block_k=block_k, interpret=_interpret())

    fa.defvjp(fwd, bwd)
    return fa


def make_qkv_score_probe(block_q: int = 256, block_k: int = 256):
    """Identity op (q, k, v, score_tap) -> (q, k, v) whose backward runs
    the separate-pass score sweep on the gradient cotangents and returns
    it as the tap cotangent.  Composed before a plain trainable flash
    attention, this is the SEPARATE-pass twin of ``with_scores=True`` —
    same score, computed by re-reading dQ/dK/dV from HBM.  Exists so the
    fused path has a bitwise reference (and a benchmark baseline)."""

    @jax.custom_vjp
    def probe(q, k, v, score_tap):
        return q, k, v

    def fwd(q, k, v, score_tap):
        return (q, k, v), None

    def bwd(_, cts):
        dq, dk, dv = cts
        scores = _attn_score_sweep(dq, dk, dv, block_q=block_q,
                                   block_k=block_k, interpret=_interpret())
        return dq, dk, dv, scores

    probe.defvjp(fwd, bwd)
    return probe

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU).  They are also used directly on small
problems where kernel launch overhead dominates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------- per-example sq-norms
def per_example_sqnorm_ref(x: jax.Array, d: jax.Array, with_bias: bool = True) -> jax.Array:
    """Paper Proposition 1 (rank-1 / MLP case).

    x: (B, d_in) layer inputs, d: (B, d_out) = dL/dY.
    Returns (B,) squared grad-norm contribution of this layer:
        ||x_n||² ||d_n||²  (+ ||d_n||² for the bias).
    """
    xs = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)
    ds = jnp.sum(jnp.square(d.astype(jnp.float32)), axis=-1)
    out = xs * ds
    if with_bias:
        out = out + ds
    return out


def ghost_norm_ref(x: jax.Array, d: jax.Array) -> jax.Array:
    """Ghost-norm extension for weight sharing over the sequence dim.

    x: (B, S, d_in), d: (B, S, d_out) = dL/dY.
    Per-example grad of the shared W is G_n = x_nᵀ d_n, and
        ||G_n||²_F = <x_n x_nᵀ, d_n d_nᵀ>_F.
    Returns (B,) float32.
    """
    x = x.astype(jnp.float32)
    d = d.astype(jnp.float32)
    gx = jnp.einsum("bsk,btk->bst", x, x)
    gd = jnp.einsum("bsk,btk->bst", d, d)
    return jnp.sum(gx * gd, axis=(1, 2))


def ghost_norm_direct_ref(x: jax.Array, d: jax.Array) -> jax.Array:
    """Same quantity via the materialized per-example gradient (O(S·din·dout)
    compute, O(din·dout) memory per example).  Used as the second oracle and
    as the runtime path when S(d_in+d_out) > d_in·d_out."""
    g = jnp.einsum("bsi,bso->bio", x.astype(jnp.float32), d.astype(jnp.float32))
    return jnp.sum(jnp.square(g), axis=(1, 2))


def per_example_sqnorm_multi_ref(xs, ds, with_bias: bool = True) -> jax.Array:
    """Multi-tap Prop.-1 oracle: Σ_t per_example_sqnorm_ref(xs[t], ds[t])."""
    out = jnp.zeros((xs[0].shape[0],), jnp.float32)
    for x, d in zip(xs, ds):
        out = out + per_example_sqnorm_ref(x, d, with_bias=with_bias)
    return out


def attn_grad_sqnorm_ref(dq, dk, dv) -> jax.Array:
    """Oracle for the fused flash-bwd score tap: per-example
    ||dQ_n||² + ||dK_n||² + ||dV_n||² over the (S, H, hd) axes."""
    def _sq(a):
        return jnp.sum(jnp.square(a.astype(jnp.float32)), axis=(1, 2, 3))
    return _sq(dq) + _sq(dk) + _sq(dv)


# --------------------------------------------------------- selective scan
def selective_scan_ref(
    u: jax.Array,      # (B, S, d_inner)
    delta: jax.Array,  # (B, S, d_inner)  (already softplus'd, > 0)
    a: jax.Array,      # (d_inner, d_state)  (negative; the continuous A)
    b: jax.Array,      # (B, S, d_state)
    c: jax.Array,      # (B, S, d_state)
    d: jax.Array,      # (d_inner,) skip connection
    return_state: bool = False,
    scan_dtype=jnp.float32,
    unroll: int = 1,
):
    """Mamba-1 selective SSM scan (sequential oracle).

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ u_t) ⊗ B_t
    y_t = (h_t · C_t) + D ⊙ u_t
    Returns y: (B, S, d_inner), same dtype as u.

    scan_dtype controls the recurrence-state precision (the perf knob
    measured in EXPERIMENTS.md §Perf; bf16 halves per-step HBM traffic).
    """
    scan_dtype = jnp.dtype(scan_dtype)
    u32, dl32 = u.astype(scan_dtype), delta.astype(scan_dtype)
    b32, c32 = b.astype(scan_dtype), c.astype(scan_dtype)
    a32 = a.astype(scan_dtype)

    def step(h, xs):
        u_t, dl_t, b_t, c_t = xs  # (B,di) (B,di) (B,ds) (B,ds)
        da = jnp.exp(dl_t[..., None] * a32[None])          # (B, di, ds)
        h = h * da + (dl_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1)          # (B, di)
        return h, y

    B_, S, di = u.shape
    ds = a.shape[-1]
    h0 = jnp.zeros((B_, di, ds), scan_dtype)
    xs = (jnp.moveaxis(u32, 1, 0), jnp.moveaxis(dl32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs, unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1) + u32 * d.astype(jnp.float32)[None, None]
    y = y.astype(u.dtype)
    if return_state:
        return y, h_final
    return y


def selective_scan_step_ref(h, u_t, delta_t, a, b_t, c_t, d):
    """Single decode step of the same recurrence. h: (B, di, ds)."""
    dl = delta_t.astype(jnp.float32)
    da = jnp.exp(dl[..., None] * a.astype(jnp.float32)[None])
    h = h * da + (dl * u_t.astype(jnp.float32))[..., None] * b_t.astype(jnp.float32)[:, None, :]
    y = jnp.sum(h * c_t.astype(jnp.float32)[:, None, :], axis=-1)
    y = y + u_t.astype(jnp.float32) * d.astype(jnp.float32)[None]
    return h, y.astype(u_t.dtype)


# --------------------------------------------------------- flash attention
def flash_attention_ref(q, k, v, window: int = 0, scale=None):
    """Causal GQA attention oracle. q:(B,S,H,hd) k,v:(B,S,Hkv,hd)."""
    bsz, s, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(bsz, s, hkv, rep, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(bsz, s, h, hd).astype(q.dtype)


# -------------------------------------------------------- decode attention
def decode_attention_ref(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, S, Hkv, hd)
    v: jax.Array,        # (B, S, Hkv, hd)
    length: jax.Array | None = None,  # (B,) valid prefix lengths
    scale: float | None = None,
) -> jax.Array:
    """One-token GQA attention against a KV cache (flash-decode oracle)."""
    B_, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B_, Hkv, rep, hd)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, kf)
    if length is not None:
        pos = jnp.arange(k.shape[1])[None, None, None, :]
        mask = pos < length[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", p, vf)
    return o.reshape(B_, H, hd).astype(q.dtype)

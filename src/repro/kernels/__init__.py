"""Pallas TPU kernels for the scoring hot-spots (see docs/KERNELS.md).

Four kernel families, each with a pure-jnp twin in :mod:`repro.kernels.ref`
that pins its numerics in tests/test_kernels.py:

* ``per_example_sqnorm`` / ``per_example_sqnorm_multi`` — paper Prop. 1
  rank-1 per-example gradient sq-norms (the multi variant sweeps all
  taps of a ghost walk in one launch).
* ``ghost_norm`` — the sequence-model Gram-matrix generalisation.
* ``flash_attention`` / ``flash_attention_bwd`` — trainable flash
  attention; the backward optionally emits a fused (B,) score tap
  (``with_scores``) alongside dQ/dK/dV, with ``attn_score_sweep`` as its
  bitwise separate-pass twin.

User-facing entry points live in :mod:`repro.kernels.ops` (jit-wrapped,
interpret-mode autodetection for CPU).
"""

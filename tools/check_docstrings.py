#!/usr/bin/env python
"""Doc-coverage gate for the contract-bearing packages (stdlib-only).

Every module under the checked packages must carry a module docstring,
and every PUBLIC top-level function, class, and method must carry its own
docstring — these packages hold the sharding/replication contracts
(docs/ARCHITECTURE.md points into them), so an undocumented public entry
point is a missing contract, and this gate keeps coverage from
regressing.  Private names (leading underscore) and trivial dunders are
exempt; ``interrogate`` would enforce the same rule set, but the repo
avoids adding dependencies the image doesn't bake in.

Usage:  python tools/check_docstrings.py [pkg_dir ...]
        (defaults to src/repro/{core,data,dist,kernels,serving,telemetry})
Exits non-zero listing every undocumented public definition.
"""
from __future__ import annotations

import ast
import os
import sys

DEFAULT_PACKAGES = ("src/repro/core", "src/repro/data", "src/repro/dist",
                    "src/repro/kernels", "src/repro/serving",
                    "src/repro/telemetry")


def _public_defs(tree: ast.Module):
    """Yield (name, node) for public top-level defs/classes and public
    methods of public top-level classes."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield node.name, node
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            yield node.name, node
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_"):
                    yield f"{node.name}.{sub.name}", sub


def check_file(path: str) -> list:
    """Return the undocumented public definitions of one module as
    ``(path, lineno, name)`` tuples; a missing module docstring reports
    as name ``<module>``."""
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append((path, 1, "<module>"))
    for name, node in _public_defs(tree):
        if ast.get_docstring(node) is None:
            missing.append((path, node.lineno, name))
    return missing


def check_packages(packages=DEFAULT_PACKAGES, root: str = ".") -> list:
    """Walk the packages and collect every undocumented public def.

    A package that resolves to zero modules (missing dir, typo, rename)
    raises instead of passing vacuously — a gate that silently checks
    nothing is the regression it exists to prevent."""
    missing = []
    for pkg in packages:
        base = os.path.join(root, pkg)
        n_files = 0
        for dirpath, _, files in os.walk(base):
            for fn in sorted(files):
                if fn.endswith(".py"):
                    n_files += 1
                    missing.extend(check_file(os.path.join(dirpath, fn)))
        if not n_files:
            raise FileNotFoundError(
                f"doc-coverage gate: package {base!r} matched no .py "
                f"files — missing directory or typo?")
    return missing


def main(argv) -> int:
    """CLI entry: print a report and return the exit code."""
    packages = tuple(argv[1:]) or DEFAULT_PACKAGES
    missing = check_packages(packages)
    if missing:
        print(f"doc-coverage gate: {len(missing)} undocumented public "
              f"definition(s):")
        for path, lineno, name in missing:
            print(f"  {path}:{lineno}: {name}")
        return 1
    print(f"doc-coverage gate: OK ({', '.join(packages)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

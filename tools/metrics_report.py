#!/usr/bin/env python
"""Render a run summary from a telemetry JSONL file (stdlib-only).

Input is the schema-versioned event stream `launch/train.py
--metrics-jsonl` writes (repro/telemetry/events.py): one JSON object per
line with an envelope ``{"v": 1, "kind": ..., "t": ..., "step": ...}``.
The report shows

  * the run header (arch / mode / mesh / monitor set),
  * per-name span statistics (count, total/mean/max seconds) — under the
    non-blocking default these are *dispatch* times, so in an async run
    scoring.dispatch + master.dispatch summing to far less than the step
    wall-clock is the overlap working, not a measurement bug,
  * the latest value of every counter,
  * the proposal-health monitor trajectory (ess / staleness / ...),
  * the paper-fig-4 √TrΣ trajectory (ideal / stale / unif) as a table
    plus unicode sparklines — the at-a-glance answer to "is importance
    sampling still paying for itself?".

``--json OUT`` additionally writes the machine-readable summary (the
exact trajectory the table renders; tests/test_telemetry.py checks it
round-trips against the emitted metrics records).

Usage:  python tools/metrics_report.py RUN.jsonl [--json OUT]
"""
from __future__ import annotations

import argparse
import json
import math
import sys

SPARK = "▁▂▃▄▅▆▇█"


def read_events(path: str) -> list[dict]:
    """Parse one event per line, skipping lines that fail to parse (a
    crashed run can truncate its final line mid-record)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and "kind" in rec:
                out.append(rec)
    return out


def sparkline(values: list[float], width: int = 40) -> str:
    """Map a numeric series onto SPARK glyphs (NaNs render as spaces);
    series longer than `width` are stride-subsampled."""
    vals = values
    if len(vals) > width:
        stride = len(vals) / width
        vals = [vals[int(i * stride)] for i in range(width)]
    finite = [v for v in vals if v is not None and not math.isnan(v)]
    if not finite:
        return " " * len(vals)
    lo, hi = min(finite), max(finite)
    span = (hi - lo) or 1.0
    out = []
    for v in vals:
        if v is None or math.isnan(v):
            out.append(" ")
        else:
            out.append(SPARK[min(int((v - lo) / span * (len(SPARK) - 1)),
                                 len(SPARK) - 1)])
    return "".join(out)


def span_stats(events: list[dict]) -> dict[str, dict]:
    """Per-span-name {count, total_s, mean_s, max_s} over span events."""
    stats: dict[str, dict] = {}
    for e in events:
        if e.get("kind") != "span":
            continue
        s = stats.setdefault(e["name"], {"count": 0, "total_s": 0.0,
                                         "max_s": 0.0})
        s["count"] += 1
        s["total_s"] += e["dur_s"]
        s["max_s"] = max(s["max_s"], e["dur_s"])
    for s in stats.values():
        s["mean_s"] = s["total_s"] / s["count"]
        s["total_s"] = round(s["total_s"], 6)
        s["mean_s"] = round(s["mean_s"], 6)
        s["max_s"] = round(s["max_s"], 6)
    return stats


def last_counters(events: list[dict]) -> dict[str, float]:
    """The final sample of every counter name (records are in step order)."""
    out: dict[str, float] = {}
    for e in events:
        if e.get("kind") == "counter":
            out[e["name"]] = e["value"]
    return out


def trajectory(events: list[dict], fields=("trace_ideal", "trace_stale",
                                           "trace_unif", "loss")) -> list[dict]:
    """The per-step metrics series: one {step, *fields} dict per metrics
    record, in emission order."""
    out = []
    for e in events:
        if e.get("kind") != "metrics":
            continue
        row = {"step": e.get("step")}
        for f in fields:
            if f in e:
                row[f] = e[f]
        out.append(row)
    return out


def monitor_trajectory(events: list[dict]) -> dict[str, list]:
    """Per-monitor series over the monitors records, plus the step axis."""
    series: dict[str, list] = {}
    steps = []
    for e in events:
        if e.get("kind") != "monitors":
            continue
        steps.append(e.get("step"))
        for k, v in e.items():
            if k in ("v", "kind", "t", "step"):
                continue
            series.setdefault(k, []).append(v)
    if steps:
        series["step"] = steps
    return series


def build_summary(events: list[dict]) -> dict:
    """The machine-readable report (--json payload)."""
    run = next((e for e in events if e.get("kind") == "run"), {})
    end = next((e for e in events if e.get("kind") == "run_end"), {})
    return {
        "run": {k: v for k, v in run.items()
                if k not in ("v", "kind", "t", "step")},
        "run_end": {k: v for k, v in end.items()
                    if k not in ("v", "kind", "t", "step")},
        "events": len(events),
        "spans": span_stats(events),
        "counters": last_counters(events),
        "monitors": monitor_trajectory(events),
        "trajectory": trajectory(events),
    }


def render(summary: dict, out=sys.stdout) -> None:
    """Pretty-print the summary (the human half of the report)."""
    w = lambda s="": print(s, file=out)
    run = summary["run"]
    if run:
        w("run: " + ", ".join(f"{k}={v}" for k, v in sorted(run.items())))
    w(f"events: {summary['events']}")
    if summary["spans"]:
        w()
        w("spans (non-blocking = dispatch time; overlap makes these sum to "
          "LESS than wall-clock):")
        for name, s in sorted(summary["spans"].items()):
            w(f"  {name:18s} n={s['count']:<5d} total {s['total_s']:.4f}s  "
              f"mean {s['mean_s'] * 1e3:8.3f}ms  max {s['max_s'] * 1e3:8.3f}ms")
    if summary["counters"]:
        w()
        w("counters (latest):")
        for name, v in sorted(summary["counters"].items()):
            w(f"  {name:24s} {v}")
    mons = {k: v for k, v in summary["monitors"].items() if k != "step"}
    if mons:
        w()
        w("proposal-health monitors:")
        for name, series in sorted(mons.items()):
            last = series[-1]
            shown = (f"{last:.4f}" if isinstance(last, float) else f"{last}")
            w(f"  {name:16s} last {shown:>10s}  "
              f"{sparkline([float(v) for v in series])}")
    traj = summary["trajectory"]
    if traj:
        w()
        w("√TrΣ trajectory (paper fig. 4 — stale between ideal and unif "
          "means IS is paying):")
        w(f"  {'step':>6s} {'ideal':>10s} {'stale':>10s} {'unif':>10s} "
          f"{'loss':>10s}")
        for row in traj:
            cells = [f"{row['step']:6d}"]
            for f in ("trace_ideal", "trace_stale", "trace_unif", "loss"):
                v = row.get(f)
                cells.append(f"{v:10.4f}" if isinstance(v, (int, float))
                             and not (isinstance(v, float) and math.isnan(v))
                             else f"{'—':>10s}")
            w("  " + " ".join(cells))
        for f in ("trace_ideal", "trace_stale", "trace_unif"):
            series = [float(r[f]) for r in traj if f in r]
            if series:
                w(f"  {f:12s} {sparkline(series)}")


def main(argv=None) -> int:
    """CLI entry: parse, summarize, render, optionally dump --json."""
    ap = argparse.ArgumentParser(
        description="Render a run summary from telemetry JSONL")
    ap.add_argument("jsonl", help="events file from --metrics-jsonl")
    ap.add_argument("--json", default="",
                    help="also write the machine-readable summary here")
    args = ap.parse_args(argv)
    events = read_events(args.jsonl)
    if not events:
        print(f"no events in {args.jsonl}", file=sys.stderr)
        return 1
    summary = build_summary(events)
    render(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())

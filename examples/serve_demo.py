"""Serving demo: batched prefill + token-by-token decode with layer caches
(GQA ring buffers / MLA compressed latents / Mamba states), on a reduced
jamba-style hybrid — the most cache-heterogeneous assigned architecture.

  PYTHONPATH=src python examples/serve_demo.py [--arch glm4-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.transformer import init_transformer
from repro.serving.engine import decode_step, prefill

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="jamba-v0.1-52b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--steps", type=int, default=24)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
print(f"arch={cfg.name}  layers={cfg.num_layers}  period={cfg.period_len()}")
params = init_transformer(jax.random.key(0), cfg)

prompt = jax.random.randint(jax.random.key(1), (args.batch, 12), 0,
                            cfg.vocab_size)
t0 = time.time()
logits, st = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=64))(
    params, prompt)
print(f"prefill {args.batch}×12 tokens: {time.time() - t0:.2f}s")
print("cache buffers:", {k: tuple(v.shape) for k, v in
                         list(st.caches.items())[:4]})

step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [tok]
t0 = time.time()
for _ in range(args.steps):
    logits, st = step(params, tok, st)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(tok)
dt = time.time() - t0
print(f"decoded {args.steps} steps × {args.batch} seqs "
      f"({args.steps * args.batch / dt:.1f} tok/s on CPU)")
print("generated (seq 0):", jnp.stack(out, 1)[0].tolist())

"""Quickstart: ISSGD in ~40 lines.

Trains the paper's MLP classifier (reduced) on a synthetic
permutation-invariant SVHN clone with distributed-importance-sampling SGD,
and prints the paper's variance monitors as it goes.

  PYTHONPATH=src python examples/quickstart.py

With ``--stream`` the dataset lives in host memory as chunks and the
devices see only a proposal-aware hot window plus the sampled minibatch
(data/streaming.py) — the loss trajectory is bitwise the same.

  PYTHONPATH=src python examples/quickstart.py --stream
"""
import sys

import jax

from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_mlp_scorer
from repro.data import make_svhn_like
from repro.models.mlp import MLPConfig, accuracy, init_mlp_classifier
from repro.models.mlp import per_example_loss
from repro.optim import sgd

# 1. model + data -----------------------------------------------------------
cfg = MLPConfig(input_dim=96, hidden=(256, 256), num_classes=10)
train, test = make_svhn_like(jax.random.key(0), n=8192, dim=cfg.input_dim)
params = init_mlp_classifier(jax.random.key(1), cfg)

# 2. the paper's system: scorer (workers) + IS train step (master) ----------
issgd_cfg = ISSGDConfig(
    batch_size=64,            # master minibatch M
    score_batch_size=512,     # how much the "workers" rescore per step
    refresh_every=8,          # parameter-push period (staleness Δt)
    mode="relaxed",           # the paper's practical algorithm
    is_cfg=ISConfig(smoothing=1.0),   # B.3 additive smoothing
)
opt = sgd(0.02)
pel = lambda p, b: per_example_loss(p, b, cfg)
scorer = make_mlp_scorer(cfg, "ghost")      # exact Prop.-1 grad norms

stream = "--stream" in sys.argv
if stream:
    # host-resident chunked dataset + proposal-aware device window; the
    # driver owns the data, so step() takes no dataset argument
    from repro.data.streaming import make_streamed_issgd
    driver = make_streamed_issgd(pel, scorer, opt, issgd_cfg, train.arrays,
                                 chunk_size=512, window_chunks=4)
    step = driver.step
else:
    step = jax.jit(make_train_step(
        per_example_loss=pel, scorer=scorer,
        optimizer=opt, cfg=issgd_cfg, num_examples=train.size))

# 3. train -------------------------------------------------------------------
# (streamed: the driver owns the examples — no dataset argument, nothing
# example-count-sized on device beyond the window)
state = init_train_state(params, opt, train.size)
for i in range(401):
    state, m = step(state) if stream else step(state, train.arrays)
    if i % 50 == 0:
        print(f"step {i:4d}  loss {float(m.loss):.4f}  "
              f"√TrΣ ideal/stale/unif = {float(m.trace_ideal):.2f}/"
              f"{float(m.trace_stale):.2f}/{float(m.trace_unif):.2f}")

print("test accuracy:", float(accuracy(state.params, test.arrays, cfg)))
if stream:
    s = driver.plane.stats
    print(f"streaming: window hit rate {s.hit_rate:.3f}, "
          f"{s.streamed_rows} scoring rows streamed, {s.swaps} swaps")

"""End-to-end driver (paper §5): ISSGD vs regular SGD on the synthetic
permutation-invariant SVHN task — the paper's figure-2/figure-4 experiment
at CPU scale.  Prints the convergence comparison and the variance-monitor
ordering Tr(Σ(q_IDEAL)) ≤ Tr(Σ(q_STALE)) ≤ Tr(Σ(q_UNIF)).

  PYTHONPATH=src python examples/issgd_vs_sgd.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import run_training, setup
from repro.models.mlp import accuracy

STEPS = 400

print("=== ISSGD (relaxed, ghost scoring) vs regular SGD ===")
results = {}
for mode, label in [("relaxed", "ISSGD"), ("uniform", "SGD  ")]:
    cfg, train, test, params = setup(seed=0)
    st, hist, dt = run_training(params, train, mode=mode, steps=STEPS,
                                lr=0.02, smoothing=1.0, seed=0)
    acc = float(accuracy(st.params, test.arrays, cfg))
    results[mode] = hist
    print(f"{label}: final loss {hist[-1]['loss']:.4f}  "
          f"test acc {acc:.3f}  ({dt:.0f}s)")

print("\nloss trajectory (step: ISSGD vs SGD):")
for a, b in zip(results["relaxed"][::8], results["uniform"][::8]):
    print(f"  {a['step']:4d}: {a['loss']:.4f} vs {b['loss']:.4f}")

tail = results["relaxed"][len(results["relaxed"]) // 2:]
ideal = np.mean([r["trace_ideal"] for r in tail])
stale = np.mean([r["trace_stale"] for r in tail])
unif = np.mean([r["trace_unif"] for r in tail])
print(f"\n√Tr(Σ) ideal ≤ stale ≤ unif:  {ideal:.3f} ≤ {stale:.3f} ≤ {unif:.3f}")
print(f"variance reduction vs uniform: {unif / stale:.2f}×")

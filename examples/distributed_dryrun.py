"""Multi-pod dry-run example: lower + compile the ISSGD train step for one
assigned architecture on the production meshes (16×16 and 2×16×16) using
512 placeholder host devices, and print the roofline terms.

  python examples/distributed_dryrun.py --arch deepseek-7b --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import run_one
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="deepseek-7b")
ap.add_argument("--shape", default="train_4k")
args = ap.parse_args()

for mp in (False, True):
    r = run_one(args.arch, args.shape, mp, Path("/tmp/dryrun_example"))
    comp = r["flops_per_device"] / PEAK_FLOPS_BF16
    mem = 2 * r["io_bytes_per_device"] / HBM_BW
    coll = r["collective_bytes_per_device"] / ICI_BW
    dom = max([("compute", comp), ("memory", mem), ("collective", coll)],
              key=lambda t: t[1])
    print(f"mesh={r['mesh']}: compute={comp:.3e}s memory={mem:.3e}s "
          f"collective={coll:.3e}s → dominant: {dom[0]}")

"""Detailed transformer-substrate tests: chunked LM head, attention
chunking, frontend embeds, remat equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import (forward, init_transformer,
                                      per_example_loss)


def _setup(name="deepseek-7b", b=3, s=33):
    cfg = get_smoke_config(name)
    params = init_transformer(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    return cfg, params, toks


def test_chunked_lm_head_matches_full():
    """loss_chunk > 0 (never materializing (B,S,V)) == the full-logits CE."""
    cfg, params, toks = _setup()
    full, _ = per_example_loss(params, cfg, {"tokens": toks})
    for chunk in (4, 8, 32):
        ccfg = dataclasses.replace(cfg, loss_chunk=chunk)
        got, _ = per_example_loss(params, ccfg, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-5, atol=1e-6)


def test_chunked_lm_head_gradients_match():
    cfg, params, toks = _setup(s=17)
    ccfg = dataclasses.replace(cfg, loss_chunk=4)

    def loss(c):
        return lambda p: jnp.sum(per_example_loss(p, c, {"tokens": toks})[0])

    g_full = jax.grad(loss(cfg))(params)
    g_chunk = jax.grad(loss(ccfg))(params)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                   np.asarray(b, jnp.float32),
                                   rtol=5e-4, atol=1e-5)


def test_attention_q_chunk_invariance():
    """Different attention query-chunk sizes give identical logits."""
    cfg, params, toks = _setup("glm4-9b", s=40)
    outs = []
    for qc in (8, 16, 512):
        ccfg = dataclasses.replace(cfg, attn_chunk=qc)
        l, _ = forward(params, ccfg, toks)
        outs.append(np.asarray(l))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


def test_remat_equivalence():
    """remat=True/False produce identical losses and gradients."""
    cfg, params, toks = _setup("jamba-v0.1-52b", s=16)
    cfg_nr = dataclasses.replace(cfg, remat=False)

    def loss(c):
        return lambda p: jnp.sum(per_example_loss(p, c, {"tokens": toks})[0])

    l1 = loss(cfg)(params)
    l2 = loss(cfg_nr)(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(loss(cfg))(params)
    g2 = jax.grad(loss(cfg_nr))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, jnp.float32),
                                   np.asarray(b, jnp.float32),
                                   rtol=2e-4, atol=1e-5)


def test_frontend_embeds_change_logits_and_loss_region():
    """VLM/audio embeds are prepended; loss covers only token positions."""
    cfg, params, _ = _setup("llava-next-34b")
    b, s = 2, 12
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)
    emb1 = jax.random.normal(jax.random.key(3), (b, 8, cfg.d_model)) * 0.02
    emb2 = jax.random.normal(jax.random.key(4), (b, 8, cfg.d_model)) * 0.02
    l1, _ = per_example_loss(params, cfg, {"tokens": toks, "embeds": emb1})
    l2, _ = per_example_loss(params, cfg, {"tokens": toks, "embeds": emb2})
    assert l1.shape == (b,)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_loss_mask_respected():
    cfg, params, toks = _setup(s=12)
    mask = jnp.ones_like(toks).at[:, 6:].set(0)
    l_masked, _ = per_example_loss(params, cfg,
                                   {"tokens": toks, "mask": mask})
    # mask keeps target positions 1..5 == targets of the length-6 prefix
    l_half, _ = per_example_loss(params, cfg, {"tokens": toks[:, :6]})
    np.testing.assert_allclose(np.asarray(l_masked), np.asarray(l_half),
                               rtol=1e-5)

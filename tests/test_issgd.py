"""ISSGD train-step behaviour (paper §4 + §5 claims at test scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_mlp_scorer
from repro.data import make_svhn_like
from repro.models.mlp import MLPConfig, init_mlp_classifier, accuracy
from repro.models.mlp import per_example_loss as mlp_pel
from repro.optim import sgd


@pytest.fixture(scope="module")
def setup():
    cfg = MLPConfig(input_dim=32, hidden=(64, 64), num_classes=10)
    train, test = make_svhn_like(jax.random.key(0), n=2048, dim=32)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    return cfg, train, test, params


def _run(setup, mode, steps=200, smoothing=0.1, strategy="ghost",
         refresh_every=4, staleness_threshold=0):
    cfg, train, test, params = setup
    opt = sgd(0.05)
    tcfg = ISSGDConfig(
        batch_size=64, score_batch_size=256, refresh_every=refresh_every,
        mode=mode,
        is_cfg=ISConfig(smoothing=smoothing,
                        staleness_threshold=staleness_threshold))
    step = jax.jit(make_train_step(
        lambda p, b: mlp_pel(p, b, cfg),
        make_mlp_scorer(cfg, strategy), opt, tcfg, train.size))
    st = init_train_state(params, opt, train.size)
    ms = []
    for _ in range(steps):
        st, m = step(st, train.arrays)
        ms.append(m)
    return st, ms


def test_variance_ordering(setup):
    """Paper §4.2: Tr(Σ(q_IDEAL)) ≤ Tr(Σ(q_STALE)) ≤ Tr(Σ(q_UNIF))."""
    _, ms = _run(setup, "relaxed", steps=120)
    late = ms[40:]
    ideal = np.mean([float(m.trace_ideal) for m in late])
    stale = np.mean([float(m.trace_stale) for m in late])
    unif = np.mean([float(m.trace_unif) for m in late])
    assert ideal <= stale * 1.02
    assert stale <= unif * 1.02
    # and the reduction must be real, not epsilon
    assert stale < 0.9 * unif


def test_issgd_trains(setup):
    cfg, train, test, params = setup
    st, ms = _run(setup, "relaxed", steps=300)
    acc = float(accuracy(st.params, test.arrays, cfg))
    assert acc > 0.75
    assert float(ms[-1].loss) < float(ms[0].loss)


def test_uniform_mode_is_plain_sgd(setup):
    st, ms = _run(setup, "uniform", steps=60)
    # IS scales are exactly 1 → loss path equals plain SGD; just sanity
    assert np.isfinite(float(ms[-1].loss))


def test_exact_mode_matches_oracle_freshness(setup):
    """Exact mode: every weight is re-scored each step → stale == fresh, so
    Tr(Σ(q_STALE)) collapses onto Tr(Σ(q)) with current weights."""
    _, ms = _run(setup, "exact", steps=20, smoothing=0.0)
    m = ms[-1]
    # with fresh raw grad-norm weights, stale proposal == ideal proposal
    np.testing.assert_allclose(float(m.trace_stale), float(m.trace_ideal),
                               rtol=5e-2)


def test_huge_smoothing_recovers_uniform_variance(setup):
    """B.3: c → ∞ ⇒ ISSGD becomes plain SGD (stale trace → unif trace)."""
    _, ms = _run(setup, "relaxed", steps=60, smoothing=1e7)
    m = ms[-1]
    np.testing.assert_allclose(float(m.trace_stale), float(m.trace_unif),
                               rtol=1e-2)


def test_staleness_threshold_masks_old_entries(setup):
    """B.1: tiny staleness window → all but the freshest slices revert to
    the neutral (uniform) weight."""
    from repro.core.importance import ISConfig
    from repro.core.weight_store import read_proposal
    st, ms = _run(setup, "relaxed", steps=30, staleness_threshold=1,
                  smoothing=0.1)
    prop = np.asarray(read_proposal(
        st.store, st.step,
        ISConfig(smoothing=0.1, staleness_threshold=1)))
    neutral = np.isclose(prop, 0.1).mean()
    # scored slices within the window: 2 slices of 256 out of 2048 examples
    assert neutral > 0.7, f"expected most entries neutral, got {neutral}"


def test_unbiasedness_of_is_gradient(setup):
    """The expected ISSGD minibatch gradient equals the full-dataset mean
    gradient (the paper's core guarantee), tested by Monte-Carlo."""
    cfg, train, _, params = setup
    sub = {k: v[:256] for k, v in train.arrays.items()}
    n = 256

    def mean_grad(p):
        return jax.grad(lambda q: jnp.mean(mlp_pel(q, sub, cfg)))(p)

    true_g = mean_grad(params)
    w = np.asarray(make_mlp_scorer(cfg, "ghost")(params, sub)) + 0.1
    wj = jnp.asarray(w)

    from repro.core.sampler import sample_indices
    from repro.core.importance import is_loss_scale
    key = jax.random.key(9)
    m = 8192
    idx = sample_indices(key, wj, m)
    scales = is_loss_scale(wj[idx], jnp.mean(wj))

    def is_loss(p):
        batch = {k: v[idx] for k, v in sub.items()}
        return jnp.mean(mlp_pel(p, batch, cfg) * scales)

    est_g = jax.grad(is_loss)(params)
    t = jnp.concatenate([x.ravel() for x in jax.tree.leaves(true_g)])
    e = jnp.concatenate([x.ravel() for x in jax.tree.leaves(est_g)])
    rel = float(jnp.linalg.norm(e - t) / jnp.linalg.norm(t))
    assert rel < 0.15, f"IS gradient deviates {rel:.3f} from true mean"


@pytest.mark.parametrize("w", [1, 2, 4, 8])
def test_round_robin_coverage(w):
    """After one full cycle of `_score_slice`, every example is scored
    exactly once (no gaps, no double-count within a cycle) for every
    logical shard count W — the property the async pipeline's staleness
    bound rests on."""
    from repro.core.issgd import (ISSGDConfig, TrainState, _resolve_shards,
                                  _score_slice, make_score_step)
    from repro.core.weight_store import init_store

    n, sb = 64, 16
    tcfg = ISSGDConfig(score_batch_size=sb, score_shards=w)
    w_loc, n_w, sb_w = _resolve_shards(tcfg, n, sb, n, 1)
    cycle = n_w // sb_w
    slices = [np.asarray(_score_slice(jnp.asarray(t, jnp.int32),
                                      w_loc, n_w, sb_w))
              for t in range(cycle)]
    for s in slices:
        assert len(np.unique(s)) == len(s)          # no dup within a step
    allidx = np.concatenate(slices)
    assert len(allidx) == n                          # no double-count
    assert np.array_equal(np.sort(allidx), np.arange(n))   # no gaps

    # and end to end through make_score_step: scored_at >= 0 everywhere
    dummy_scorer = lambda p, b: jnp.ones((b["x"].shape[0],), jnp.float32)
    score = jax.jit(make_score_step(dummy_scorer, tcfg, n))
    state = TrainState(params=(), opt_state=(), stale_params=(),
                       store=init_store(n), step=jnp.zeros((), jnp.int32),
                       rng=jax.random.key(0))
    data = {"x": jnp.zeros((n, 3), jnp.float32)}
    for _ in range(cycle):
        state = score(state, data)
        state = state._replace(step=state.step + 1)
    assert int((state.store.scored_at >= 0).sum()) == n

"""Correctness of the §Perf optimization knobs (EXPERIMENTS.md §Perf):
every speedup must keep the math right (or have a bounded, measured error).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                              per_example_loss, per_example_loss_and_score)
from repro.core.scorer import make_mlp_scorer


def _scan_inputs(key, b=2, s=256, di=32, ds=8):
    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (b, s, di))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    a = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, ds))
    c = jax.random.normal(ks[4], (b, s, ds))
    d = jax.random.normal(ks[5], (di,))
    return u, delta, a, bm, c, d


def test_scan_unroll_is_exact():
    """lax.scan unrolling is a pure scheduling change — bitwise-compatible
    math, so outputs must agree to float tolerance."""
    args = _scan_inputs(jax.random.key(0))
    y1 = ref.selective_scan_ref(*args, unroll=1)
    y8 = ref.selective_scan_ref(*args, unroll=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               rtol=1e-6, atol=1e-6)


def test_scan_bf16_error_bounded():
    """bf16 recurrence state: relative error stays small over long
    sequences (the decay keeps error accumulation contractive)."""
    args = _scan_inputs(jax.random.key(1), s=1024)
    y32 = np.asarray(ref.selective_scan_ref(*args, scan_dtype=jnp.float32),
                     np.float32)
    y16 = np.asarray(ref.selective_scan_ref(*args, scan_dtype=jnp.bfloat16),
                     np.float32)
    rel = np.abs(y16 - y32) / (np.abs(y32) + 1e-3)
    assert np.median(rel) < 0.02, np.median(rel)
    assert np.mean(rel) < 0.05, np.mean(rel)


def test_fused_score_matches_logit_grad_scorer():
    """Fused-mode scores == the standalone logit_grad scorer (same math,
    one forward pass saved)."""
    cfg = MLPConfig(input_dim=16, hidden=(24,), num_classes=5)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    batch = {"x": jax.random.normal(jax.random.key(1), (12, 16)),
             "y": jax.random.randint(jax.random.key(2), (12,), 0, 5)}
    losses, scores = per_example_loss_and_score(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(losses), np.asarray(per_example_loss(params, batch, cfg)),
        rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(scores),
        np.asarray(make_mlp_scorer(cfg, "logit_grad")(params, batch)),
        rtol=1e-5)


def test_lm_fused_score_matches_scorer():
    from repro.configs import get_smoke_config
    from repro.core.scorer import make_lm_scorer
    from repro.models.transformer import (init_transformer,
                                          per_example_loss_and_score)
    cfg = get_smoke_config("deepseek-7b")
    params = init_transformer(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (3, 18), 0,
                                          cfg.vocab_size)}
    _, scores = per_example_loss_and_score(params, cfg, batch)
    want = make_lm_scorer(cfg, "logit_grad")(params, batch)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(want),
                               rtol=1e-4)


def test_fused_mode_trains_and_reduces_variance():
    from repro.core.importance import ISConfig
    from repro.core.issgd import (ISSGDConfig, init_train_state,
                                  make_score_step, make_train_step)
    from repro.data import make_svhn_like
    from repro.optim import sgd

    cfg = MLPConfig(input_dim=32, hidden=(64,), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n=1024, dim=32)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=64, score_batch_size=256, mode="fused",
                       is_cfg=ISConfig(smoothing=0.1))
    step = jax.jit(make_train_step(
        lambda p, b: per_example_loss(p, b, cfg),
        make_mlp_scorer(cfg, "logit_grad"), opt, tcfg, train.size,
        fused_score=lambda p, b: per_example_loss_and_score(p, b, cfg)))
    probe = jax.jit(make_score_step(make_mlp_scorer(cfg, "logit_grad"),
                                    tcfg, train.size))
    st = init_train_state(params, opt, train.size)
    first = None
    for i in range(150):
        st, m = step(st, train.arrays)
        if i % 8 == 0:
            st = probe(st, train.arrays)
        if first is None:
            first = float(m.loss)
    assert float(m.loss) < first
    assert float(m.trace_stale) < float(m.trace_unif)

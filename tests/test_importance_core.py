"""Hypothesis-free unit tests for the core importance-sampling math
(split from test_importance.py so they run even where the `hypothesis`
dev dependency is absent — e.g. the runtime-only CI jobs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import importance as imp
from repro.core import variance as var
from repro.core.importance import ISConfig
from repro.core.sampler import sample_indices
from repro.core.weight_store import init_store, read_proposal, write_scores

jax.config.update("jax_enable_x64", False)

def test_is_scale_uniform_weights_is_identity():
    """Paper §4.1 sanity check: equal ω̃ → scale 1/M·mean = plain SGD."""
    w = jnp.full((16,), 3.7)
    scale = imp.is_loss_scale(w[:4], jnp.mean(w))
    np.testing.assert_allclose(np.asarray(scale), np.ones(4), rtol=1e-6)


def test_ideal_achieved_by_grad_norm_weights():
    """Using ω̃_n = g_n exactly attains eq. 7 from eq. 6."""
    g = jnp.asarray([0.5, 1.0, 2.0, 4.0, 0.1])
    np.testing.assert_allclose(
        float(var.trace_sigma(g, g)), float(var.trace_sigma_ideal(g)), rtol=1e-6)


def test_store_roundtrip_and_staleness():
    store = init_store(10)
    cfg = ISConfig(smoothing=1.0, staleness_threshold=5)
    # cold store == uniform proposal
    p0 = np.asarray(read_proposal(store, 0, cfg))
    np.testing.assert_allclose(p0, p0[0])

    store = write_scores(store, jnp.asarray([1, 3]), jnp.asarray([9.0, 4.0]), step=2)
    p = np.asarray(read_proposal(store, step=3, cfg=cfg))
    assert p[1] == pytest.approx(10.0) and p[3] == pytest.approx(5.0)
    assert p[0] == pytest.approx(1.0)

    # after the staleness window, entries revert to neutral (B.1)
    p_old = np.asarray(read_proposal(store, step=20, cfg=cfg))
    np.testing.assert_allclose(p_old, p_old[0])


def test_ess_and_entropy():
    u = jnp.ones((32,))
    assert float(imp.effective_sample_size(u)) == pytest.approx(32.0)
    peaked = jnp.zeros((32,)).at[0].set(1.0) + 1e-9
    assert float(imp.effective_sample_size(peaked)) < 1.5
    assert float(imp.proposal_entropy(u)) == pytest.approx(np.log(32), rel=1e-5)
    assert float(imp.proposal_entropy(peaked)) < 0.01


def test_sampler_distribution_chi2():
    N = 256
    w = np.linspace(1, 4, N).astype(np.float32)
    idx = np.asarray(sample_indices(jax.random.key(7), jnp.asarray(w), 100_000))
    h = np.bincount(idx, minlength=N) / 100_000
    p = w / w.sum()
    tv = 0.5 * np.abs(h - p).sum()
    assert tv < 0.05


def test_sampler_zero_weight_never_sampled():
    w = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    idx = np.asarray(sample_indices(jax.random.key(0), w, 4096))
    assert set(np.unique(idx)) <= {1, 3}

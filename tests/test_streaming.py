"""Streaming data plane (data/store.py, data/streaming.py).

Pins the PR's acceptance criteria: a streamed run is same-seed *bitwise*
identical to the device-resident run (1 device and a 4-device mesh, in
relaxed / fused / async modes), and the HLO gate — no streamed program
takes or builds a dataset-sized array; only the window, the scoring
slice, and the sampled minibatch ever reach a device.  Also: the chunked
host store's layout/fetch semantics, the explicit gather modes of
data/pipeline.py, the hypothesis property that the two-level gather
equals ArrayDataset.batch for arbitrary index sets, proposal-aware
prefetch/eviction, and the checkpointed bitwise resume of an async
streamed run.

Multi-device tests run in subprocesses because the XLA host-device count
is fixed at first jax init (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import REPO, mesh_src, run_py as _run_py

pytestmark = pytest.mark.stream


def _setup(n=512, mode="relaxed"):
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                  per_example_loss,
                                  per_example_loss_and_score)
    from repro.optim import sgd

    cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=16, classes=4)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode=mode,
                       is_cfg=ISConfig(smoothing=0.1), score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    fused = lambda p, b: per_example_loss_and_score(p, b, cfg)
    return pel, scorer, opt, tcfg, params, train, fused


# ---------------------------------------------------------------------------
# host store
# ---------------------------------------------------------------------------

def test_chunked_store_layout_and_fetch():
    from repro.data.store import ChunkedExampleStore

    rng = np.random.default_rng(0)
    arrays = {"x": rng.normal(size=(256, 5)).astype(np.float32),
              "y": rng.integers(0, 9, size=(256,)).astype(np.int32)}
    store = ChunkedExampleStore.from_arrays(arrays, chunk_size=32)
    assert store.num_chunks == 8 and store.num_examples == 256
    assert store.shard_chunks(1, 4) == range(2, 4)
    assert list(store.owner_shard(np.asarray([0, 3, 7]), 4)) == [0, 1, 3]

    # arbitrary-order fetch returns rows in request order, exact bits
    idx = np.asarray([255, 0, 33, 33, 100, 7])
    rows = store.fetch_rows(idx)
    np.testing.assert_array_equal(rows["x"], arrays["x"][idx])
    np.testing.assert_array_equal(rows["y"], arrays["y"][idx])
    # whole-chunk reassembly in arbitrary chunk order
    stacked = store.stack_chunks([3, 0])
    np.testing.assert_array_equal(stacked["x"],
                                  np.concatenate([arrays["x"][96:128],
                                                  arrays["x"][:32]]))
    with pytest.raises(IndexError):
        store.fetch_rows(np.asarray([256]))
    with pytest.raises(ValueError):
        ChunkedExampleStore.from_arrays(arrays, chunk_size=100)  # 256 % 100


def test_index_to_chunk_resolution():
    from repro.core.sampler import index_to_chunk

    idx = np.asarray([0, 31, 32, 255])
    c, o = index_to_chunk(idx, 32)
    np.testing.assert_array_equal(c, [0, 0, 1, 7])
    np.testing.assert_array_equal(o, [0, 31, 0, 31])
    cj, oj = index_to_chunk(jnp.asarray(idx), 32)
    np.testing.assert_array_equal(np.asarray(cj), c)
    np.testing.assert_array_equal(np.asarray(oj), o)
    with pytest.raises(ValueError):
        index_to_chunk(idx, 0)


def test_chunk_proposal_mass_single_device():
    from repro.core.sampler import chunk_proposal_mass

    w = jnp.arange(16, dtype=jnp.float32)
    mass = np.asarray(chunk_proposal_mass(w, 4))
    np.testing.assert_allclose(mass, [6.0, 22.0, 38.0, 54.0])
    # trailing partial chunk is zero-padded, not rejected (PR 10 fix)
    mass = np.asarray(chunk_proposal_mass(w, 5))
    np.testing.assert_allclose(mass, [10.0, 35.0, 60.0, 15.0])
    with pytest.raises(ValueError):
        chunk_proposal_mass(w, 0)


# ---------------------------------------------------------------------------
# explicit gather modes (satellite: no implicit out-of-bounds behavior)
# ---------------------------------------------------------------------------

def test_gather_modes_explicit():
    from repro.data.pipeline import ArrayDataset, gather_batch, take_rows

    a = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    inb = jnp.asarray([4, 0, 5], jnp.int32)
    oob = jnp.asarray([2, 99], jnp.int32)

    # the hot path promises in-bounds and matches plain indexing bitwise
    np.testing.assert_array_equal(np.asarray(take_rows(a, inb)),
                                  np.asarray(a)[np.asarray(inb)])
    # clip clamps (the one-owner collectives mask the clamped rows)
    np.testing.assert_array_equal(np.asarray(take_rows(a, oob, mode="clip")),
                                  np.asarray(a)[[2, 5]])
    # fill poisons — a schedule bug surfaces as NaN, not a repeated row
    filled = np.asarray(take_rows(a, oob, mode="fill"))
    assert np.isnan(filled[1]).all() and not np.isnan(filled[0]).any()
    with pytest.raises(ValueError, match="mode"):
        take_rows(a, inb, mode="wrap")

    ds = ArrayDataset({"x": a})
    np.testing.assert_array_equal(
        np.asarray(ds.batch(inb)["x"]),
        np.asarray(gather_batch({"x": a}, inb)["x"]))
    # the mode is plumbed through the dataset API too
    np.testing.assert_array_equal(
        np.asarray(ds.batch(oob, mode="clip")["x"]), np.asarray(a)[[2, 5]])


def test_property_two_level_gather_equals_dataset_batch():
    """Hypothesis: for arbitrary index sets and arbitrary window states,
    the plane's two-level gather returns exactly ArrayDataset.batch."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.data import ArrayDataset
    from repro.data.store import ChunkedExampleStore
    from repro.data.streaming import StreamingDataPlane

    n, dim, csize = 256, 3, 32
    rng = np.random.default_rng(7)
    arrays = {"x": rng.normal(size=(n, dim)).astype(np.float32),
              "y": rng.integers(0, 5, size=(n,)).astype(np.int32)}
    ds = ArrayDataset({k: jnp.asarray(v) for k, v in arrays.items()})
    plane = StreamingDataPlane(
        ChunkedExampleStore.from_arrays(arrays, csize), window_chunks=3)

    @given(st.lists(st.integers(0, n - 1), min_size=24, max_size=24),
           st.lists(st.floats(0.0, 10.0), min_size=n // csize,
                    max_size=n // csize))
    @settings(max_examples=25, deadline=None)
    def check(idx, mass):
        # random window state: prefetch off an arbitrary mass, then flip
        plane.prefetch(np.asarray(mass))
        plane.swap_window()
        got = plane.gather_global(np.asarray(idx))
        want = ds.batch(jnp.asarray(idx, jnp.int32))
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))

    check()


# ---------------------------------------------------------------------------
# window semantics
# ---------------------------------------------------------------------------

def test_prefetch_follows_proposal_mass_and_evicts():
    from repro.data.store import ChunkedExampleStore
    from repro.data.streaming import StreamingDataPlane

    n, csize = 256, 32                      # 8 chunks
    arrays = {"x": np.arange(n, dtype=np.float32)[:, None]}
    plane = StreamingDataPlane(
        ChunkedExampleStore.from_arrays(arrays, csize), window_chunks=2)
    np.testing.assert_array_equal(plane.window_ids, [[0, 1]])  # cold start

    # all the mass on chunks 5 and 6 → they become the window...
    mass = np.zeros(8); mass[5] = 3.0; mass[6] = 2.0
    assert plane.prefetch(mass)
    # ...but double-buffered: the serving window is unchanged until swap
    np.testing.assert_array_equal(plane.window_ids, [[0, 1]])
    plane.reset_stats()
    plane.gather_global(np.asarray([5 * csize + 1]))
    assert plane.stats.misses == 1 and plane.stats.hits == 0
    assert plane.swap_window()
    np.testing.assert_array_equal(plane.window_ids, [[5, 6]])

    # hot rows now hit on device; evicted chunk 0 misses
    plane.reset_stats()
    out = plane.gather_global(np.asarray([5 * csize + 1, 6 * csize + 2, 3]))
    assert plane.stats.hits == 2 and plane.stats.misses == 1
    np.testing.assert_array_equal(np.asarray(out["x"]).ravel(),
                                  [5 * csize + 1, 6 * csize + 2, 3])

    # identical ranking → nothing staged, swap is a no-op
    assert not plane.prefetch(mass)
    assert not plane.swap_window()
    # ties break toward lower chunk ids, deterministically
    assert plane.prefetch(np.ones(8))
    plane.swap_window()
    np.testing.assert_array_equal(plane.window_ids, [[0, 1]])


def test_streamed_rejects_exact_and_bad_async_modes():
    from repro.data.streaming import make_streamed_steps

    pel, scorer, opt, tcfg, params, train, fused = _setup()
    import dataclasses
    with pytest.raises(ValueError, match="exact"):
        make_streamed_steps(pel, scorer, opt,
                            dataclasses.replace(tcfg, mode="exact"),
                            train.size, 64)
    with pytest.raises(ValueError, match="async"):
        make_streamed_steps(pel, scorer, opt,
                            dataclasses.replace(tcfg, mode="fused"),
                            train.size, 64, fused_score=fused,
                            async_mode=True)
    with pytest.raises(ValueError, match="chunk_size"):
        make_streamed_steps(pel, scorer, opt, tcfg, train.size, 100)


# ---------------------------------------------------------------------------
# the acceptance criterion: streamed ≡ resident, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["relaxed", "fused", "uniform"])
def test_streamed_bitwise_equals_resident_1device(mode):
    from repro.core.issgd import (init_train_state, make_score_step,
                                  make_train_step)
    from repro.data.streaming import make_streamed_issgd

    pel, scorer, opt, tcfg, params, train, fused = _setup(mode=mode)
    fs = fused if mode == "fused" else None
    data, n, T = train.arrays, train.size, 8

    step = jax.jit(make_train_step(pel, scorer, opt, tcfg, n,
                                   fused_score=fs))
    probe = (jax.jit(make_score_step(scorer, tcfg, n))
             if mode == "fused" else None)
    st_r = init_train_state(params, opt, n)

    drv = make_streamed_issgd(pel, scorer, opt, tcfg, data, chunk_size=64,
                              window_chunks=3, fused_score=fs)
    st_s = init_train_state(params, opt, n)

    for t in range(T):
        st_r, mr = step(st_r, data)
        st_s, ms = drv.step(st_s)
        assert np.array_equal(np.asarray(mr.sample_indices),
                              np.asarray(ms.sample_indices)), t
        assert float(mr.loss) == float(ms.loss), t          # bitwise
        assert float(mr.trace_stale) == float(ms.trace_stale), t
        if mode == "fused" and t % 3 == 0:
            st_r = probe(st_r, data)
            st_s = drv.probe(st_s)
    np.testing.assert_array_equal(np.asarray(st_r.store.weights),
                                  np.asarray(st_s.store.weights))
    for a, b in zip(jax.tree.leaves(st_r.params),
                    jax.tree.leaves(st_s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = drv.plane.stats
    assert s.hits > 0 and s.misses > 0    # both gather levels exercised


@pytest.mark.parametrize("swap_every", [1, 3])
def test_streamed_async_bitwise_equals_async_pipeline(swap_every):
    """Async streaming keeps the AsyncPipeline contract exactly: same
    sampled indices, losses, buffers, and swap stamps at every cadence."""
    from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                           make_async_steps)
    from repro.data.streaming import make_streamed_issgd

    pel, scorer, opt, tcfg, params, train, _ = _setup()
    data, n, T = train.arrays, train.size, 8

    pipe = AsyncPipeline(*make_async_steps(pel, scorer, opt, tcfg, n),
                         swap_every=swap_every)
    st_a = init_async_state(params, opt, n)
    drv = make_streamed_issgd(pel, scorer, opt, tcfg, data, chunk_size=64,
                              window_chunks=3, async_mode=True,
                              swap_every=swap_every)
    st_b = init_async_state(params, opt, n)

    for t in range(T):
        st_a, ma = pipe.step(st_a, data)
        st_b, mb = drv.step(st_b)
        assert np.array_equal(np.asarray(ma.sample_indices),
                              np.asarray(mb.sample_indices)), t
        assert float(ma.loss) == float(mb.loss), t
        assert float(ma.trace_stale) == float(mb.trace_stale), t
    np.testing.assert_array_equal(np.asarray(st_a.store.read_buf.weights),
                                  np.asarray(st_b.store.read_buf.weights))
    np.testing.assert_array_equal(np.asarray(st_a.store.write_buf.weights),
                                  np.asarray(st_b.store.write_buf.weights))
    assert int(st_a.store.synced_at) == int(st_b.store.synced_at)
    for a, b in zip(jax.tree.leaves(st_a.params),
                    jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_MESH_SETUP = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
        from repro.core import distributed as D
        from repro.core.async_pipeline import (AsyncPipeline, make_async_steps,
                                               init_async_state)
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like, ChunkedExampleStore
        from repro.data.streaming import StreamingDataPlane, StreamedISSGD
        from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                      per_example_loss,
                                      per_example_loss_and_score)
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
        train, _ = make_svhn_like(jax.random.key(0), n=512, dim=16, classes=4)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        pel = lambda p, b: per_example_loss(p, b, cfg)
        scorer = make_mlp_scorer(cfg, "ghost")
        fused = lambda p, b: per_example_loss_and_score(p, b, cfg)
        data = train.arrays
        n = train.size
        CS = 32                       # 16 chunks, 4 per shard
        """ + mesh_src(4) + """
        data4 = D.shard_dataset(data, mesh)

        def make_streamed(tcfg, async_mode=False, fused_score=None):
            plane = StreamingDataPlane(
                ChunkedExampleStore.from_arrays(data, CS), 2, mesh=mesh)
            s, smp, m, rcfg = D.make_sharded_streamed_steps(
                pel, scorer, opt, tcfg, n, mesh, data, chunk_size=CS,
                fused_score=fused_score, async_mode=async_mode)
            return plane, StreamedISSGD(plane, s, smp, m, rcfg, n,
                                        async_mode=async_mode,
                                        swap_every=2)
"""


def test_streamed_bitwise_equals_resident_mesh4():
    """The acceptance gate on 4 devices: relaxed, fused, and async
    streamed runs match their resident counterparts bitwise."""
    out = _run_py(_MESH_SETUP + """
        for mode in ("relaxed", "fused"):
            tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode=mode,
                               is_cfg=ISConfig(smoothing=0.1), score_shards=4)
            fs = fused if mode == "fused" else None
            step, rcfg = D.make_sharded_train_step(pel, scorer, opt, tcfg, n,
                                                   mesh, data, fused_score=fs)
            step = jax.jit(step)
            probe = (jax.jit(D.make_sharded_score_step(scorer, rcfg, n, mesh,
                                                       data))
                     if mode == "fused" else None)
            st_r = D.shard_train_state(init_train_state(params, opt, n), mesh)
            plane, drv = make_streamed(tcfg, fused_score=fs)
            st_s = D.shard_train_state(init_train_state(params, opt, n), mesh)
            for t in range(8):
                st_r, mr = step(st_r, data4)
                st_s, ms = drv.step(st_s)
                assert np.array_equal(np.asarray(mr.sample_indices),
                                      np.asarray(ms.sample_indices)), (mode, t)
                assert float(mr.loss) == float(ms.loss), (mode, t)
                if mode == "fused" and t % 3 == 0:
                    st_r = probe(st_r, data4)
                    st_s = drv.probe(st_s)
            assert np.array_equal(np.asarray(st_r.store.weights),
                                  np.asarray(st_s.store.weights)), mode
            for a, b in zip(jax.tree.leaves(st_r.params),
                            jax.tree.leaves(st_s.params)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), mode
            assert plane.stats.hits > 0 and plane.stats.misses > 0, mode
            print(mode, 'ok')

        tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        s4, m4, rcfg = D.make_sharded_async_steps(pel, scorer, opt, tcfg, n,
                                                  mesh, data)
        pipe = AsyncPipeline(s4, m4, swap_every=2)
        st_a = D.shard_train_state(init_async_state(params, opt, n), mesh)
        plane, drv = make_streamed(tcfg, async_mode=True)
        st_b = D.shard_train_state(init_async_state(params, opt, n), mesh)
        for t in range(8):
            st_a, ma = pipe.step(st_a, data4)
            st_b, mb = drv.step(st_b)
            assert np.array_equal(np.asarray(ma.sample_indices),
                                  np.asarray(mb.sample_indices)), t
            assert float(ma.loss) == float(mb.loss), t
        assert np.array_equal(np.asarray(st_a.store.read_buf.weights),
                              np.asarray(st_b.store.read_buf.weights))
        assert np.array_equal(np.asarray(st_a.store.write_buf.weights),
                              np.asarray(st_b.store.write_buf.weights))
        print('async ok')
    """)
    assert "relaxed ok" in out and "fused ok" in out and "async ok" in out


def test_streamed_hlo_never_materializes_dataset():
    """Acceptance gate: no streamed device program contains a
    dataset-sized tensor — the examples on device are only the window
    (n_shards·window_chunks·chunk_size rows), the streamed scoring slice,
    and the sampled minibatch.  The weight-table guarantee (no unsharded
    f32[N]) holds alongside, and the sync scoring program stays
    collective-free."""
    out = _run_py(_MESH_SETUP + """
        import re
        tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        plane, drv = make_streamed(tcfg)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh)

        score_rows = plane.fetch_sharded(drv._score_indices(0))
        idx = jnp.zeros((16,), jnp.int32)
        batch = plane.gather_global(np.zeros(16, np.int64))
        fresh = jnp.zeros((64,), jnp.float32)
        stale = jnp.zeros((64,), jnp.float32)

        # dataset-sized tensors: any [n] or [n, ...] shaped operand
        pat = re.compile(rf"[a-z0-9]+\\[{n}[,\\]]")
        programs = {
            'scoring': drv._scoring.lower(
                st.stale_params, st.store, st.step, score_rows),
            'sample': drv._sample.lower(
                st.store, st.step, st.rng),
            'master': drv._master.lower(
                st.params, st.opt_state, st.stale_params, st.store, st.step,
                st.rng, batch, fresh, stale),
            'combine': plane._combine.lower(
                plane._window, jnp.zeros((16,), jnp.int32),
                jnp.zeros((16,), bool), batch),
        }
        for name, lowered in programs.items():
            hlo = lowered.compile().as_text()
            full = pat.findall(hlo)
            assert not full, (name, full[:5])
        # sync streamed scoring compiles to zero collectives
        hlo_s = programs['scoring'].compile().as_text()
        assert 'all-reduce' not in hlo_s, 'collectives in streamed scoring'
        print('hlo gates pass')
    """)
    assert "hlo gates pass" in out


# ---------------------------------------------------------------------------
# checkpointed resume (satellite: cursor + BufferedWeightStore round-trip)
# ---------------------------------------------------------------------------

def test_streamed_async_checkpoint_resume_bitwise(tmp_path):
    """Save an async streamed run mid-flight, restore into a *fresh*
    driver (cold window, new programs), continue — and match the
    uninterrupted run bitwise.  The streaming cursor is pure state
    (round-robin slice and swap cadence are functions of `step`; the
    window never affects values), so step + rng + BufferedWeightStore is
    the whole resume contract."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.async_pipeline import init_async_state
    from repro.data.streaming import make_streamed_issgd

    pel, scorer, opt, tcfg, params, train, _ = _setup()
    data, n, K, T, T0 = train.arrays, train.size, 2, 10, 5

    def fresh_driver():
        return make_streamed_issgd(pel, scorer, opt, tcfg, data,
                                   chunk_size=64, window_chunks=3,
                                   async_mode=True, swap_every=K)

    # uninterrupted reference
    drv = fresh_driver()
    st = init_async_state(params, opt, n)
    mid = None
    for t in range(T):
        if t == T0:
            mid = save_checkpoint(tmp_path / "mid.npz", st, step=t)
        st, _ = drv.step(st)

    # restore into a cold driver and continue
    drv2 = fresh_driver()
    template = init_async_state(params, opt, n)
    st2, step0 = restore_checkpoint(mid, template)
    assert step0 == T0 and int(st2.step) == T0
    for t in range(T0, T):
        st2, _ = drv2.step(st2)

    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(st.store.read_buf.weights),
                                  np.asarray(st2.store.read_buf.weights))
    np.testing.assert_array_equal(np.asarray(st.store.write_buf.weights),
                                  np.asarray(st2.store.write_buf.weights))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(st.rng)),
        np.asarray(jax.random.key_data(st2.rng)))
    assert int(st.store.synced_at) == int(st2.store.synced_at)


@pytest.mark.slow
def test_train_cli_stream_mesh4():
    """End-to-end CLI gate: --stream --mesh 4 (async) runs green."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # train.py must force the devices itself
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--mesh", "4", "--steps", "8", "--examples", "1024",
         "--stream", "--window-chunks", "2", "--chunk-size", "64",
         "--async-scoring", "--swap-every", "2"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "streaming:" in r.stdout and "hit rate" in r.stdout, \
        r.stdout[-1000:]

"""Async scoring pipeline (core/async_pipeline.py).

Pins the PR's key invariant: an async run with swap cadence K is *bitwise*
a relaxed-mode run whose proposal is L(t) = t − K⌊t/K⌋ + 1 steps staler.
The reference run is built from the same scoring/master bodies but with a
single-buffer store and an explicit store *history* (the master reads the
snapshot from K⌊t/K⌋ writes ago), so the double-buffered swap logic is the
only thing that differs.  Also: scored_at lag observability, mesh-4
equivalence, the HLO no-full-table gate for the async master step, and the
zero-collective guarantee for the scoring step.

Multi-device tests run in subprocesses because the XLA host-device count is
fixed at first jax init (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import REPO, mesh_src, run_py as _run_py


def _setup(n=512):
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                  per_example_loss)
    from repro.optim import sgd

    cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=16, classes=4)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.1), score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    return pel, scorer, opt, tcfg, params, train


_SHARDED_SETUP = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig
        from repro.core import distributed as D
        from repro.core.async_pipeline import (AsyncPipeline, make_async_steps,
                                               init_async_state)
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like
        from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                      per_example_loss)
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
        train, _ = make_svhn_like(jax.random.key(0), n=512, dim=16, classes=4)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        pel = lambda p, b: per_example_loss(p, b, cfg)
        scorer = make_mlp_scorer(cfg, "ghost")
        data = train.arrays
        n = train.size
"""


@pytest.mark.parametrize("swap_every", [1, 3])
def test_async_equals_lagged_relaxed_reference(swap_every):
    """The tentpole invariant: async(swap cadence K) is bitwise a relaxed
    run whose proposal lags by L(t) = t − K⌊t/K⌋ + 1 steps."""
    from repro.core.async_pipeline import (AsyncPipeline, make_async_steps,
                                           init_async_state)
    from repro.core.weight_store import init_store

    pel, scorer, opt, tcfg, params, train = _setup()
    data, n, K, T = train.arrays, train.size, swap_every, 8

    s_step, m_step = make_async_steps(pel, scorer, opt, tcfg, n)
    pipe = AsyncPipeline(s_step, m_step, swap_every=K)
    astate = init_async_state(params, opt, n)
    alog = []
    for _ in range(T):
        astate, am = pipe.step(astate, data)
        alog.append((np.asarray(am.sample_indices), float(am.loss)))

    # reference: same bodies, single buffer, explicit history, no donation
    score_j, master_j = jax.jit(s_step), jax.jit(m_step)
    store = init_store(n)
    hist = [store]
    p_r, o_r, sp_r = params, opt.init(params), params
    rng_r = jax.random.key(0)
    for t in range(T):
        ts = jnp.asarray(t, jnp.int32)
        store, _sm = score_j(sp_r, store, ts, data)
        hist.append(store)
        lag_store = hist[(t // K) * K]      # writes through step K⌊t/K⌋ − 1
        p_r, o_r, sp_r, _, rng_r, rm = master_j(p_r, o_r, sp_r, lag_store,
                                                ts, rng_r, data)
        ai, al = alog[t]
        assert np.array_equal(ai, np.asarray(rm.sample_indices)), t
        assert al == float(rm.loss), t      # bitwise

    for a, b in zip(jax.tree.leaves(astate.params), jax.tree.leaves(p_r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(astate.store.write_buf.weights),
                          np.asarray(store.weights))
    assert np.array_equal(np.asarray(astate.store.read_buf.weights),
                          np.asarray(hist[(T // K) * K].weights))


@pytest.mark.parametrize("publish_every", [1, 3])
def test_serve_snapshot_equals_explicit_stale_checkpoint(publish_every):
    """The serving extension of the swap invariant: a serve tick reading
    `PublishedParams` under publish cadence K decodes bitwise against the
    explicit checkpoint params(K⌊t/K⌋).  The snapshot is a real copy —
    it neither drifts with the live training params between publishes nor
    perturbs the training stream it rides on."""
    from repro.configs import get_smoke_config
    from repro.core.async_pipeline import (AsyncPipeline, make_async_steps,
                                           init_async_state)
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_lm_scorer
    from repro.core.weight_store import publish_params
    from repro.data import make_token_dataset
    from repro.models.transformer import init_transformer, per_example_loss
    from repro.optim import sgd
    from repro.serving.engine import generate

    cfg = get_smoke_config("glm4-9b")
    n, K, T = 64, publish_every, 5
    train = make_token_dataset(jax.random.key(0), n=n, seq=17,
                               vocab=cfg.vocab_size)
    params = init_transformer(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=4, score_batch_size=16, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.1))
    pel = lambda p, b: per_example_loss(p, cfg, b)[0]
    scorer = make_lm_scorer(cfg, "loss")
    s_step, m_step = make_async_steps(pel, scorer, opt, tcfg, n)
    data = train.arrays
    prompt = jax.random.randint(jax.random.key(9), (1, 4), 0, cfg.vocab_size)

    hist, served, stamps = [], [], []
    published = [None]

    def serve_tick(state):
        t = len(hist)
        # host-side checkpoint of the live params entering tick t
        hist.append(jax.tree.map(np.asarray, state.params))
        if published[0] is None or t % K == 0:
            published[0] = publish_params(state.params, state.step)
        stamps.append(int(published[0].synced_at))
        served.append(generate(published[0].params, cfg, prompt,
                               steps=3, max_len=8)[0].tolist())

    pipe = AsyncPipeline(s_step, m_step, swap_every=1, serve_tick=serve_tick)
    state = init_async_state(params, opt, n)
    for _ in range(T):
        state, _ = pipe.step(state, data)

    for t in range(T):
        assert stamps[t] == K * (t // K), (t, stamps[t])
        ck = jax.tree.map(jnp.asarray, hist[K * (t // K)])
        want = generate(ck, cfg, prompt, steps=3, max_len=8)[0].tolist()
        assert served[t] == want, t


def test_scored_at_exposes_lag():
    """The lag is observable through read_buf.scored_at (B.1 timestamps):
    after step t the snapshot holds writes through K⌊(t+1)/K⌋ − 1 while
    write_buf holds writes through t."""
    from repro.core.async_pipeline import (AsyncPipeline, make_async_steps,
                                           init_async_state)

    pel, scorer, opt, tcfg, params, train = _setup()
    data, n, K = train.arrays, train.size, 4

    pipe = AsyncPipeline(*make_async_steps(pel, scorer, opt, tcfg, n),
                         swap_every=K)
    state = init_async_state(params, opt, n)
    assert int(state.store.synced_at) == -1
    for t in range(10):
        state, _ = pipe.step(state, data)
        synced = ((t + 1) // K) * K - 1
        assert int(state.store.synced_at) == synced, t
        assert int(state.store.read_buf.scored_at.max()) == synced, t
        assert int(state.store.write_buf.scored_at.max()) == t, t


def test_async_rejects_exact_and_fused():
    import dataclasses
    from repro.core.async_pipeline import make_async_steps

    pel, scorer, opt, tcfg, params, train = _setup()
    for mode in ("exact", "fused"):
        bad = dataclasses.replace(tcfg, mode=mode)
        with pytest.raises(ValueError, match="async"):
            make_async_steps(pel, scorer, opt, bad, train.size)


def test_async_sharded_matches_single_device():
    """Same-seed equivalence of the async pipeline on a 4-device mesh vs
    one device — the one-code-path property carries over to the split
    step."""
    out = _run_py(_SHARDED_SETUP + """
        K = 2
        s1, m1 = make_async_steps(pel, scorer, opt, tcfg, n)
        pipe1 = AsyncPipeline(s1, m1, swap_every=K)
        st1 = init_async_state(params, opt, n)

        """ + mesh_src(4) + """
        s4, m4, _ = D.make_sharded_async_steps(pel, scorer, opt, tcfg, n,
                                               mesh, data)
        pipe4 = AsyncPipeline(s4, m4, swap_every=K)
        st4 = D.shard_train_state(init_async_state(params, opt, n), mesh)
        data4 = D.shard_dataset(data, mesh)

        for t in range(8):
            st1, a = pipe1.step(st1, data)
            st4, b = pipe4.step(st4, data4)
            assert np.array_equal(np.asarray(a.sample_indices),
                                  np.asarray(b.sample_indices)), t
            np.testing.assert_allclose(float(a.loss), float(b.loss),
                                       rtol=1e-5, atol=1e-6, err_msg=str(t))
        np.testing.assert_allclose(np.asarray(st1.store.write_buf.weights),
                                   np.asarray(st4.store.write_buf.weights),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st1.store.read_buf.weights),
                                   np.asarray(st4.store.read_buf.weights),
                                   rtol=1e-4, atol=1e-5)
        print('async sharded equivalent')
    """)
    assert "async sharded equivalent" in out


def test_async_master_step_hlo_gates():
    """The HLO no-full-table gate of tests/test_sharded.py holds for the
    async master step, and the scoring step (monitors off) compiles to
    zero collectives."""
    out = _run_py(_SHARDED_SETUP + """
        import re
        """ + mesh_src(4) + """
        s4, m4, _ = D.make_sharded_async_steps(pel, scorer, opt, tcfg, n,
                                               mesh, data)
        st4 = D.shard_train_state(init_async_state(params, opt, n), mesh)
        data4 = D.shard_dataset(data, mesh)

        hlo = jax.jit(m4).lower(
            st4.params, st4.opt_state, st4.stale_params, st4.store.read_buf,
            st4.step, st4.rng, data4).compile().as_text()
        full = re.findall(rf"[fs]32\\[{n}\\]", hlo)
        assert not full, f"full-table tensors in async master HLO: {full[:5]}"

        s4nc, _, _ = D.make_sharded_async_steps(pel, scorer, opt, tcfg, n,
                                                mesh, data,
                                                monitor_traces=False)
        hlo_s = jax.jit(s4nc).lower(
            st4.stale_params, st4.store.write_buf, st4.step,
            data4).compile().as_text()
        assert "all-reduce" not in hlo_s, "collectives in the scoring step"
        print('async hlo gates pass')
    """)
    assert "async hlo gates pass" in out


@pytest.mark.slow
def test_train_cli_async_mesh4():
    """End-to-end CLI gate: --async-scoring --swap-every 2 --mesh 4."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # train.py must force the devices itself
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--mesh", "4", "--steps", "8", "--examples", "1024",
         "--async-scoring", "--swap-every", "2"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "async" in r.stdout, r.stdout[-1000:]

"""Checkpoint roundtrip including the ISSGD weight store ("database")."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.issgd import init_train_state
from repro.models.mlp import MLPConfig, init_mlp_classifier
from repro.optim import adam


def test_roundtrip_train_state(tmp_path):
    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=3)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    opt = adam(1e-3)
    st = init_train_state(params, opt, num_examples=32, seed=4)
    # mutate the store so the roundtrip is non-trivial
    st = st._replace(store=st.store._replace(
        weights=st.store.weights.at[3].set(7.5),
        scored_at=st.store.scored_at.at[3].set(11)),
        step=jnp.asarray(42, jnp.int32))

    p = save_checkpoint(tmp_path / "ckpt.npz", st, step=42)
    restored, step = restore_checkpoint(p, st)

    assert step == 42
    assert float(restored.store.weights[3]) == 7.5
    assert int(restored.store.scored_at[3]) == 11
    for a, b in zip(jax.tree.leaves(st.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # adam moments roundtrip too
    for a, b in zip(jax.tree.leaves(st.opt_state),
                    jax.tree.leaves(restored.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_prng_key_and_buffered_store(tmp_path):
    """PRNG keys serialize via key_data (the stream continues, not
    restarts) and the async double-buffered store round-trips — the two
    halves of the bitwise-resume contract of tests/test_streaming.py."""
    from repro.core.weight_store import to_buffered

    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=3)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    opt = adam(1e-3)
    st = init_train_state(params, opt, num_examples=32, seed=4)
    rng, _ = jax.random.split(st.rng)      # evolve past the seed value
    st = st._replace(rng=rng, store=to_buffered(st.store._replace(
        weights=st.store.weights.at[5].set(2.5))))

    p = save_checkpoint(tmp_path / "ckpt.npz", st, step=9)
    template = init_train_state(params, opt, num_examples=32, seed=0)
    template = template._replace(store=to_buffered(template.store))
    restored, step = restore_checkpoint(p, template)

    assert step == 9
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(restored.rng)),
        np.asarray(jax.random.key_data(st.rng)))
    # the restored key continues the same stream
    assert float(jax.random.uniform(restored.rng)) == \
        float(jax.random.uniform(st.rng))
    assert float(restored.store.read_buf.weights[5]) == 2.5
    assert float(restored.store.write_buf.weights[5]) == 2.5
    assert int(restored.store.synced_at) == int(st.store.synced_at)


def test_roundtrip_bf16(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5}
    p = save_checkpoint(tmp_path / "c.npz", tree, step=1)
    restored, _ = restore_checkpoint(p, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], jnp.float32),
                                  np.asarray(tree["w"], jnp.float32))

"""Checkpoint roundtrip including the ISSGD weight store ("database")."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.issgd import init_train_state
from repro.models.mlp import MLPConfig, init_mlp_classifier
from repro.optim import adam


def test_roundtrip_train_state(tmp_path):
    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=3)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    opt = adam(1e-3)
    st = init_train_state(params, opt, num_examples=32, seed=4)
    # mutate the store so the roundtrip is non-trivial
    st = st._replace(store=st.store._replace(
        weights=st.store.weights.at[3].set(7.5),
        scored_at=st.store.scored_at.at[3].set(11)),
        step=jnp.asarray(42, jnp.int32))

    p = save_checkpoint(tmp_path / "ckpt.npz", st, step=42)
    restored, step = restore_checkpoint(p, st)

    assert step == 42
    assert float(restored.store.weights[3]) == 7.5
    assert int(restored.store.scored_at[3]) == 11
    for a, b in zip(jax.tree.leaves(st.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # adam moments roundtrip too
    for a, b in zip(jax.tree.leaves(st.opt_state),
                    jax.tree.leaves(restored.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_bf16(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5}
    p = save_checkpoint(tmp_path / "c.npz", tree, step=1)
    restored, _ = restore_checkpoint(p, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], jnp.float32),
                                  np.asarray(tree["w"], jnp.float32))

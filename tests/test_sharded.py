"""Sharded ISSGD (core/distributed.py): equivalence, unbiasedness, and the
no-full-table guarantee.

Multi-device tests run in subprocesses because the XLA host-device count is
fixed at first jax init (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import REPO, mesh_src, run_py as _run_py


_SETUP = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
        from repro.core import distributed as D
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like
        from repro.models.mlp import MLPConfig, init_mlp_classifier, per_example_loss
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=32, hidden=(64, 64), num_classes=10)
        train, _ = make_svhn_like(jax.random.key(0), n=2048, dim=32)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        tcfg = ISSGDConfig(batch_size=64, score_batch_size=256, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        pel = lambda p, b: per_example_loss(p, b, cfg)
        scorer = make_mlp_scorer(cfg, "ghost")
"""


def test_sharded_matches_single_device():
    """Same-seed equivalence on 4 forced host devices: identical sampled
    indices, loss trajectories equal to float noise.  The logical scoring
    decomposition (score_shards=4) — not the mesh — fixes the round-robin
    assignment and the two-stage draw, so the single-device run executes
    the same algorithm."""
    out = _run_py(_SETUP + """
        step1 = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size))
        st1 = init_train_state(params, opt, train.size)

        """ + mesh_src(4) + """
        step4, _ = D.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays)
        step4 = jax.jit(step4)
        st4 = D.shard_train_state(init_train_state(params, opt, train.size),
                                  mesh)
        data4 = D.shard_dataset(train.arrays, mesh)

        for i in range(60):
            st1, m1 = step1(st1, train.arrays)
            st4, m4 = step4(st4, data4)
            assert np.array_equal(np.asarray(m1.sample_indices),
                                  np.asarray(m4.sample_indices)), i
            np.testing.assert_allclose(float(m1.loss), float(m4.loss),
                                       rtol=1e-5, atol=1e-6, err_msg=str(i))
        np.testing.assert_allclose(np.asarray(st1.store.weights),
                                   np.asarray(st4.store.weights),
                                   rtol=1e-4, atol=1e-5)
        for a, b in zip(jax.tree.leaves(st1.params),
                        jax.tree.leaves(st4.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)
        print('equivalent over 60 steps')
    """)
    assert "equivalent over 60 steps" in out


def test_mesh_size_one_is_bitwise_special_case():
    """shard_map over a 1-device mesh == the plain axes=() step, bitwise:
    single-device execution IS the sharded path, not a second code path."""
    out = _run_py(_SETUP + """
        step_plain = jax.jit(make_train_step(pel, scorer, opt, tcfg,
                                             train.size))
        """ + mesh_src(1) + """
        step_m1, _ = D.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays)
        step_m1 = jax.jit(step_m1)
        sa = init_train_state(params, opt, train.size)
        sb = D.shard_train_state(init_train_state(params, opt, train.size),
                                 mesh)
        db = D.shard_dataset(train.arrays, mesh)
        for i in range(10):
            sa, ma = step_plain(sa, train.arrays)
            sb, mb = step_m1(sb, db)
            assert np.array_equal(np.asarray(ma.sample_indices),
                                  np.asarray(mb.sample_indices)), i
        np.testing.assert_allclose(float(ma.loss), float(mb.loss), rtol=1e-6)
        print('mesh1 ok')
    """, devices=1)
    assert "mesh1 ok" in out


def test_store_never_materialized_unsharded():
    """Acceptance gate: the sharded step never builds an unsharded f32[N]
    weights array — checked via output shardings AND by scanning the
    partitioned HLO for full-table-sized tensors."""
    out = _run_py(_SETUP + """
        import re
        from jax.sharding import NamedSharding, PartitionSpec as P
        N = train.size
        """ + mesh_src(4) + """
        step4, _ = D.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays)
        st4 = D.shard_train_state(init_train_state(params, opt, train.size),
                                  mesh)
        data4 = D.shard_dataset(train.arrays, mesh)
        jitted = jax.jit(step4)
        # 1. the store stays sharded over 'data' with N/4 rows per device
        new_state, _ = jitted(st4, data4)
        spec = new_state.store.weights.sharding.spec
        assert spec == P('data'), spec
        shapes = {s.data.shape for s in
                  new_state.store.weights.addressable_shards}
        assert shapes == {(N // 4,)}, shapes
        # 2. no f32[N]/s32[N] tensor anywhere in the partitioned module
        hlo = jitted.lower(st4, data4).compile().as_text()
        full = re.findall(rf"[fs]32\\[{N}\\]", hlo)
        assert not full, f"full-table tensors in HLO: {full[:5]}"
        print('store stays sharded')
    """)
    assert "store stays sharded" in out


def test_two_stage_sampler_unbiased():
    """The hierarchical draw matches the target distribution and yields an
    unbiased IS estimate — single process, logical shards only (the
    mesh-size-1 special case exercises the same arithmetic)."""
    from repro.core.sampler import two_stage_sample

    n, m = 1024, 400_000
    w = (jnp.arange(n, dtype=jnp.float32) % 23) + 0.25
    idx = np.asarray(two_stage_sample(jax.random.key(5), w, m,
                                      shards_per_device=8))
    p = np.asarray(w / w.sum())
    h = np.bincount(idx, minlength=n) / m
    tv = 0.5 * np.abs(h - p).sum()
    assert tv < 0.02, tv
    # unbiasedness of the IS-weighted estimator: E[f/Nq] == mean(f)
    f = np.cos(np.arange(n)) * 7.0 + 3.0
    est = np.mean(f[idx] / (n * p[idx]))
    np.testing.assert_allclose(est, f.mean(), rtol=5e-3)


def test_two_stage_sampler_shard_invariance():
    """Same key ⇒ identical indices for every shards_per_device that keeps
    the same logical decomposition — the property the distributed
    equivalence rests on."""
    from repro.core.sampler import two_stage_sample

    n = 512
    w = jnp.abs(jax.random.normal(jax.random.key(0), (n,))) + 0.1
    ref = np.asarray(two_stage_sample(jax.random.key(1), w, 1000,
                                      shards_per_device=8))
    # resampling with the identical setup is deterministic
    again = np.asarray(two_stage_sample(jax.random.key(1), w, 1000,
                                        shards_per_device=8))
    assert np.array_equal(ref, again)
    # all mass in one shard still resolves in-range
    w1 = jnp.zeros((n,)).at[100:110].set(1.0)
    idx = np.asarray(two_stage_sample(jax.random.key(2), w1, 500,
                                      shards_per_device=8))
    assert idx.min() >= 100 and idx.max() < 110


def test_write_scores_global_drops_foreign_rows():
    """write_scores_global with axes=() equals write_scores; out-of-range
    indices never corrupt the local shard."""
    from repro.core.weight_store import (init_store, write_scores,
                                         write_scores_global)

    store = init_store(64)
    idx = jnp.asarray([3, 17, 42], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    a = write_scores(store, idx, vals, 5)
    b = write_scores_global(store, idx, vals, 5, axes=())
    np.testing.assert_array_equal(np.asarray(a.weights), np.asarray(b.weights))
    np.testing.assert_array_equal(np.asarray(a.scored_at),
                                  np.asarray(b.scored_at))


def test_scatter_rows_duplicate_indices_last_write_wins():
    """Fused mode samples with replacement, so one batch can write the same
    row twice; XLA scatter order is unspecified, so scatter_rows pins
    last-write-wins (the freshest score for that example in program
    order)."""
    from repro.core.collectives import scatter_rows

    arr = jnp.zeros((8,), jnp.float32)
    idx = jnp.asarray([2, 2, 5, 2], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    out = np.asarray(scatter_rows(arr, idx, vals, axes=()))
    assert out[2] == 4.0, out          # the LAST write to row 2 wins
    assert out[5] == 3.0, out
    assert np.all(out[[0, 1, 3, 4, 6, 7]] == 0.0)
    # jitted path agrees (the semantics must not depend on op lowering)
    out_j = np.asarray(jax.jit(
        lambda a, i, v: scatter_rows(a, i, v, axes=()))(arr, idx, vals))
    np.testing.assert_array_equal(out, out_j)


def test_write_scores_global_duplicate_indices_last_write_wins():
    from repro.core.weight_store import init_store, write_scores_global

    store = write_scores_global(
        init_store(16),
        jnp.asarray([3, 9, 3, 3], jnp.int32),
        jnp.asarray([1.0, 7.0, 2.0, 5.0], jnp.float32), step=4, axes=())
    w = np.asarray(store.weights)
    assert w[3] == 5.0 and w[9] == 7.0, w
    assert int(store.scored_at[3]) == 4


def test_scatter_rows_duplicates_sharded_last_write_wins():
    """Same semantics when the array is sharded: duplicates that cross into
    one device's shard still resolve to the last occurrence."""
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.collectives import scatter_rows
        from repro.dist import shard_map

        """ + mesh_src(2) + """
        arr = jax.device_put(jnp.zeros((8,), jnp.float32),
                             NamedSharding(mesh, P('data')))
        idx = jnp.asarray([6, 1, 6, 1, 3], jnp.int32)   # dups on both shards
        vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0], jnp.float32)
        f = shard_map(lambda a, i, v: scatter_rows(a, i, v, ('data',)),
                      mesh=mesh, in_specs=(P('data'), P(), P()),
                      out_specs=P('data'))
        out = np.asarray(jax.jit(f)(arr, idx, vals))
        assert out[6] == 3.0 and out[1] == 4.0 and out[3] == 5.0, out
        print('sharded last-write-wins ok')
    """, devices=2)
    assert "sharded last-write-wins ok" in out


@pytest.mark.slow
def test_train_cli_smoke_mesh4():
    """End-to-end CLI gate: the acceptance-criteria command (reduced step
    count) runs green on 4 forced host devices."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # train.py must force the devices itself
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--mesh", "4", "--steps", "8", "--examples", "1024"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "mesh: (4,)" in r.stdout, r.stdout[-1000:]

"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant, run one forward pass, one ISSGD train step, and one
serve decode step on CPU; assert output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_lm_scorer
from repro.data import make_token_dataset
from repro.models.transformer import forward, init_transformer, per_example_loss
from repro.optim import sgd
from repro.serving.engine import decode_step, init_serve_state, prefill


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_integrity(name):
    cfg = get_config(name)
    assert cfg.num_layers % cfg.period_len() == 0
    assert cfg.param_count() > 1e9
    # every full config must be expressible by the layer machinery
    assert len(cfg.layer_specs()) == cfg.period_len()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_shapes(name):
    cfg = get_smoke_config(name)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = init_transformer(jax.random.key(0), cfg)
    b, s = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            jax.random.key(2), (b, min(cfg.num_frontend_tokens, 8),
                                cfg.d_model)) * 0.02
    losses, aux = per_example_loss(params, cfg, batch)
    assert losses.shape == (b,)
    assert not bool(jnp.any(jnp.isnan(losses)))
    assert bool(jnp.all(losses > 0))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_issgd_train_step(name):
    cfg = get_smoke_config(name)
    params = init_transformer(jax.random.key(0), cfg)
    data = make_token_dataset(jax.random.key(1), n=64, seq=17,
                              vocab=cfg.vocab_size)
    opt = sgd(1e-2)
    tcfg = ISSGDConfig(batch_size=4, score_batch_size=8, refresh_every=2,
                       mode="relaxed", is_cfg=ISConfig(smoothing=1.0))
    step = jax.jit(make_train_step(
        lambda p, b: per_example_loss(p, cfg, b)[0],
        make_lm_scorer(cfg, "logit_grad"), opt, tcfg, data.size))
    st = init_train_state(params, opt, data.size)
    for _ in range(2):
        st, m = step(st, data.arrays)
    assert np.isfinite(float(m.loss))
    assert not any(bool(jnp.any(jnp.isnan(x)))
                   for x in jax.tree.leaves(st.params))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    cfg = get_smoke_config(name)
    params = init_transformer(jax.random.key(0), cfg)
    b = 2
    st = init_serve_state(cfg, batch=b, max_len=32)
    # warm the cache with a short prompt, then decode twice
    prompt = jax.random.randint(jax.random.key(1), (b, 8), 0, cfg.vocab_size)
    logits, st = prefill(params, cfg, prompt, max_len=32)
    assert logits.shape == (b, cfg.vocab_size)
    for t in range(2):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, st = decode_step(params, cfg, tok, st)
        assert logits.shape == (b, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", ["glm4-9b", "jamba-v0.1-52b",
                                  "falcon-mamba-7b", "minicpm3-4b",
                                  "dbrx-132b"])
def test_smoke_decode_matches_forward(name):
    """Teacher-forced decode reproduces the training forward exactly."""
    cfg = get_smoke_config(name)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # dropless
    params = init_transformer(jax.random.key(0), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    logits_full, _ = forward(params, cfg, toks)
    last, st = prefill(params, cfg, toks[:, :s // 2], max_len=32)
    errs = [float(jnp.max(jnp.abs(last - logits_full[:, s // 2 - 1])))]
    for t in range(s // 2, s):
        lg, st = decode_step(params, cfg, toks[:, t], st)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, t]))))
    assert max(errs) < 1e-4, errs


def test_sliding_window_ring_decode_exact():
    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), sliding_window=8)
    params = init_transformer(jax.random.key(0), cfg)
    b, s = 2, 24
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    logits_full, _ = forward(params, cfg, toks)
    last, st = prefill(params, cfg, toks[:, :s // 2], max_len=64)
    errs = [float(jnp.max(jnp.abs(last - logits_full[:, s // 2 - 1])))]
    for t in range(s // 2, s):
        lg, st = decode_step(params, cfg, toks[:, t], st)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, t]))))
    assert max(errs) < 1e-4, errs


def test_pallas_decode_kernel_in_engine():
    """The flash-decode kernel path agrees with the ref path end-to-end."""
    cfg = get_smoke_config("glm4-9b")
    params = init_transformer(jax.random.key(0), cfg)
    b = 2
    prompt = jax.random.randint(jax.random.key(1), (b, 8), 0, cfg.vocab_size)
    logits, st = prefill(params, cfg, prompt, max_len=32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    l_ref, _ = decode_step(params, cfg, tok, st, decode_kernel="ref")
    l_pal, _ = decode_step(params, cfg, tok, st, decode_kernel="pallas")
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_prefill_matches_ref_prefill():
    """attn_impl='pallas' (flash kernel) prefill == chunked-jnp prefill."""
    cfg = get_smoke_config("glm4-9b")
    params = init_transformer(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, cfg.vocab_size)
    l_ref, st_ref = prefill(params, cfg, toks, max_len=32, attn_impl="ref")
    l_pal, st_pal = prefill(params, cfg, toks, max_len=32, attn_impl="pallas")
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-5)
    for k in st_ref.caches:
        np.testing.assert_allclose(np.asarray(st_pal.caches[k], jnp.float32),
                                   np.asarray(st_ref.caches[k], jnp.float32),
                                   rtol=1e-4, atol=1e-5)

"""Shared test utilities (imported by the test modules; tests/ is on
sys.path under pytest's rootdir insertion)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The dp×mp equivalence grid of ISSUE 4: a pure-model-parallel mesh, a
# mixed one, and the data-only degenerate case — every mesh-shaped test
# battery parametrizes over this with the `dp_mp_grid` decorator.
DP_MP_MESHES = [(1, 2), (2, 2), (4, 1)]
dp_mp_grid = pytest.mark.parametrize("dp,mp", DP_MP_MESHES)


def mesh_src(dp: int, mp: int = 1) -> str:
    """Source snippet constructing ``mesh`` with the given dp×mp shape —
    the one place test subprocesses build meshes, delegating to the
    launcher's own `make_debug_mesh` so tests always exercise the mesh
    layout the production entry point builds."""
    return ("from repro.launch.mesh import make_debug_mesh as _mdm; "
            f"mesh = _mdm({dp}, model={mp})")


def run_py(code: str, devices: int = 4, timeout: int = 560) -> str:
    """Run `code` in a subprocess with N forced XLA host devices (the
    device count is fixed at first backend init, so multi-device tests
    cannot share the pytest process)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def run_mesh_py(code: str, dp: int, mp: int = 1, timeout: int = 560) -> str:
    """`run_py` with the device count forced to dp·mp and a ``mesh``
    variable (plus ``DP``/``MP`` ints) prepended to the snippet."""
    header = f"import jax\nDP, MP = {dp}, {mp}\n" + mesh_src(dp, mp) + "\n"
    return run_py(header + textwrap.dedent(code), devices=dp * mp,
                  timeout=timeout)

"""Shared test utilities (imported by the test modules; tests/ is on
sys.path under pytest's rootdir insertion)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 560) -> str:
    """Run `code` in a subprocess with N forced XLA host devices (the
    device count is fixed at first backend init, so multi-device tests
    cannot share the pytest process)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout

"""The closed train/serve loop: decode on the training mesh against
published snapshots, finished traffic back into the store, reserved rows
flipped live and picked up by scoring + the two-stage proposal.

Covers the growth primitives (store append/write_rows, plane growth
bookkeeping), the EMPTY reserved-row discipline in the WeightStore, the
TrafficIngest watermark, and the acceptance criterion of ISSUE 7: the
loop closes on one device AND on a dp×mp mesh — a served row ends up in
the example store, gets a scoring stamp, and carries nonzero proposal
mass, while untouched reserved rows stay inert.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import run_mesh_py


# ---------------------------------------------------------------------------
# store growth
# ---------------------------------------------------------------------------

def test_store_append_and_write_rows_round_trip():
    from repro.data.store import ChunkedExampleStore

    rng = np.random.default_rng(0)
    arrays = {"x": rng.normal(size=(64, 5)).astype(np.float32),
              "y": rng.integers(0, 9, size=(64,)).astype(np.int32)}
    store = ChunkedExampleStore.from_arrays(arrays, chunk_size=16)

    cid = store.append_chunk()
    assert cid == 4
    assert store.num_chunks == 5 and store.num_examples == 80
    # existing rows keep their indices and bits; new rows are zeros
    got = store.fetch_rows(np.asarray([0, 63]))
    np.testing.assert_array_equal(got["x"], arrays["x"][[0, 63]])
    assert not store.fetch_rows(np.asarray([64, 79]))["x"].any()

    rows = {"x": rng.normal(size=(3, 5)).astype(np.float32),
            "y": rng.integers(0, 9, size=(3,)).astype(np.int32)}
    idx = np.asarray([64, 71, 79])
    store.write_rows(idx, rows)
    back = store.fetch_rows(idx)
    np.testing.assert_array_equal(back["x"], rows["x"])
    np.testing.assert_array_equal(back["y"], rows["y"])

    with pytest.raises(IndexError, match="out of range"):
        store.write_rows(np.asarray([80]), rows)
    with pytest.raises(ValueError, match="chunk keys"):
        store.append_chunk({"x": np.zeros((16, 5), np.float32)})


def test_plane_routes_grown_rows_through_host():
    from repro.data.store import ChunkedExampleStore
    from repro.data.streaming import StreamingDataPlane

    rng = np.random.default_rng(1)
    arrays = {"x": rng.normal(size=(64, 4)).astype(np.float32)}
    store = ChunkedExampleStore.from_arrays(arrays, chunk_size=16)
    plane = StreamingDataPlane(store, window_chunks=2)

    store.append_chunk()
    want = rng.normal(size=(1, 4)).astype(np.float32)
    store.write_rows(np.asarray([70]), {"x": want})
    got = plane.gather_global(np.asarray([70, 0]))
    np.testing.assert_array_equal(got["x"][0], want[0])
    np.testing.assert_array_equal(got["x"][1], arrays["x"][0])
    # a pre-growth-length mass vector still schedules a prefetch
    plane.prefetch(np.ones(4, np.float32))


# ---------------------------------------------------------------------------
# reserved WeightStore rows: EMPTY until marked live
# ---------------------------------------------------------------------------

def test_reserved_rows_inert_until_marked_live():
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, make_scoring_pass
    from repro.core.scorer import make_mlp_scorer
    from repro.core.weight_store import (EMPTY, init_store, mark_live,
                                         read_proposal, reserve_tail)
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier

    cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=64, dim=16, classes=4)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    tcfg = ISSGDConfig(batch_size=8, score_batch_size=32, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.1))
    scoring_pass = make_scoring_pass(make_mlp_scorer(cfg, "ghost"), tcfg, 64)

    store = reserve_tail(init_store(64), 48)
    assert (np.asarray(store.scored_at[48:]) == EMPTY).all()
    data = train.arrays
    for t in range(4):  # two full round-robin sweeps over all 64 rows
        store, _, _ = scoring_pass(params, store, jnp.asarray(t), data)
    sa = np.asarray(store.scored_at)
    assert (sa[:48] >= 0).all()
    assert (sa[48:] == EMPTY).all(), "scoring stamped reserved rows"
    q = np.asarray(read_proposal(store, 4, tcfg.is_cfg))
    assert (q[:48] > 0).all()
    assert (q[48:] == 0).all(), "reserved rows leaked proposal mass"

    store = mark_live(store, jnp.asarray([48, 49]))
    assert np.asarray(store.scored_at)[48] == -1  # live, never scored
    for t in range(4, 8):
        store, _, _ = scoring_pass(params, store, jnp.asarray(t), data)
    sa = np.asarray(store.scored_at)
    assert sa[48] >= 0 and sa[49] >= 0
    assert (sa[50:] == EMPTY).all()
    q = np.asarray(read_proposal(store, 8, tcfg.is_cfg))
    assert q[48] > 0 and q[49] > 0 and (q[50:] == 0).all()


# ---------------------------------------------------------------------------
# traffic ingest
# ---------------------------------------------------------------------------

def test_traffic_ingest_watermark_padding_capacity():
    from repro.data.store import ChunkedExampleStore
    from repro.serving import TrafficIngest

    store = ChunkedExampleStore.from_arrays(
        {"tokens": np.arange(320, dtype=np.int32).reshape(32, 10)}, 8)
    store.append_chunk()
    ing = TrafficIngest(store, seq_len=10, start_row=32, capacity_rows=4)

    ing.add(np.asarray([5, 6, 7]), np.asarray([8, 9]))
    idx = ing.flush()
    np.testing.assert_array_equal(idx, [32])
    row = store.fetch_rows(idx)["tokens"][0]
    np.testing.assert_array_equal(row, [5, 6, 7, 8, 9, 0, 0, 0, 0, 0])

    # overlong traffic truncates to the row length
    ing.add(np.arange(8), np.arange(8))
    np.testing.assert_array_equal(
        store.fetch_rows(ing.flush())["tokens"][0],
        [0, 1, 2, 3, 4, 5, 6, 7, 0, 1])

    # capacity: 2 rows of room left, 5 queued -> 3 dropped, none overwrite
    for _ in range(5):
        ing.add(np.asarray([1]), np.asarray([2]))
    assert ing.flush().tolist() == [34, 35]
    assert ing.ingested == 4 and ing.dropped == 3
    assert ing.flush().size == 0
    # row 0 of the live region untouched throughout
    np.testing.assert_array_equal(store.fetch_rows(np.asarray([0]))["tokens"][0],
                                  np.arange(10))


# ---------------------------------------------------------------------------
# the acceptance criterion: the loop closes
# ---------------------------------------------------------------------------

def _loop_fixture():
    """Live token store + reserved tail, streamed pipe, serve loop — the
    train.py --serve-loop wiring, assembled by hand."""
    from repro.configs import get_smoke_config
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig, init_train_state
    from repro.core.scorer import make_lm_scorer
    from repro.core.weight_store import init_store, reserve_tail
    from repro.data import make_token_dataset
    from repro.data.store import ChunkedExampleStore
    from repro.data.streaming import (StreamedISSGD, StreamingDataPlane,
                                      make_streamed_steps)
    from repro.models.transformer import init_transformer, per_example_loss
    from repro.optim import sgd
    from repro.serving import (ContinuousBatcher, ServeLoop, TrafficIngest,
                               make_synthetic_traffic)

    cfg = get_smoke_config("glm4-9b")
    train = make_token_dataset(jax.random.key(0), n=64, seq=17,
                               vocab=cfg.vocab_size)
    store = ChunkedExampleStore.from_arrays(train.arrays, chunk_size=8)
    n_live = store.num_examples
    store.append_chunk()
    store.append_chunk()
    n = store.num_examples
    params = init_transformer(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=4, score_batch_size=16, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.1))
    pel = lambda p, b: per_example_loss(p, cfg, b)[0]
    scorer = make_lm_scorer(cfg, "loss")
    s, smp, m = make_streamed_steps(pel, scorer, opt, tcfg, n, 8)
    plane = StreamingDataPlane(store, window_chunks=2)
    pipe = StreamedISSGD(plane, s, smp, m, tcfg, n)
    state = init_train_state(params, opt, n)._replace(
        store=reserve_tail(init_store(n), n_live))

    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=8)
    ingest = TrafficIngest(store, seq_len=17, start_row=n_live,
                           capacity_rows=n - n_live)
    traffic = make_synthetic_traffic(cfg.vocab_size, prompt_len=4, rate=1,
                                     max_new_tokens=4, seed=3)
    serve = ServeLoop(batcher, ingest, traffic)
    pipe.serve_tick = serve.on_train_step
    return cfg, tcfg, store, pipe, state, serve, n_live, n


def test_serve_loop_closes_single_device():
    from repro.core.weight_store import EMPTY, read_proposal

    cfg, tcfg, store, pipe, state, serve, n_live, n = _loop_fixture()
    prompts, gens, order = {}, {}, []
    inner = serve.traffic

    def recording_traffic(tick):
        reqs = inner(tick)
        for r in reqs:
            prompts[r.uid] = np.asarray(r.prompt)
        return reqs

    serve.traffic = recording_traffic
    drain = serve.batcher.drain_completed

    def recording_drain():
        done = drain()
        for req, gen in done:
            gens[req.uid] = list(gen)
            order.append(req.uid)
        return done

    serve.batcher.drain_completed = recording_drain

    for _ in range(16):
        state, _ = pipe.step(state)
        state = serve.ingest_into(state)

    ingested = serve.ingest.ingested
    assert 1 <= ingested < n - n_live, ingested
    assert serve.ingest.dropped == 0
    # served rows landed verbatim (prompt + generated, zero-padded)
    for j, uid in enumerate(order[:ingested][:3]):
        toks = np.concatenate([prompts[uid], gens[uid]])
        row = store.fetch_rows(np.asarray([n_live + j]))["tokens"][0]
        np.testing.assert_array_equal(row[:toks.size], toks)
        assert not row[toks.size:].any()
    # ...and entered the scoring fan-out + proposal
    sa = np.asarray(state.store.scored_at)
    q = np.asarray(read_proposal(state.store, state.step, tcfg.is_cfg))
    assert sa[n_live] >= 0, "served row never scored"
    assert q[n_live] > 0, "served row carries no proposal mass"
    assert sa[n - 1] == EMPTY and q[n - 1] == 0, "untouched reserve leaked"


_MESH_LOOP = """
import numpy as np
from repro.configs import get_smoke_config
from repro.core import distributed as D
from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state
from repro.core.scorer import make_lm_scorer
from repro.core.weight_store import (EMPTY, init_store, read_proposal,
                                     reserve_tail)
from repro.data import make_token_dataset
from repro.data.store import ChunkedExampleStore
from repro.data.streaming import StreamedISSGD, StreamingDataPlane
from repro.dist.sharding import param_pspecs
from repro.models.transformer import (init_transformer, per_example_loss,
                                      transformer_specs)
from repro.optim import sgd
from repro.serving import (ContinuousBatcher, ServeLoop, TrafficIngest,
                           make_synthetic_traffic)

cfg = get_smoke_config("glm4-9b")
train = make_token_dataset(jax.random.key(0), n=64, seq=17,
                           vocab=cfg.vocab_size)
store = ChunkedExampleStore.from_arrays(train.arrays, chunk_size=8)
n_live = store.num_examples
store.append_chunk()  # reserve BEFORE the sharded plane lays out chunks
store.append_chunk()
n = store.num_examples
params = init_transformer(jax.random.key(1), cfg)
opt = sgd(0.05)
tcfg = ISSGDConfig(batch_size=4, score_batch_size=16, mode="relaxed",
                   is_cfg=ISConfig(smoothing=0.1))
maxes = ("model",) if MP > 1 else ()
pel = lambda p, b: per_example_loss(p, cfg, b, model_axes=maxes)[0]
scorer = make_lm_scorer(cfg, "loss", model_axes=maxes)
specs = transformer_specs(cfg)
template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
            for k in store.keys}
s, smp, m, tcfg = D.make_sharded_streamed_steps(
    pel, scorer, opt, tcfg, n, mesh, template, chunk_size=8,
    param_specs=specs, params_template=params)
plane = StreamingDataPlane(store, window_chunks=2, mesh=mesh)
pipe = StreamedISSGD(plane, s, smp, m, tcfg, n)
state = init_train_state(params, opt, n)._replace(
    store=reserve_tail(init_store(n), n_live))
state = D.shard_train_state(state, mesh, param_specs=specs)

b_pp = param_pspecs(specs, params, mesh) if MP > 1 else None
batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=8,
                            mesh=mesh, param_pspecs=b_pp)
ingest = TrafficIngest(store, seq_len=17, start_row=n_live,
                       capacity_rows=n - n_live)
traffic = make_synthetic_traffic(cfg.vocab_size, prompt_len=4, rate=1,
                                 max_new_tokens=4, seed=3)
serve = ServeLoop(batcher, ingest, traffic)
pipe.serve_tick = serve.on_train_step

for _ in range(12):
    state, _ = pipe.step(state)
    state = serve.ingest_into(state)

assert serve.ingest.ingested >= 1, serve.ingest.ingested
sa = np.asarray(state.store.scored_at)
q = np.asarray(read_proposal(state.store, state.step, tcfg.is_cfg))
assert sa[n_live] >= 0, sa[n_live]
assert q[n_live] > 0
assert sa[n - 1] == EMPTY and q[n - 1] == 0

# a sharded plane refuses post-layout growth (ownership would remap)
store.append_chunk()
try:
    plane.gather_global(np.asarray([0]))
except ValueError as e:
    assert "reserve chunks before" in str(e)
else:
    raise AssertionError("sharded plane accepted store growth")
print("LOOP-OK", serve.ingest.ingested)
"""


@pytest.mark.slow
def test_serve_loop_closes_on_mesh():
    out = run_mesh_py(_MESH_LOOP, 2, 2)
    assert "LOOP-OK" in out


_MESH_DECODE = """
import numpy as np
from repro.configs import get_smoke_config
from repro.dist.sharding import param_pspecs
from repro.models.transformer import init_transformer, transformer_specs
from repro.serving import ContinuousBatcher, Request
from repro.serving.engine import generate

cfg = get_smoke_config("glm4-9b")
params = init_transformer(jax.random.key(0), cfg)
prompts = [jax.random.randint(jax.random.key(i + 1), (8,), 0,
                              cfg.vocab_size) for i in range(3)]
want = {i: generate(params, cfg, p[None], steps=4, max_len=16)[0].tolist()
        for i, p in enumerate(prompts)}

b_pp = param_pspecs(transformer_specs(cfg), params, mesh) if MP > 1 else None
batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=16,
                            mesh=mesh, param_pspecs=b_pp)
got = batcher.run([Request(uid=i, prompt=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
assert got == want, (got, want)
print("DECODE-OK")
"""


@pytest.mark.slow
def test_mesh_batcher_matches_host_generate():
    out = run_mesh_py(_MESH_DECODE, 2, 2)
    assert "DECODE-OK" in out

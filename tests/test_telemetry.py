"""Telemetry (repro/telemetry/): monitors, events, spans, and the report.

The two contracts that make in-step monitors safe to ship on by default
are pinned here first: monitors OFF is the identity code path (the step's
HLO is byte-identical to a build that never heard of telemetry), and
monitors ON never perturbs the trajectory (bitwise-equal params/store
after N steps).  Then value correctness (every monitor against a numpy
brute force, ESS cross-checked against StepMetrics.ess_frac, entropy
against importance.proposal_entropy), the async staleness monitor
observing exactly the PR-2 lag L(t) = t − K⌊t/K⌋ + 1, mesh/single-device
agreement, and the non-blocking span contract: dispatch spans stay far
below the blocked phase wall-clock, the witness that instrumentation did
not re-serialize the scoring/master overlap.

Satellites: score_trace_metrics (NaN path, brute-force eqs. 6-9,
collective-freeness under a mesh) and tools/metrics_report.py
reproducing the √TrΣ trajectory from a run's JSONL.
"""
import json
import math
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import REPO, mesh_src, run_py as _run_py


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _setup(n=256, hidden=(32,), dim=16, batch=16, score_batch=64,
           smoothing=0.1):
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                  per_example_loss)
    from repro.optim import sgd

    cfg = MLPConfig(input_dim=dim, hidden=hidden, num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=dim, classes=4)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=batch, score_batch_size=score_batch,
                       mode="relaxed", is_cfg=ISConfig(smoothing=smoothing),
                       score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    return pel, scorer, opt, tcfg, params, train


# ---------------------------------------------------------------------------
# MonitorSet
# ---------------------------------------------------------------------------

def test_monitor_set_parse_and_validate():
    from repro.telemetry import MONITOR_NAMES, MonitorSet

    assert MonitorSet.parse("all").names == MONITOR_NAMES
    assert MonitorSet.parse("none").names == ()
    assert MonitorSet.parse("").names == ()
    assert MonitorSet.parse("off").names == ()
    # order-normalized regardless of spelling order
    assert MonitorSet.parse("staleness,ess").names == ("ess", "staleness")
    assert not MonitorSet(())          # falsy -> collapses to the off path
    assert MonitorSet(("ess",))
    assert (MonitorSet(()) or None) is None
    with pytest.raises(ValueError, match="unknown monitor"):
        MonitorSet.parse("ess,bogus")
    with pytest.raises(ValueError, match="unknown monitor"):
        MonitorSet(("bogus",))


# ---------------------------------------------------------------------------
# events + spans
# ---------------------------------------------------------------------------

def test_event_sink_roundtrip(tmp_path):
    from repro.telemetry import SCHEMA_VERSION, EventSink
    from repro.telemetry.events import read_events

    p = str(tmp_path / "run.jsonl")
    sink = EventSink(p, run={"arch": "mlp", "seed": 3}, flush_every=2)
    sink.span("scoring.dispatch", 0.0123, step=0)
    sink.counter("stream.hit_rate", 0.5, step=0)
    sink.emit("metrics", step=1, loss=float(np.float32(1.5)),
              idx=np.arange(2))
    sink.close()
    sink.close()   # idempotent

    recs = read_events(p)
    assert [r["kind"] for r in recs] == ["run", "span", "counter", "metrics"]
    assert all(r["v"] == SCHEMA_VERSION for r in recs)
    assert all("t" in r for r in recs)
    assert recs[0]["arch"] == "mlp"
    assert recs[1]["name"] == "scoring.dispatch"
    assert recs[1]["dur_s"] == pytest.approx(0.0123)
    assert recs[2]["value"] == 0.5
    assert recs[3]["loss"] == 1.5 and recs[3]["idx"] == [0, 1]

    # appended garbage is skipped, not fatal (crashed runs truncate lines)
    with open(p, "a") as f:
        f.write("{not json\n")
    assert len(read_events(p)) == 4


def test_null_sink_is_inert(tmp_path):
    from repro.telemetry import NullSink, Telemetry

    sink = NullSink()
    assert not sink
    sink.emit("metrics", loss=1.0)
    sink.span("x", 0.1)
    sink.counter("c", 1)
    sink.flush(), sink.close()
    assert sink.emitted == 0 and sink.path is None

    tel = Telemetry.null()
    assert not tel
    assert tel is Telemetry.null()     # shared instance
    assert tel.timed("x", lambda a: a + 1, 1) == 2   # bypasses spans
    with tel.span("y"):
        pass
    assert not tel.due(0)              # never due: nothing to emit into


def test_span_context_and_timed_block(tmp_path):
    from repro.telemetry import EventSink
    from repro.telemetry.events import read_events
    from repro.telemetry.spans import span, timed

    p = str(tmp_path / "s.jsonl")
    sink = EventSink(p)
    with span(sink, "serve.tick", step=4):
        time.sleep(0.01)
    out = timed(sink, "master.dispatch", jnp.square, jnp.float32(3.0),
                step=5, block=True)
    assert float(out) == 9.0
    sink.close()
    recs = [r for r in read_events(p) if r["kind"] == "span"]
    assert recs[0]["name"] == "serve.tick" and recs[0]["step"] == 4
    assert recs[0]["dur_s"] >= 0.01
    assert recs[1]["name"] == "master.dispatch" and recs[1]["dur_s"] > 0


# ---------------------------------------------------------------------------
# the two safety contracts
# ---------------------------------------------------------------------------

def test_monitors_off_is_hlo_identical():
    """A monitors-off build compiles to the byte-identical program of a
    build that never passed the kwarg — the gate that telemetry costs
    nothing when unused."""
    from repro.core.issgd import init_train_state, make_train_step
    from repro.telemetry import MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup()
    state = init_train_state(params, opt, train.size, seed=0)

    def lowered(**kw):
        step = make_train_step(pel, scorer, opt, tcfg, train.size, **kw)
        return jax.jit(step).lower(state, train.arrays).as_text()

    base = lowered()
    assert lowered(monitors=None) == base
    assert lowered(monitors=MonitorSet(())) == base


def test_monitors_on_is_bitwise_noninvasive():
    """Enabling every monitor adds outputs but never changes the
    trajectory: params, store, and metrics stay bitwise equal."""
    from repro.core.issgd import init_train_state, make_train_step
    from repro.telemetry import MONITOR_NAMES, MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup()
    plain = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size))
    mon_step = make_train_step(pel, scorer, opt, tcfg, train.size,
                               monitors=MonitorSet.all())
    assert mon_step.with_monitors
    mon_step = jax.jit(mon_step)

    s_a = init_train_state(params, opt, train.size, seed=0)
    s_b = init_train_state(params, opt, train.size, seed=0)
    for _ in range(6):
        s_a, m_a = plain(s_a, train.arrays)
        s_b, m_b, mon = mon_step(s_b, train.arrays)
    assert set(mon) == set(MONITOR_NAMES)
    s_a = s_a._replace(rng=jax.random.key_data(s_a.rng))
    s_b = s_b._replace(rng=jax.random.key_data(s_b.rng))
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m_a.loss), np.asarray(m_b.loss))


# ---------------------------------------------------------------------------
# monitor values
# ---------------------------------------------------------------------------

def test_monitor_values_match_brute_force():
    """Each monitor against a numpy reference computed from the exact
    proposal the master sampled from (the untouched read_buf of an async
    step), plus cross-checks against the repo's own ESS / entropy
    helpers and StepMetrics.ess_frac."""
    from repro.core.async_pipeline import init_async_state, make_async_steps
    from repro.core.importance import proposal_entropy
    from repro.core.weight_store import read_proposal
    from repro.telemetry import MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup()
    _, master = make_async_steps(pel, scorer, opt, tcfg, train.size,
                                 monitors=MonitorSet.all())
    assert master.with_monitors
    state = init_async_state(params, opt, train.size, seed=0)
    read_buf = state.store.read_buf

    *_, metrics, mon = jax.jit(master)(
        state.params, state.opt_state, state.stale_params, read_buf,
        state.step, state.rng, train.arrays)

    w = np.asarray(read_proposal(read_buf, state.step, tcfg.is_cfg),
                   np.float64)
    n = train.size
    ess_ref = (w.sum() ** 2 / (w ** 2).sum()) / n
    wn = w / w.sum()
    ent_ref = -(wn[wn > 0] * np.log(wn[wn > 0])).sum()
    assert float(mon["ess"]) == pytest.approx(ess_ref, rel=1e-5)
    assert float(mon["entropy"]) == pytest.approx(ent_ref, rel=1e-5)
    assert float(mon["entropy"]) == pytest.approx(
        float(proposal_entropy(jnp.asarray(w, jnp.float32))), rel=1e-5)
    assert float(mon["max_weight_frac"]) == pytest.approx(
        w.max() / w.sum(), rel=1e-5)
    assert int(mon["empty_rows"]) == 0
    # cold store: scored_at == -1 everywhere -> staleness = step + 1
    assert int(mon["staleness"]) == 1
    # the same proposal's ESS/N is already a StepMetrics field — agree
    assert float(mon["ess"]) == pytest.approx(float(metrics.ess_frac),
                                              rel=1e-6)


def test_empty_rows_counts_reserved_capacity():
    """The empty_rows monitor counts exactly the EMPTY-reserved serving
    rows, which carry zero proposal mass."""
    from repro.core.async_pipeline import init_async_state, make_async_steps
    from repro.core.weight_store import reserve_tail
    from repro.telemetry import MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup()
    _, master = make_async_steps(pel, scorer, opt, tcfg, train.size,
                                 monitors=MonitorSet(("empty_rows", "ess")))
    state = init_async_state(params, opt, train.size, seed=0)
    n_live = train.size - 32
    rb = reserve_tail(state.store.read_buf, n_live)

    *_, mon = jax.jit(master)(
        state.params, state.opt_state, state.stale_params, rb, state.step,
        state.rng, train.arrays)
    assert int(mon["empty_rows"]) == 32
    # reserved rows are proposal-invisible: ESS is over the live mass only
    assert float(mon["ess"]) == pytest.approx(n_live / train.size, rel=1e-5)


@pytest.mark.parametrize("swap_every", [1, 3])
def test_async_staleness_monitor_observes_lag(swap_every):
    """The staleness monitor reads L(t) = t − K⌊t/K⌋ + 1 right off the
    read_buf the master sampled from — the PR-2 invariant, now observable
    per step from telemetry instead of only provable in tests."""
    from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                           make_async_steps)
    from repro.telemetry import MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup()
    s_step, m_step = make_async_steps(
        pel, scorer, opt, tcfg, train.size,
        monitors=MonitorSet(("staleness",)))
    pipe = AsyncPipeline(s_step, m_step, swap_every)
    state = init_async_state(params, opt, train.size, seed=0)
    K = swap_every
    for t in range(3 * K + 2):
        state, _ = pipe.step(state, train.arrays)
        assert int(pipe.last_monitors["staleness"]) == t - K * (t // K) + 1


def test_mesh_monitors_match_single_device():
    """Monitor scalars psum/pmax to globals: a mesh-4 run reports the
    same values (to float tolerance) as the single-device build."""
    code = """
        import jax, numpy as np
        from repro.core import distributed as D
        from repro.core.issgd import init_train_state, make_train_step
        from repro.telemetry import MonitorSet
        import sys; sys.path.insert(0, "tests")
        from test_telemetry import _setup

        pel, scorer, opt, tcfg, params, train = _setup()
        state = init_train_state(params, opt, train.size, seed=0)

        ref_step = jax.jit(make_train_step(
            pel, scorer, opt, tcfg, train.size, monitors=MonitorSet.all()))
        _, _, ref = ref_step(state, train.arrays)

        %s
        step4, tcfg4 = D.make_sharded_train_step(
            pel, scorer, opt, tcfg, train.size, mesh, train.arrays,
            monitors=MonitorSet.all())
        assert step4.with_monitors
        st4 = D.shard_train_state(state, mesh)
        d4 = D.shard_dataset(train.arrays, mesh)
        _, _, mon = jax.jit(step4)(st4, d4)
        for k in ref:
            np.testing.assert_allclose(np.asarray(mon[k]),
                                       np.asarray(ref[k]), rtol=1e-5)
        print("MESH_MONITORS_OK")
    """ % mesh_src(4)
    assert "MESH_MONITORS_OK" in _run_py(code, devices=4)


# ---------------------------------------------------------------------------
# the overlap witness
# ---------------------------------------------------------------------------

def test_async_dispatch_spans_witness_overlap(tmp_path):
    """Non-blocking spans time only dispatch: with a deliberately heavy
    scoring computation, the recorded scoring.dispatch span must be far
    below the phase's blocked wall-clock — proof the master was dispatched
    while scoring was still in flight (instrumentation did not
    re-serialize the PR-2 overlap)."""
    from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                           make_async_steps)
    from repro.telemetry import EventSink, Telemetry
    from repro.telemetry.events import read_events

    pel, scorer, opt, tcfg, params, train = _setup(
        n=4096, hidden=(256, 256), dim=64, score_batch=1024)
    s_step, m_step = make_async_steps(pel, scorer, opt, tcfg, train.size)

    p = str(tmp_path / "spans.jsonl")
    tel = Telemetry(EventSink(p), every=1)
    pipe = AsyncPipeline(s_step, m_step, telemetry=tel)
    state = init_async_state(params, opt, train.size, seed=0)

    state, m = pipe.step(state, train.arrays)     # warm-up / compile
    jax.block_until_ready((state.params, m))
    # blocked wall-clock of one scoring dispatch, measured directly
    t0 = time.perf_counter()
    out = pipe._scoring(state.stale_params, state.store.write_buf,
                        state.step, train.arrays)
    jax.block_until_ready(out)
    t_block = time.perf_counter() - t0
    # rebuild: the measurement above consumed the donated write_buf
    state = init_async_state(params, opt, train.size, seed=0)
    for _ in range(3):
        state, m = pipe.step(state, train.arrays)
    jax.block_until_ready((state.params, m))
    tel.sink.close()

    spans = [r["dur_s"] for r in read_events(p)
             if r["kind"] == "span" and r["name"] == "scoring.dispatch"]
    assert len(spans) == 4
    # post-warm-up dispatches return long before the compute finishes
    assert min(spans[1:]) < 0.5 * t_block, (spans, t_block)


# ---------------------------------------------------------------------------
# score_trace_metrics satellites
# ---------------------------------------------------------------------------

def test_score_trace_metrics_monitor_false_is_nan():
    from repro.core.async_pipeline import score_trace_metrics

    g = jnp.abs(jax.random.normal(jax.random.key(0), (64,)))
    w = jnp.abs(jax.random.normal(jax.random.key(1), (64,))) + 0.1
    sm = score_trace_metrics(g, w, axes=(), n_total=64, monitor=False)
    assert all(math.isnan(float(v)) for v in sm)


def test_score_trace_metrics_matches_brute_force():
    """√TrΣ against the eq. 6-9 formulas in float64 numpy."""
    from repro.core.async_pipeline import score_trace_metrics

    rng = np.random.default_rng(0)
    g = np.abs(rng.normal(size=(128,))).astype(np.float32)
    w = (np.abs(rng.normal(size=(128,))) + 0.1).astype(np.float32)
    sm = score_trace_metrics(jnp.asarray(g), jnp.asarray(w), axes=(),
                             n_total=128)
    g64, w64 = g.astype(np.float64), w.astype(np.float64)
    ideal = g64.mean() ** 2
    stale = w64.mean() * (g64 ** 2 / w64).mean()
    unif = (g64 ** 2).mean()
    assert float(sm.trace_ideal) == pytest.approx(math.sqrt(ideal), rel=1e-5)
    assert float(sm.trace_stale) == pytest.approx(math.sqrt(stale), rel=1e-5)
    assert float(sm.trace_unif) == pytest.approx(math.sqrt(unif), rel=1e-5)


def test_score_trace_metrics_collectives_under_mesh():
    """Under shard_map the monitored build psums (all-reduce in the HLO);
    monitor=False lowers collective-free — the async scoring step can
    stay rendezvous-free when traces are off."""
    code = """
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core.async_pipeline import ScoreMetrics, score_trace_metrics
        from repro.dist import shard_map
        %s

        g = jnp.abs(jax.random.normal(jax.random.key(0), (256,)))
        w = jnp.abs(jax.random.normal(jax.random.key(1), (256,))) + 0.1

        def lowered(monitor):
            f = shard_map(
                partial(score_trace_metrics, axes=("data",), n_total=256,
                        monitor=monitor),
                mesh=mesh, in_specs=(P("data"), P("data")),
                out_specs=ScoreMetrics(P(), P(), P()))
            return jax.jit(f).lower(g, w).compile().as_text()

        assert "all-reduce" in lowered(True)
        assert "all-reduce" not in lowered(False)
        print("TRACE_COLLECTIVES_OK")
    """ % mesh_src(4)
    assert "TRACE_COLLECTIVES_OK" in _run_py(code, devices=4)


# ---------------------------------------------------------------------------
# metrics_report
# ---------------------------------------------------------------------------

def _run_report(jsonl, out_json):
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "metrics_report.py"),
         jsonl, "--json", out_json],
        capture_output=True, text=True, cwd=REPO, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_metrics_report_reproduces_trajectory(tmp_path):
    """The report's --json trajectory is exactly the metrics records of
    the event stream, and the rendered text carries the fig-4 table."""
    from repro.telemetry import EventSink

    p = str(tmp_path / "run.jsonl")
    sink = EventSink(p, run={"arch": "mlp_svhn", "mode": "relaxed"})
    expect = []
    for i, t in enumerate(range(0, 30, 10)):
        row = {"step": t, "trace_ideal": 10.0 - i, "trace_stale": 11.0 - i,
               "trace_unif": 12.0 - i, "loss": 2.0 / (i + 1)}
        expect.append(row)
        sink.emit("metrics", step=t,
                  **{k: v for k, v in row.items() if k != "step"})
        sink.emit("monitors", step=t, ess=0.5 + 0.1 * i, staleness=1)
    sink.span("scoring.dispatch", 0.004, step=0)
    sink.counter("store.swaps", 3, step=20)
    sink.emit("run_end", step=20, steps=21)
    sink.close()

    out_json = str(tmp_path / "summary.json")
    text = _run_report(p, out_json)
    with open(out_json) as f:
        summary = json.load(f)
    assert summary["trajectory"] == expect
    assert summary["spans"]["scoring.dispatch"]["count"] == 1
    assert summary["counters"]["store.swaps"] == 3
    assert summary["monitors"]["ess"] == [0.5, 0.6, 0.7]
    assert summary["run"]["arch"] == "mlp_svhn"
    assert "√TrΣ trajectory" in text and "scoring.dispatch" in text


@pytest.mark.slow
def test_train_cli_telemetry_end_to_end(tmp_path):
    """train.py --metrics-jsonl + --monitors all, then metrics_report:
    the reported √TrΣ trajectory is the run's own metrics records, and
    span + monitor events are present (the CI smoke greps the same)."""
    jsonl = str(tmp_path / "run.jsonl")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--steps", "8", "--examples", "256", "--batch", "8",
         "--score-batch", "32", "--log-every", "4", "--monitors", "all",
         "--async-scoring", "--swap-every", "2",
         "--metrics-jsonl", jsonl],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]

    from repro.telemetry.events import read_events
    recs = read_events(jsonl)
    kinds = {x["kind"] for x in recs}
    assert {"run", "span", "counter", "metrics", "monitors",
            "run_end"} <= kinds
    mets = [x for x in recs if x["kind"] == "metrics"]

    out_json = str(tmp_path / "summary.json")
    _run_report(jsonl, out_json)
    with open(out_json) as f:
        summary = json.load(f)
    assert [row["step"] for row in summary["trajectory"]] == \
        [m["step"] for m in mets]
    for row, m in zip(summary["trajectory"], mets):
        for f_ in ("trace_ideal", "trace_stale", "trace_unif", "loss"):
            assert row[f_] == m[f_]
    mons = [x for x in recs if x["kind"] == "monitors"]
    assert all(x["staleness"] >= 1 for x in mons)   # async: always lagged
    assert summary["spans"]["scoring.dispatch"]["count"] == 8

"""ASGD simulator + the paper's §6 ISSGD-combination (core/asgd.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asgd import ASGDConfig, init_asgd_state, make_asgd_step
from repro.core.importance import ISConfig
from repro.data import make_svhn_like
from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                              per_example_loss, per_example_loss_and_score)
from repro.optim import sgd


def _setup():
    cfg = MLPConfig(input_dim=32, hidden=(64,), num_classes=10)
    train, _ = make_svhn_like(jax.random.key(0), n=1024, dim=32)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    return cfg, train, params


def _run(mode, delay, steps=120):
    cfg, train, params = _setup()
    opt = sgd(0.05)
    acfg = ASGDConfig(batch_size=64, delay=delay, mode=mode,
                      is_cfg=ISConfig(smoothing=0.5))
    step = jax.jit(make_asgd_step(
        lambda p, b: per_example_loss(p, b, cfg), opt, acfg, train.size,
        fused_score=lambda p, b: per_example_loss_and_score(p, b, cfg)))
    st = init_asgd_state(params, opt, acfg, train.size)
    losses = []
    for _ in range(steps):
        st, m = step(st, train.arrays)
        losses.append(float(m.loss))
    return st, losses, m


def test_asgd_trains_despite_staleness():
    st, losses, m = _run("uniform", delay=4)
    assert losses[-1] < losses[0]
    assert np.isfinite(losses[-1])
    assert float(m.delay_gap) > 0  # gradients really were stale


def test_asgd_delay0_matches_sync_direction():
    """delay=0 ASGD is synchronous SGD: the FIFO head equals params."""
    st, losses, m = _run("uniform", delay=0, steps=30)
    assert float(m.delay_gap) == 0.0


def test_combined_asgd_issgd_trains():
    """The paper's §6 'peers' design: stale grads + shared IS weights."""
    st, losses, m = _run("issgd", delay=4, steps=150)
    assert losses[-1] < losses[0]
    # the store actually received scores from the peers
    assert float(jnp.sum(st.store.scored_at >= 0)) > 0

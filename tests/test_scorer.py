"""Scorer correctness: ghost strategy vs the vmap-grad oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scorer import make_lm_scorer, make_mlp_scorer
from repro.models.config import ModelConfig
from repro.models.mlp import MLPConfig, init_mlp_classifier
from repro.models.transformer import init_transformer, per_example_loss

TAPPED = ["wq", "wk", "wv", "'wo'", "w_in", "w_gate", "w_out", "unembed",
          "router", "in_proj", "x_proj", "out_proj", "wkv_a", "wkv_b",
          "wq_a", "wq_b"]


def _restricted_full_norms(params, cfg, toks):
    """Per-example grad norms over the tapped-linear subset via autodiff."""
    import jax.tree_util as jtu

    def loss_one(p, t):
        l, _ = per_example_loss(p, cfg, {"tokens": t[None]})
        return l[0]

    grads = jax.vmap(jax.grad(loss_one), in_axes=(None, 0))(params, toks)
    sq = 0.0
    for path, g in jtu.tree_flatten_with_path(grads)[0]:
        keys = jtu.keystr(path)
        if any(k in keys for k in TAPPED):
            sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32)),
                              axis=tuple(range(1, g.ndim)))
    return jnp.sqrt(sq)


def test_mlp_ghost_exact():
    """On the paper's MLP, ghost == full over ALL parameters (Prop. 1)."""
    cfg = MLPConfig(input_dim=24, hidden=(32, 16), num_classes=7)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    batch = {"x": jax.random.normal(jax.random.key(1), (8, 24)),
             "y": jax.random.randint(jax.random.key(2), (8,), 0, 7)}
    full = make_mlp_scorer(cfg, "full")(params, batch)
    ghost = make_mlp_scorer(cfg, "ghost")(params, batch)
    np.testing.assert_allclose(np.asarray(ghost), np.asarray(full), rtol=1e-4)


@pytest.mark.parametrize("name,kw", [
    ("dense", dict(num_heads=4, num_kv_heads=2, d_ff=64)),
    ("mla", dict(num_heads=4, num_kv_heads=4, d_ff=64, attention="mla",
                 q_lora_rank=16, kv_lora_rank=12, qk_nope_dim=8,
                 qk_rope_dim=4, v_head_dim=8)),
    ("ssm", dict(num_heads=4, num_kv_heads=4, d_ff=0, ssm_state=4,
                 attention="none")),
    ("hybrid", dict(num_heads=4, num_kv_heads=2, d_ff=64, ssm_state=4,
                    attn_every=2, attn_offset=1)),
])
def test_lm_ghost_matches_restricted_full(name, kw):
    cfg = ModelConfig(name=name, arch_type=name, num_layers=2, d_model=32,
                      vocab_size=50, remat=False, **kw)
    params = init_transformer(jax.random.key(3), cfg)
    toks = jax.random.randint(jax.random.key(4), (4, 12), 0, 50)
    ghost = make_lm_scorer(cfg, "ghost")(params, {"tokens": toks})
    want = _restricted_full_norms(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(ghost), np.asarray(want), rtol=2e-3)


def test_lm_ghost_with_remat_scan():
    """Ghost taps flow through jax.checkpoint'd scan bodies."""
    cfg = ModelConfig(name="d", arch_type="dense", num_layers=4, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=50,
                      remat=True)
    params = init_transformer(jax.random.key(3), cfg)
    toks = jax.random.randint(jax.random.key(4), (3, 10), 0, 50)
    ghost = make_lm_scorer(cfg, "ghost")(params, {"tokens": toks})
    want = _restricted_full_norms(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(ghost), np.asarray(want), rtol=2e-3)


def test_logit_grad_correlates_after_warmup():
    """After a little training the logit-grad proxy ranks examples like the
    true gradient norm (EL2N-style).  At random init the first-layer ‖x‖
    term dominates and the proxy is weak — which is why `ghost` exists."""
    cfg = MLPConfig(input_dim=24, hidden=(32, 32), num_classes=7)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (64, 24))
    # normalize inputs: isolates the backward factor the proxy estimates
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True) * np.sqrt(24)
    batch = {"x": x,
             "y": jax.random.randint(jax.random.key(2), (64,), 0, 7)}
    # a few plain-SGD steps to leave the random-init regime
    from repro.models.mlp import per_example_loss as pel
    for i in range(50):
        g = jax.grad(lambda p: jnp.mean(pel(p, batch, cfg)))(params)
        params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    full = np.asarray(make_mlp_scorer(cfg, "full")(params, batch))
    proxy = np.asarray(make_mlp_scorer(cfg, "logit_grad")(params, batch))
    corr = np.corrcoef(full, proxy)[0, 1]
    assert corr > 0.7, f"proxy should rank like the true norm, corr={corr}"


def test_loss_strategy_nonnegative():
    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=3)
    params = init_mlp_classifier(jax.random.key(0), cfg)
    batch = {"x": jax.random.normal(jax.random.key(1), (8, 8)),
             "y": jax.random.randint(jax.random.key(2), (8,), 0, 3)}
    w = make_mlp_scorer(cfg, "loss")(params, batch)
    assert bool(jnp.all(w >= 0))


@pytest.mark.parametrize("name,kw", [
    ("dense", dict(num_heads=4, num_kv_heads=2, d_ff=64)),
    ("moe", dict(num_heads=4, num_kv_heads=2, d_ff=64, num_experts=4,
                 num_experts_per_tok=2)),
    ("ssm", dict(d_ff=0, ssm_state=4, attention="none")),
])
def test_ghost_rev_matches_ghost(name, kw):
    """The memory-scalable reverse-scan ghost scorer is exact (f32)."""
    cfg = ModelConfig(name=name, arch_type=name, num_layers=4, d_model=32,
                      vocab_size=50, remat=False, dtype="float32", **kw)
    params = init_transformer(jax.random.key(3), cfg)
    toks = jax.random.randint(jax.random.key(4), (4, 12), 0, 50)
    g = np.asarray(make_lm_scorer(cfg, "ghost")(params, {"tokens": toks}))
    r = np.asarray(make_lm_scorer(cfg, "ghost_rev")(params, {"tokens": toks}))
    np.testing.assert_allclose(r, g, rtol=1e-5)

"""Unit + property tests for the core importance-sampling math (paper §3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # CI installs it; skip cleanly where absent
from hypothesis import given, settings, strategies as st

from repro.core import importance as imp
from repro.core import variance as var
from repro.core.importance import ISConfig
from repro.core.sampler import sample_indices

jax.config.update("jax_enable_x64", False)


def _weights(draw_len=st.integers(4, 64)):
    return st.lists(
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False),
        min_size=4, max_size=64,
    )


# ---------------------------------------------------------------- smoothing
@given(_weights(), st.floats(0.0, 50.0))
@settings(max_examples=50, deadline=None)
def test_smoothing_positive_and_monotone(ws, c):
    w = jnp.asarray(ws, jnp.float32)
    cfg = ISConfig(smoothing=c)
    s = imp.smooth_weights(w, cfg)
    assert bool(jnp.all(s > 0))
    # smoothing preserves the ordering of weights
    order_raw = jnp.argsort(w, stable=True)
    order_s = jnp.argsort(s, stable=True)
    np.testing.assert_array_equal(np.asarray(order_raw), np.asarray(order_s))


@given(_weights())
@settings(max_examples=30, deadline=None)
def test_smoothing_limit_is_uniform(ws):
    """B.3: c → ∞ recovers plain SGD (uniform proposal)."""
    w = jnp.asarray(ws, jnp.float32)
    s = imp.smooth_weights(w, ISConfig(smoothing=1e9))
    p = np.asarray(imp.normalize(s))
    np.testing.assert_allclose(p, np.full_like(p, 1.0 / len(p)), rtol=1e-4)


# ------------------------------------------------------------ loss scaling
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_is_estimator_unbiased(seed):
    """The IS gradient estimator has the same expectation as the full mean.

    f(x_n) here is a vector per example; we draw many minibatches with the
    proposal ∝ ω̃ and check the IS-weighted mean converges to the true mean.
    """
    rng = np.random.default_rng(seed)
    N, d = 64, 8
    f = rng.normal(size=(N, d)).astype(np.float32)
    w = rng.uniform(0.1, 10.0, size=N).astype(np.float32)
    true_mean = f.mean(axis=0)

    key = jax.random.key(seed)
    M = 4096 * 8
    idx = np.asarray(sample_indices(key, jnp.asarray(w), M))
    scale = np.asarray(imp.is_loss_scale(jnp.asarray(w)[idx], jnp.mean(jnp.asarray(w))))
    est = (f[idx] * scale[:, None]).mean(axis=0)
    # Monte-Carlo: tolerance scales with the estimator std
    g2 = (np.linalg.norm(f, axis=1) ** 2 / w).mean() * w.mean()
    tol = 5.0 * np.sqrt(g2 / M) + 1e-4
    assert np.linalg.norm(est - true_mean) < tol


# -------------------------------------------------------- variance monitors
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_trace_sigma_matches_bruteforce(seed):
    """Eq. 6 equals the brute-force covariance trace of the IS estimator."""
    rng = np.random.default_rng(seed)
    N, d = 32, 5
    f = rng.normal(size=(N, d)).astype(np.float64)
    w = rng.uniform(0.5, 4.0, size=N).astype(np.float64)
    p = w / w.sum()
    mu = f.mean(axis=0)
    # estimator for draw n:  (1/N) * f_n / p_n  = f_n * mean(w)/w_n
    est = f * (w.mean() / w)[:, None]
    second = (p[:, None] * est * est).sum(axis=0)  # E[est⊙est]
    brute = second.sum() - (mu ** 2).sum()
    ours = float(var.trace_sigma(
        jnp.asarray(np.linalg.norm(f, axis=1)), jnp.asarray(w),
        g_true_sq=float((mu ** 2).sum())))
    np.testing.assert_allclose(ours, brute, rtol=1e-5, atol=1e-8)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ideal_is_lower_bound(seed):
    """Theorem 1: Tr(Σ(q*)) ≤ Tr(Σ(q)) for any positive weighting q."""
    rng = np.random.default_rng(seed)
    N = 48
    g = rng.uniform(0.0, 5.0, size=N).astype(np.float64)
    ideal = float(var.trace_sigma_ideal(jnp.asarray(g)))
    unif = float(var.trace_sigma_unif(jnp.asarray(g)))
    assert ideal <= unif + 1e-9
    for _ in range(5):
        w = rng.uniform(0.05, 10.0, size=N)
        other = float(var.trace_sigma(jnp.asarray(g), jnp.asarray(w)))
        assert ideal <= other + 1e-7 * max(1.0, abs(other))


# ------------------------------------------------------------- weight store
# ------------------------------------------------------------------ sampler

"""Statistical test battery (marker: `stats`).

Pins the distributional claims the async PR leans on:

  * chi-squared goodness-of-fit of `two_stage_sample` against the target
    multinomial, across `axes=()` shard decompositions and real 2/4-device
    meshes (the two-stage draw must *be* the multinomial, not just close);
  * §4.1 unbiasedness: E[IS-scaled minibatch gradient] equals the
    full-batch gradient within CLT tolerance for the relaxed, fused, and
    async modes — including a deliberately skewed store, where the scales
    (mean ω̃ / ω̃_i) do the heavy lifting.

All tests use fixed seeds, so they are deterministic; the thresholds are
set at ≈4σ so a correct sampler passes with huge margin.  Deselect with
``-m "not stats"`` on flaky CPU runners — tier-1 keeps them by default.

Multi-device legs run in subprocesses (XLA device count is fixed at first
backend init).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import mesh_src, run_py as _run_py


def chi2_critical(df: int, z: float = 3.719) -> float:
    """Wilson–Hilferty upper-tail critical value; z=3.719 ≈ α = 1e-4."""
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * math.sqrt(a)) ** 3


def _target_weights(n: int) -> jnp.ndarray:
    """A lumpy but strictly positive target (spread ≈ 70×)."""
    w = (jnp.arange(n, dtype=jnp.float32) % 17) + 0.25
    return w.at[:: n // 8].mul(4.0)


@pytest.mark.stats
@pytest.mark.parametrize("shards", [1, 4, 8])
def test_two_stage_sample_chi2_gof(shards):
    """axes=(): the hierarchical draw matches the target multinomial under
    a chi-squared GOF test for every logical shard decomposition."""
    from repro.core.sampler import two_stage_sample

    n, m = 256, 200_000
    w = _target_weights(n)
    idx = np.asarray(two_stage_sample(jax.random.key(7), w, m,
                                      shards_per_device=shards))
    counts = np.bincount(idx, minlength=n)
    p = np.asarray(w / w.sum(), np.float64)
    expected = m * p
    assert expected.min() > 20          # chi-squared validity regime
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    crit = chi2_critical(n - 1)
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
@pytest.mark.mp
@pytest.mark.parametrize("dp,mp", [(1, 2), (2, 2)])
def test_model_parallel_proposal_chi2_matches_single_device(dp, mp):
    """ISSUE 4 (c): build the proposal with the model-axis-sharded scorer
    (partial per-example sq-norms psum'd over `model`) on a dp×mp mesh,
    then chi-squared-test draws from it against the SINGLE-DEVICE
    proposal distribution: the psum'd proposal must *be* the same
    multinomial, not just close."""
    from _helpers import run_mesh_py

    out = run_mesh_py("""
        import json
        import jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import (ISSGDConfig, init_train_state,
                                      make_train_step)
        from repro.core import distributed as D
        from repro.core.sampler import sample_indices
        from repro.core.scorer import make_mlp_scorer
        from repro.core.weight_store import read_proposal
        from repro.data import make_svhn_like
        from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                      mlp_specs, per_example_loss)
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
        train, _ = make_svhn_like(jax.random.key(2), n=256, dim=16,
                                  classes=4)
        params = init_mlp_classifier(jax.random.key(3), cfg)
        opt = sgd(0.0)   # freeze params: both runs score identical θ
        tcfg = ISSGDConfig(batch_size=16, score_batch_size=64,
                           mode="relaxed", is_cfg=ISConfig(smoothing=0.05),
                           score_shards=4)
        n = train.size
        MAXES = ('model',)
        pel1 = lambda p, b: per_example_loss(p, b, cfg)
        sc1 = make_mlp_scorer(cfg, 'ghost')
        pel = lambda p, b: per_example_loss(p, b, cfg, model_axes=MAXES)
        sc = make_mlp_scorer(cfg, 'ghost', model_axes=MAXES)

        step1 = jax.jit(make_train_step(pel1, sc1, opt, tcfg, n))
        stepm, _ = D.make_sharded_train_step(
            pel, sc, opt, tcfg, n, mesh, train.arrays,
            param_specs=mlp_specs(cfg), params_template=params)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=mlp_specs(cfg))
        dm = D.shard_dataset(train.arrays, mesh)
        for _ in range(4):   # 4 x 64 rows = the whole table scored
            s1, _ = step1(s1, train.arrays)
            sm, _ = stepm(sm, dm)

        p_ref = np.asarray(read_proposal(s1.store, 4, tcfg.is_cfg),
                           np.float64)
        p_ref /= p_ref.sum()
        w_mp = jnp.asarray(np.asarray(sm.store.weights))
        from repro.core.weight_store import WeightStore
        store_mp = WeightStore(
            weights=w_mp,
            scored_at=jnp.asarray(np.asarray(sm.store.scored_at)))
        prop_mp = read_proposal(store_mp, 4, tcfg.is_cfg)

        m_draws = 200_000
        idx = np.asarray(sample_indices(jax.random.key(11), prop_mp,
                                        m_draws, num_shards=4))
        counts = np.bincount(idx, minlength=n)
        expected = m_draws * p_ref
        assert expected.min() > 20
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        print(json.dumps(dict(chi2=chi2, df=n - 1)))
    """, dp=dp, mp=mp)
    import json
    rec = json.loads(out.strip().splitlines()[-1])
    crit = chi2_critical(rec["df"])
    assert rec["chi2"] < crit, f"chi2={rec['chi2']:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
@pytest.mark.mp
def test_transformer_mp_proposal_chi2_matches_single_device():
    """ISSUE 5: the transformer ghost proposal built on a 1×2 model-
    parallel mesh (head/ffn-sharded layers, partial per-example sq-norms
    psum'd over `model`) is the SAME multinomial as the single-device
    proposal — chi-squared GOF of draws from the mp proposal against the
    single-device distribution."""
    from _helpers import run_mesh_py

    out = run_mesh_py("""
        import json
        import jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import (ISSGDConfig, init_train_state,
                                      make_train_step)
        from repro.core import distributed as D
        from repro.core.sampler import sample_indices
        from repro.core.scorer import make_lm_scorer
        from repro.core.weight_store import WeightStore, read_proposal
        from repro.data import make_token_dataset
        from repro.models.config import ModelConfig
        from repro.models.transformer import (init_transformer,
                                              per_example_loss,
                                              transformer_specs)
        from repro.optim import sgd

        cfg = ModelConfig(name='t', arch_type='t', num_layers=2,
                          d_model=24, num_heads=4, num_kv_heads=2,
                          d_ff=48, vocab_size=64, dtype='float32',
                          remat=False)
        train = make_token_dataset(jax.random.key(0), n=256, seq=13,
                                   vocab=cfg.vocab_size)
        params = init_transformer(jax.random.key(1), cfg)
        opt = sgd(0.0)   # freeze params: both runs score identical θ
        tcfg = ISSGDConfig(batch_size=16, score_batch_size=64,
                           mode="relaxed", is_cfg=ISConfig(smoothing=0.05),
                           score_shards=4)
        n = train.size
        specs = transformer_specs(cfg)
        pel1 = lambda p, b: per_example_loss(p, cfg, b)[0]
        sc1 = make_lm_scorer(cfg, 'ghost')
        pel = lambda p, b: per_example_loss(p, cfg, b,
                                            model_axes=('model',))[0]
        sc = make_lm_scorer(cfg, 'ghost', model_axes=('model',))

        step1 = jax.jit(make_train_step(pel1, sc1, opt, tcfg, n))
        stepm, _ = D.make_sharded_train_step(
            pel, sc, opt, tcfg, n, mesh, train.arrays,
            param_specs=specs, params_template=params)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(train.arrays, mesh)
        for _ in range(4):   # 4 x 64 rows = the whole table scored
            s1, _ = step1(s1, train.arrays)
            sm, _ = stepm(sm, dm)

        p_ref = np.asarray(read_proposal(s1.store, 4, tcfg.is_cfg),
                           np.float64)
        p_ref /= p_ref.sum()
        store_mp = WeightStore(
            weights=jnp.asarray(np.asarray(sm.store.weights)),
            scored_at=jnp.asarray(np.asarray(sm.store.scored_at)))
        prop_mp = read_proposal(store_mp, 4, tcfg.is_cfg)

        m_draws = 200_000
        idx = np.asarray(sample_indices(jax.random.key(11), prop_mp,
                                        m_draws, num_shards=4))
        counts = np.bincount(idx, minlength=n)
        expected = m_draws * p_ref
        assert expected.min() > 20
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        print(json.dumps(dict(chi2=chi2, df=n - 1)))
    """, dp=1, mp=2)
    import json
    rec = json.loads(out.strip().splitlines()[-1])
    crit = chi2_critical(rec["df"])
    assert rec["chi2"] < crit, f"chi2={rec['chi2']:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
def test_fused_scorer_proposal_chi2_matches_separate():
    """ISSUE 6: the proposal built from the ghost scorer with the FUSED
    `with_scores` attention kernels is the SAME multinomial as the
    separate-pass proposal (the two score paths are bitwise-equal, see
    test_kernels.py) — scored tables compared exactly, then chi-squared
    GOF of draws from the fused proposal against the separate-path
    distribution."""
    from repro.core.importance import ISConfig
    from repro.core.issgd import (ISSGDConfig, init_train_state,
                                  make_train_step)
    from repro.core.sampler import sample_indices
    from repro.core.scorer import make_lm_scorer
    from repro.core.weight_store import read_proposal
    from repro.data import make_token_dataset
    from repro.models.config import ModelConfig
    from repro.models.transformer import init_transformer, per_example_loss
    from repro.optim import sgd

    cfg = ModelConfig(name='t', arch_type='t', num_layers=2, d_model=24,
                      num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=64,
                      dtype='float32', remat=False)
    train = make_token_dataset(jax.random.key(0), n=256, seq=13,
                               vocab=cfg.vocab_size)
    params = init_transformer(jax.random.key(1), cfg)
    opt = sgd(0.0)   # freeze params: both runs score identical θ
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.05), score_shards=4)
    n = train.size
    pel = lambda p, b: per_example_loss(p, cfg, b, attn_impl="flash")[0]
    stores = {}
    for variant in ("fused", "separate"):
        sc = make_lm_scorer(cfg, "ghost", attn_impl="flash",
                            attn_scores=variant)
        step = jax.jit(make_train_step(pel, sc, opt, tcfg, n))
        st = init_train_state(params, opt, n)
        for _ in range(4):   # 4 x 64 rows = the whole table scored
            st, _ = step(st, train.arrays)
        stores[variant] = st.store
    np.testing.assert_array_equal(
        np.asarray(stores["fused"].weights),
        np.asarray(stores["separate"].weights))

    p_sep = np.asarray(read_proposal(stores["separate"], 4, tcfg.is_cfg),
                       np.float64)
    p_sep /= p_sep.sum()
    prop_f = read_proposal(stores["fused"], 4, tcfg.is_cfg)
    m_draws = 200_000
    idx = np.asarray(sample_indices(jax.random.key(11), prop_f, m_draws,
                                    num_shards=4))
    counts = np.bincount(idx, minlength=n)
    expected = m_draws * p_sep
    assert expected.min() > 20
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    crit = chi2_critical(n - 1)
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
@pytest.mark.parametrize("devices,score_shards", [(2, 4), (4, 8)])
def test_two_stage_sample_chi2_gof_sharded(devices, score_shards):
    """The same GOF battery with the table sharded over a real 2/4-device
    mesh and the draw running under shard_map."""
    out = _run_py(f"""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.sampler import two_stage_sample
        from repro.dist import shard_map

        ND, W = {devices}, {score_shards}
        n, m_batch, n_batches = 256, 50_000, 4
        w = (jnp.arange(n, dtype=jnp.float32) % 17) + 0.25
        w = w.at[:: n // 8].mul(4.0)
        {mesh_src(devices)}
        w_sharded = jax.device_put(w, NamedSharding(mesh, P('data')))

        def body(key, local_w):
            return two_stage_sample(key, local_w, m_batch, axes=('data',),
                                    shards_per_device=W // ND)

        draw = jax.jit(shard_map(body, mesh=mesh,
                                 in_specs=(P(), P('data')), out_specs=P()))
        counts = np.zeros(n, np.int64)
        for i in range(n_batches):
            idx = np.asarray(draw(jax.random.key(100 + i), w_sharded))
            counts += np.bincount(idx, minlength=n)
        m = m_batch * n_batches
        p = np.asarray(w / w.sum(), np.float64)
        chi2 = float(((counts - m * p) ** 2 / (m * p)).sum())
        print(json.dumps(dict(chi2=chi2, df=n - 1)))
    """, devices=devices)
    import json
    rec = json.loads(out.strip().splitlines()[-1])
    crit = chi2_critical(rec["df"])
    assert rec["chi2"] < crit, f"chi2={rec['chi2']:.1f} >= crit={crit:.1f}"


# ---------------------------------------------------------------------------
# §4.1 unbiasedness: E[IS-scaled minibatch grad] == full-batch grad
# ---------------------------------------------------------------------------

def _unbias_setup():
    from repro.core.importance import ISConfig
    from repro.core.issgd import ISSGDConfig
    from repro.core.scorer import make_mlp_scorer
    from repro.core.weight_store import WeightStore
    from repro.data import make_svhn_like
    from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                  per_example_loss, per_example_loss_and_score)
    from repro.optim import sgd

    n = 256
    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(2), n=n, dim=8, classes=4)
    params = init_mlp_classifier(jax.random.key(3), cfg)
    opt = sgd(1.0)  # lr=1 → grad estimate = params - new_params, exactly
    tcfg = ISSGDConfig(batch_size=32, score_batch_size=64, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.05), score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    fused = lambda p, b: per_example_loss_and_score(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")

    # deliberately skewed store: 40× spread, everything freshly stamped
    skew = (jnp.arange(n, dtype=jnp.float32) * 37.0 % 97.0) / 97.0
    skewed_store = WeightStore(weights=0.1 + 4.0 * skew ** 3,
                               scored_at=jnp.zeros((n,), jnp.int32))

    flat = lambda tree: np.concatenate(
        [np.asarray(x).ravel() for x in jax.tree.leaves(tree)])
    full_grad = flat(jax.grad(
        lambda p: jnp.mean(per_example_loss(p, train.arrays, cfg)))(params))
    return (train, params, opt, tcfg, pel, fused, scorer, skewed_store,
            flat, full_grad)


def _assert_clt_close(grads: np.ndarray, full_grad: np.ndarray):
    """Componentwise z-test: |mean − truth| ≤ 4·SEM (+ float atol)."""
    mean = grads.mean(axis=0)
    sem = grads.std(axis=0) / math.sqrt(grads.shape[0])
    err = np.abs(mean - full_grad)
    bound = 4.0 * sem + 1e-6
    worst = np.argmax(err - bound)
    assert np.all(err <= bound), (
        f"component {worst}: |{mean[worst]:.5f} - {full_grad[worst]:.5f}| "
        f"> 4*sem={4 * sem[worst]:.5f}")


@pytest.mark.stats
@pytest.mark.parametrize("mode", ["relaxed", "fused", "async"])
def test_is_gradient_unbiased_clt(mode):
    from repro.core.issgd import TrainState, make_train_step
    import dataclasses

    (train, params, opt, tcfg, pel, fused, scorer, skewed_store, flat,
     full_grad) = _unbias_setup()
    data, n, trials = train.arrays, train.size, 300
    opt_state = opt.init(params)

    if mode == "async":
        from repro.core.async_pipeline import make_async_pipeline
        from repro.core.weight_store import to_buffered
        pipe = make_async_pipeline(pel, scorer, opt, tcfg, n, swap_every=1)
        def one_trial(r):
            state = TrainState(params, opt_state, params,
                               to_buffered(skewed_store),
                               jnp.zeros((), jnp.int32),
                               jax.random.key(1000 + r))
            new_state, _ = pipe.step(state, data)
            return flat(params) - flat(new_state.params)
    else:
        tcfg_m = dataclasses.replace(tcfg, mode=mode)
        step = jax.jit(make_train_step(
            pel, scorer, opt, tcfg_m, n,
            fused_score=fused if mode == "fused" else None))
        def one_trial(r):
            state = TrainState(params, opt_state, params, skewed_store,
                               jnp.zeros((), jnp.int32),
                               jax.random.key(1000 + r))
            new_state, _ = step(state, data)
            return flat(params) - flat(new_state.params)

    grads = np.stack([one_trial(r) for r in range(trials)])
    _assert_clt_close(grads, full_grad)


@pytest.mark.stats
def test_uniform_store_gives_unit_scales():
    """Sanity anchor for the battery: with a flat store the IS scales are
    exactly 1 (the paper's plain-SGD recovery)."""
    from repro.core.importance import ISConfig, is_loss_scale
    from repro.core.weight_store import init_store, read_proposal

    store = init_store(64)
    proposal = read_proposal(store, 0, ISConfig(smoothing=1.0))
    scales = is_loss_scale(proposal[:8], jnp.mean(proposal))
    np.testing.assert_array_equal(np.asarray(scales), np.ones(8, np.float32))


# ---------------------------------------------------------------------------
# Proposal strategy zoo (core/strategies.py)
# ---------------------------------------------------------------------------

def test_upper_bound_dominates_logit_grad():
    """Pinsker: ‖p − y‖₂ ≤ ‖p − y‖₁ ≤ sqrt(2·CE), so the forward-only
    upper_bound score dominates the logit_grad score elementwise — the
    provable-bound property the zoo docstring claims, checked exactly."""
    from repro.core.scorer import make_mlp_scorer
    from repro.core.strategies import make_proposal
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier

    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(2), n=256, dim=8, classes=4)
    params = init_mlp_classifier(jax.random.key(3), cfg)
    ub = np.asarray(make_proposal(make_mlp_scorer, cfg, "upper_bound")(
        params, train.arrays))
    lg = np.asarray(make_mlp_scorer(cfg, "logit_grad")(params, train.arrays))
    assert np.all(ub + 1e-5 >= lg), float((lg - ub).max())
    assert ub.shape == lg.shape == (train.size,)


@pytest.mark.stats
@pytest.mark.parametrize("strategy", ["upper_bound", "bandit_mixed"])
def test_zoo_proposal_chi2_gof(strategy):
    """The hierarchical draw from a store scored by the zoo strategies is
    the exact multinomial of the smoothed proposal — the sampler makes no
    assumption about where the weights came from."""
    from repro.core.importance import ISConfig
    from repro.core.issgd import (ISSGDConfig, init_train_state,
                                  make_train_step)
    from repro.core.sampler import sample_indices
    from repro.core.scorer import make_mlp_scorer
    from repro.core.strategies import make_proposal
    from repro.core.weight_store import read_proposal
    from repro.data import make_svhn_like
    from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                  per_example_loss)
    from repro.optim import sgd

    n = 256
    cfg = MLPConfig(input_dim=8, hidden=(16,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(2), n=n, dim=8, classes=4)
    params = init_mlp_classifier(jax.random.key(3), cfg)
    opt = sgd(0.0)   # freeze params: the scored table is deterministic
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                       is_cfg=ISConfig(smoothing=0.05), score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    scorer = make_proposal(make_mlp_scorer, cfg, strategy, mix=(0.3, 0.7))
    step = jax.jit(make_train_step(pel, scorer, opt, tcfg, n))
    st = init_train_state(params, opt, n)
    for _ in range(4):   # 4 x 64 rows = the whole table scored
        st, _ = step(st, train.arrays)

    prop = read_proposal(st.store, 4, tcfg.is_cfg)
    p = np.asarray(prop, np.float64)
    p /= p.sum()
    m_draws = 200_000
    idx = np.asarray(sample_indices(jax.random.key(11), prop, m_draws,
                                    num_shards=4))
    counts = np.bincount(idx, minlength=n)
    expected = m_draws * p
    assert expected.min() > 20          # chi-squared validity regime
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    crit = chi2_critical(n - 1)
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
def test_gated_switch_preserves_unbiasedness():
    """Mid-run uniform↔IS switches keep §4.1 unbiasedness: after a
    closed-gate (uniform) step, the open-gate IS step's gradient estimate
    is unbiased for the full-batch gradient at the post-switch params —
    the controller can flip the gate whenever it likes."""
    from repro.core.issgd import TrainState, make_train_step

    (train, params, opt, tcfg, pel, fused, scorer, skewed_store, flat,
     full_grad) = _unbias_setup()
    data, n, trials = train.arrays, train.size, 300
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(pel, scorer, opt, tcfg, n, gated=True))

    # one shared closed-gate (uniform) step with a fixed key: every trial
    # resumes from the same post-switch state, so the truth is fixed too
    s0 = TrainState(params, opt_state, params, skewed_store,
                    jnp.zeros((), jnp.int32), jax.random.key(7))
    s1, _ = step(s0, data, jnp.asarray(False))
    full_grad1 = flat(jax.grad(
        lambda p: jnp.mean(pel(p, data)))(s1.params))

    def one_trial(r):
        s2, _ = step(s1._replace(rng=jax.random.key(1000 + r)), data,
                     jnp.asarray(True))
        return flat(s1.params) - flat(s2.params)

    grads = np.stack([one_trial(r) for r in range(trials)])
    _assert_clt_close(grads, full_grad1)


# ---------------------------------------------------------------------------
# Quantized score tables (ISSUE 10): draws follow the quantized proposal,
# and its distance from the f32 proposal stays under the analytic bound
# ---------------------------------------------------------------------------

def _stores_by_dtype(n: int = 256, cs: int = 32):
    from repro.core.weight_store import WeightStore, quantize_weights

    w = _target_weights(n)
    zeros = jnp.zeros((n,), jnp.int32)
    f32 = WeightStore(weights=w, scored_at=zeros)
    bf16 = WeightStore(weights=w.astype(jnp.bfloat16), scored_at=zeros)
    codes, qscale = quantize_weights(w, cs)
    int8 = WeightStore(weights=codes, scored_at=zeros, qscale=qscale)
    return f32, {"bf16": bf16, "int8": int8}, cs


@pytest.mark.stats
@pytest.mark.massindex
@pytest.mark.parametrize("table_dtype", ["bf16", "int8"])
def test_quantized_table_draws_chi2_gof(table_dtype):
    """The two-stage draw from a bf16/int8 table IS the multinomial of
    the *quantized* proposal (reads dequantize, nothing else changes) —
    chi-squared GOF against the dequantized distribution."""
    from repro.core.importance import ISConfig
    from repro.core.sampler import sample_indices
    from repro.core.weight_store import read_proposal

    _, quantized, _ = _stores_by_dtype()
    cfg = ISConfig(smoothing=0.05)
    prop = read_proposal(quantized[table_dtype], 1, cfg)
    n, m = prop.shape[0], 200_000
    idx = np.asarray(sample_indices(jax.random.key(13), prop, m,
                                    num_shards=4))
    counts = np.bincount(idx, minlength=n)
    p = np.asarray(prop, np.float64)
    p /= p.sum()
    expected = m * p
    assert expected.min() > 20          # chi-squared validity regime
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    crit = chi2_critical(n - 1)
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


@pytest.mark.stats
@pytest.mark.massindex
@pytest.mark.parametrize("table_dtype", ["bf16", "int8"])
def test_quantized_proposal_tv_under_analytic_bound(table_dtype):
    """Measured TV(p_f32, p_quantized) ≤ quantization_tv_bound — the
    computed-and-asserted distortion guarantee of the quantized tables
    (and the bound itself is small enough to matter: < 2%)."""
    from repro.core.importance import ISConfig
    from repro.core.weight_store import quantization_tv_bound, read_proposal

    f32, quantized, cs = _stores_by_dtype()
    cfg = ISConfig(smoothing=0.05)
    p = np.asarray(read_proposal(f32, 1, cfg), np.float64)
    q = np.asarray(read_proposal(quantized[table_dtype], 1, cfg), np.float64)
    tv = 0.5 * np.abs(p / p.sum() - q / q.sum()).sum()
    bound = float(quantization_tv_bound(f32, 1, cfg, cs, table_dtype))
    assert tv <= bound, f"TV={tv:.3e} > bound={bound:.3e}"
    assert bound < 0.02, bound

"""Adaptive IS controller (core/controller.py) and the gated step contract.

Pins the PR's invariants:

  * gated=False is the identity path — HLO-byte-identical to a build
    that never heard of the controller;
  * a gated relaxed step with the gate closed is *bitwise* a plain
    uniform-mode run, and with the gate open bitwise the relaxed run
    (both draws come from the same key; the gate only selects);
  * the async pipeline under a never-opening controller is bitwise the
    uniform-mode pipeline;
  * every in-run decision is an exact pure fold over the JSONL event
    stream — replay_decisions over the file reproduces the run's
    decisions bit-for-bit;
  * the decision rules themselves (variance-ratio gate, ess-floor veto,
    hysteresis, swap cadence from the dispatch-time ratio);
  * the benchmark harness's timed loop performs exactly one host sync
    per recording step (the PR's benchmark-layer bugfix).
"""
import dataclasses
import inspect
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import REPO
from repro.core.controller import (ControllerConfig, ProposalController,
                                   replay_decisions)
from repro.core.importance import ISConfig
from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
from repro.core.scorer import make_mlp_scorer
from repro.data import make_svhn_like
from repro.models.mlp import MLPConfig, init_mlp_classifier, per_example_loss
from repro.optim import sgd
from repro.telemetry import EventSink, NullSink
from repro.telemetry.events import read_events


def _setup(mode="relaxed", n=256):
    cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=16, classes=4)
    params = init_mlp_classifier(jax.random.key(1), cfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode=mode,
                       is_cfg=ISConfig(smoothing=0.1), score_shards=4)
    pel = lambda p, b: per_example_loss(p, b, cfg)
    scorer = make_mlp_scorer(cfg, "ghost")
    return pel, scorer, opt, tcfg, params, train


def _bitwise_equal_states(a, b):
    a = a._replace(rng=jax.random.key_data(a.rng))
    b = b._replace(rng=jax.random.key_data(b.rng))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- identity

def test_gate_off_is_hlo_identical():
    """gated=False must not change a single HLO byte of the step."""
    pel, scorer, opt, tcfg, params, train = _setup()
    state = init_train_state(params, opt, train.size, seed=0)

    def lowered(**kw):
        step = make_train_step(pel, scorer, opt, tcfg, train.size, **kw)
        return jax.jit(step).lower(state, train.arrays).as_text()

    base = lowered()
    assert lowered(gated=False) == base


def test_gated_requires_relaxed():
    pel, scorer, opt, tcfg, params, train = _setup(mode="uniform")
    with pytest.raises(ValueError, match="relaxed"):
        make_train_step(pel, scorer, opt, tcfg, train.size, gated=True)


# ------------------------------------------------------- gate bitwise pins

@pytest.mark.parametrize("open_gate,ref_mode",
                         [(False, "uniform"), (True, "relaxed")])
def test_gate_matches_reference_mode_bitwise(open_gate, ref_mode):
    """Closed gate ≡ uniform mode, open gate ≡ relaxed mode — per step
    and in the final state, bit for bit."""
    pel, scorer, opt, tcfg, params, train = _setup()
    gstep = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size,
                                    gated=True))
    rcfg = dataclasses.replace(tcfg, mode=ref_mode)
    rstep = jax.jit(make_train_step(pel, scorer, opt, rcfg, train.size))
    gs = init_train_state(params, opt, train.size, seed=0)
    rs = init_train_state(params, opt, train.size, seed=0)
    gate = jnp.asarray(open_gate)
    for t in range(6):
        gs, gm = gstep(gs, train.arrays, gate)
        rs, rm = rstep(rs, train.arrays)
        assert np.array_equal(np.asarray(gm.sample_indices),
                              np.asarray(rm.sample_indices)), t
        assert float(gm.loss) == float(rm.loss), t
    _bitwise_equal_states(gs, rs)


def test_gate_flip_mid_run_tracks_reference():
    """Flipping the gate mid-run never recompiles and lands on the
    matching reference branch each step."""
    pel, scorer, opt, tcfg, params, train = _setup()
    gstep = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size,
                                    gated=True))
    ustep = jax.jit(make_train_step(
        pel, scorer, opt, dataclasses.replace(tcfg, mode="uniform"),
        train.size))
    rstep = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size))
    gs = init_train_state(params, opt, train.size, seed=0)
    rs = init_train_state(params, opt, train.size, seed=0)
    schedule = [False, False, True, False, True, True]
    for t, open_gate in enumerate(schedule):
        gs, gm = gstep(gs, train.arrays, jnp.asarray(open_gate))
        # the reference advances with whichever plain-mode step matches;
        # both read the same state, so the trajectories stay aligned
        rs, rm = (rstep if open_gate else ustep)(rs, train.arrays)
        assert np.array_equal(np.asarray(gm.sample_indices),
                              np.asarray(rm.sample_indices)), t
    _bitwise_equal_states(gs, rs)


def test_async_closed_gate_is_uniform_bitwise():
    """An async pipeline under a never-opening controller is bitwise the
    uniform-mode pipeline."""
    from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                           make_async_steps)
    pel, scorer, opt, tcfg, params, train = _setup()
    data, n = train.arrays, train.size

    gsteps = make_async_steps(pel, scorer, opt, tcfg, n, gated=True)
    with pytest.raises(ValueError, match="controller"):
        AsyncPipeline(*gsteps, swap_every=2)   # gated needs its gate owner
    ctl = ProposalController(ControllerConfig())      # gate starts closed
    gpipe = AsyncPipeline(*gsteps, swap_every=2, controller=ctl)
    ucfg = dataclasses.replace(tcfg, mode="uniform")
    upipe = AsyncPipeline(*make_async_steps(pel, scorer, opt, ucfg, n),
                          swap_every=2)
    ga, ua = (init_async_state(params, opt, n),
              init_async_state(params, opt, n))
    for t in range(6):
        ga, gm = gpipe.step(ga, data)
        ua, um = upipe.step(ua, data)
        assert float(gm.loss) == float(um.loss), t
    for x, y in zip(jax.tree.leaves(ga._replace(rng=jax.random.key_data(ga.rng))),
                    jax.tree.leaves(ua._replace(rng=jax.random.key_data(ua.rng)))):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ replay pins

def test_jsonl_replay_matches_in_run(tmp_path):
    """Decisions recomputed offline from the JSONL alone match the
    in-run decisions exactly (strict replay raises on any mismatch)."""
    pel, scorer, opt, tcfg, params, train = _setup()
    path = str(tmp_path / "events.jsonl")
    ctl = ProposalController(ControllerConfig(adapt_every=4))
    sink = ctl.attach(EventSink(path))
    step = jax.jit(make_train_step(pel, scorer, opt, tcfg, train.size,
                                   gated=True))
    st = init_train_state(params, opt, train.size, seed=0)
    for i in range(16):
        st, m = step(st, train.arrays, ctl.gate())
        if i % 2 == 0:
            vals = jax.device_get((m.loss, m.trace_stale, m.trace_unif,
                                   m.ess_frac))
            sink.emit("metrics", step=i, loss=float(vals[0]),
                      trace_stale=float(vals[1]),
                      trace_unif=float(vals[2]), ess_frac=float(vals[3]))
        ctl.maybe_decide(i)
    sink.close()
    assert len(ctl.decisions) == 4
    assert replay_decisions(read_events(path)) == ctl.decisions


def test_replay_strict_raises_on_tampered_stream(tmp_path):
    import json
    path = str(tmp_path / "events.jsonl")
    ctl = ProposalController(ControllerConfig(adapt_every=1))
    sink = ctl.attach(EventSink(path))
    sink.emit("metrics", step=0, trace_stale=1.0, trace_unif=2.0)
    ctl.maybe_decide(0)
    sink.close()
    recs = list(read_events(path))
    tampered = [dict(r, trace_unif=0.5) if r["kind"] == "metrics" else r
                for r in recs]
    with pytest.raises(ValueError, match="replay mismatch"):
        replay_decisions(tampered)
    assert replay_decisions(recs) == ctl.decisions   # untouched stream ok


# --------------------------------------------------------- decision rules

def test_gate_decision_rules():
    ctl = ProposalController(ControllerConfig(adapt_every=1))
    sink = ctl.attach(NullSink())
    assert bool(sink)       # the tap stays truthy over a NullSink
    d = ctl.maybe_decide(0)
    assert d.reason == "no-signal" and not d.use_is
    sink.emit("metrics", step=1, trace_stale=1.0, trace_unif=2.0,
              ess_frac=0.9)
    d = ctl.maybe_decide(1)
    assert d.use_is and d.reason == "is-pays" and d.var_ratio == 2.0
    sink.emit("metrics", step=2, trace_stale=2.0, trace_unif=1.0)
    d = ctl.maybe_decide(2)
    assert not d.use_is and d.reason == "uniform-pays"


def test_decision_cadence():
    ctl = ProposalController(ControllerConfig(adapt_every=4))
    ctl.attach(NullSink())
    assert [i for i in range(12) if ctl.maybe_decide(i)] == [3, 7, 11]


def test_ess_floor_vetoes_gate():
    ctl = ProposalController(ControllerConfig(adapt_every=1, ess_floor=0.5))
    sink = ctl.attach(NullSink())
    sink.emit("metrics", step=0, trace_stale=1.0, trace_unif=3.0,
              ess_frac=0.1)
    d = ctl.maybe_decide(0)
    assert not d.use_is and d.reason == "ess-floor"


def test_nonfinite_pairs_are_skipped():
    ctl = ProposalController(ControllerConfig(adapt_every=1))
    sink = ctl.attach(NullSink())
    sink.emit("metrics", step=0, trace_stale=float("nan"), trace_unif=2.0)
    sink.emit("metrics", step=0, trace_stale=0.0, trace_unif=2.0)
    d = ctl.maybe_decide(0)
    assert d.reason == "no-signal" and d.var_ratio is None


def test_hysteresis_delays_flip():
    ctl = ProposalController(ControllerConfig(adapt_every=1, hysteresis=2))
    sink = ctl.attach(NullSink())
    sink.emit("metrics", step=0, trace_stale=1.0, trace_unif=2.0)
    d = ctl.maybe_decide(0)
    assert not d.use_is and d.reason == "is-pays-pending"
    sink.emit("metrics", step=1, trace_stale=1.0, trace_unif=2.0)
    d = ctl.maybe_decide(1)
    assert d.use_is and d.reason == "is-pays"


def test_swap_cadence_from_dispatch_ratio(tmp_path):
    """K = clip(round(scoring/master dispatch-time ratio)) — and the
    cadence decisions replay exactly from the JSONL spans."""
    path = str(tmp_path / "spans.jsonl")
    ctl = ProposalController(ControllerConfig(adapt_every=1,
                                              adapt_swap=True),
                             swap_every=2)
    sink = ctl.attach(EventSink(path))
    for _ in range(4):
        sink.span("scoring.dispatch", 0.030, step=0)
        sink.span("master.dispatch", 0.010, step=0)
    d = ctl.maybe_decide(0)
    assert d.swap_every == 3
    assert d.dispatch_ratio == pytest.approx(3.0)
    for _ in range(2):                  # ratio 90 → clamped to swap_max
        sink.span("scoring.dispatch", 0.900, step=1)
        sink.span("master.dispatch", 0.010, step=1)
    d = ctl.maybe_decide(1)
    assert d.swap_every == 8
    sink.close()
    assert replay_decisions(read_events(path)) == ctl.decisions


def test_gate_is_cached_device_scalar():
    ctl = ProposalController(ControllerConfig())
    g0 = ctl.gate()
    assert g0 is ctl.gate()             # cached between decisions
    assert bool(np.asarray(g0)) is False
    ctl.use_is = True
    g1 = ctl.gate()
    assert g1 is not g0 and bool(np.asarray(g1)) is True


# ----------------------------------------------- benchmark-layer bugfixes

def test_benchmark_recording_steps_single_sync(monkeypatch):
    """run_training's timed loop performs exactly ONE host transfer per
    recording step — the per-metric float() syncs are gone."""
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    import benchmarks.common as bc

    assert "float(m." not in inspect.getsource(bc.run_training)

    cfg, train, test, params = bc.setup(0)
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(bc.jax, "device_get", counting)
    timings = {}
    st, hist, elapsed = bc.run_training(
        params, train, mode="relaxed", steps=7, lr=0.01, smoothing=1.0,
        strategy="loss", score_batch=128, record_every=3, timings=timings)
    assert len(calls) == 3              # recording steps 0, 3, 6 only
    assert len(hist) == 3
    assert timings["us_per_step"] > 0 and timings["compile_s"] > 0
    assert elapsed > 0

"""Billion-example sampling structures (ISSUE 10 battery, marker
`massindex`): the chunked mass index, quantized score tables, and TTL
decay — property-pinned.

Pins the tentpole's contracts:

  * index exactness — under arbitrary interleavings of
    ``write_scores_global`` / ``reserve_tail`` / ``mark_live``, the
    index's stage-1 chunk masses equal ``chunk_proposal_mass`` of the
    resulting proposal *exactly*, and ``refresh_chunks`` over the
    touched chunks is bitwise ``build_index`` from scratch (hypothesis
    properties);
  * draw exactness — the O(log C) tree descent resolves every uniform
    draw to the same chunk as ``searchsorted`` over the dense chunk CDF,
    and tree-mode (``block_sums`` from ``block_masses``) draws are
    *bitwise* the dense draws, on one device and on a 4-device mesh;
  * mode equivalence — ``index="tree"`` runs bitwise-identical to
    ``index="dense"`` across relaxed / fused / async / streamed, on a
    1×1 and a 2×2 mesh (subprocess battery);
  * the off path — the default config (dense / f32 / no TTL) lowers to
    byte-identical HLO with every new knob explicitly at its off value,
    and ``read_sampling_proposal`` with ``score_ttl=0`` is byte-identical
    to plain ``read_proposal``;
  * TTL decay — matches a brute-force numpy reference, preserves the
    floor and EMPTY semantics, and the PR 8 monitors observe the decayed
    proposal (ess) next to the undecayed scored_at lag (staleness);
  * the trailing-partial-chunk fix — ``chunk_proposal_mass`` zero-pads
    instead of raising, ``index_to_chunk`` routes tail rows to the last
    chunk, and the streaming plane's exact-multiple assumption
    (``ChunkedExampleStore.from_arrays``) stays pinned.

The quantized-table distributional legs (chi² GOF of draws against the
quantized proposal, measured TV under ``quantization_tv_bound``) live in
tests/test_sampler_stats.py with the rest of the stats battery.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import run_mesh_py

# CI installs hypothesis; where absent the two property tests degrade to
# fixed-seed sweeps of the same case functions instead of skipping the
# whole battery (the test_importance_core precedent).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.importance import ISConfig
from repro.core.issgd import (ISSGDConfig, init_train_state, make_train_step,
                              read_sampling_proposal)
from repro.core.mass_index import (block_masses, build_index, chunk_masses,
                                   indexed_sample, refresh_chunks,
                                   sample_chunks, total_mass)
from repro.core.sampler import (chunk_proposal_mass, index_to_chunk,
                                two_stage_sample)
from repro.core.weight_store import (EMPTY, decay_proposal, init_store,
                                     mark_live, read_proposal, reserve_tail,
                                     write_scores_global)

pytestmark = pytest.mark.massindex


def _setup_step(n=256, **cfg_kw):
    from repro.core.scorer import make_mlp_scorer
    from repro.data import make_svhn_like
    from repro.models.mlp import MLPConfig, init_mlp_classifier, \
        per_example_loss
    from repro.optim import sgd

    mcfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
    train, _ = make_svhn_like(jax.random.key(0), n=n, dim=16, classes=4)
    params = init_mlp_classifier(jax.random.key(1), mcfg)
    opt = sgd(0.05)
    tcfg = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                      is_cfg=ISConfig(smoothing=0.1), score_shards=4,
                      **cfg_kw)
    pel = lambda p, b: per_example_loss(p, b, mcfg)
    scorer = make_mlp_scorer(mcfg, "ghost")
    return pel, scorer, opt, tcfg, params, train


def _bitwise_equal_states(a, b):
    a = a._replace(rng=jax.random.key_data(a.rng))
    b = b._replace(rng=jax.random.key_data(b.rng))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- index exactness (property)

def _index_mass_case(seed, chunks, cs, ops):
    """Interleaved write_scores_global / reserve_tail / mark_live: the
    index's leaves equal chunk_proposal_mass of the proposal *exactly*,
    and refreshing only the chunks a final write touched is bitwise a
    from-scratch rebuild."""
    rng = np.random.default_rng(seed)
    n = chunks * cs - int(rng.integers(0, cs))       # allow a partial tail
    n = max(n, 2)
    cfg = ISConfig(smoothing=0.1)
    store = init_store(n)
    step = 0
    for _ in range(ops):
        op = rng.integers(0, 3)
        if op == 0:
            k = int(rng.integers(1, min(n, 8) + 1))
            idx = jnp.asarray(rng.choice(n, size=k, replace=False))
            vals = jnp.asarray(rng.uniform(0.1, 5.0, k), jnp.float32)
            store = write_scores_global(store, idx, vals, step=step)
        elif op == 1:
            store = reserve_tail(store, int(rng.integers(1, n + 1)))
        else:
            k = int(rng.integers(1, min(n, 8) + 1))
            store = mark_live(store, rng.choice(n, size=k, replace=False))
        step += 1

    prop0 = read_proposal(store, step, cfg)
    index0 = build_index(prop0, cs)
    dense = chunk_proposal_mass(prop0, cs)
    assert np.array_equal(np.asarray(index0.mass), np.asarray(dense))

    # one more write; refreshing only its chunks ≡ full rebuild, bitwise
    k = int(rng.integers(1, min(n, 8) + 1))
    idx = rng.choice(n, size=k, replace=False)
    store = write_scores_global(store, jnp.asarray(idx),
                                jnp.asarray(rng.uniform(0.1, 5.0, k),
                                            jnp.float32), step=step)
    prop1 = read_proposal(store, step, cfg)
    touched = np.unique(idx // cs)
    refreshed = refresh_chunks(index0, prop1, cs, jnp.asarray(touched))
    rebuilt = build_index(prop1, cs)
    assert np.array_equal(np.asarray(refreshed.mass),
                          np.asarray(rebuilt.mass))
    assert np.array_equal(np.asarray(refreshed.tree),
                          np.asarray(rebuilt.tree))


def _descend_case(seed, chunks, cs):
    """The O(log C) root-to-leaf descent resolves every draw to exactly
    the searchsorted chunk (integer masses: all sums exact in f32)."""
    rng = np.random.default_rng(seed)
    mass = rng.integers(0, 64, chunks).astype(np.float32)
    if mass.sum() == 0:
        mass[rng.integers(0, chunks)] = 1.0
    table = np.repeat(mass / cs, cs).astype(np.float32)
    # integer leaf masses: build the index from per-chunk masses directly
    from repro.core.mass_index import MassIndex, tree_from_masses
    index = MassIndex(mass=jnp.asarray(mass),
                      tree=tree_from_masses(jnp.asarray(mass)))
    total = float(np.asarray(total_mass(index)))
    u = jnp.asarray(rng.uniform(0.0, total, 128), jnp.float32)
    got = np.asarray(sample_chunks(index, u))
    ref = np.clip(np.searchsorted(np.cumsum(mass), np.asarray(u),
                                  side="right"), 0, chunks - 1)
    np.testing.assert_array_equal(got, ref)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.integers(2, 12),
           st.integers(1, 24), st.integers(1, 6))
    @settings(max_examples=25, deadline=None)
    def test_index_mass_exact_under_interleaved_store_ops(seed, chunks,
                                                          cs, ops):
        _index_mass_case(seed, chunks, cs, ops)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 40),
           st.integers(1, 16))
    @settings(max_examples=30, deadline=None)
    def test_descend_matches_searchsorted(seed, chunks, cs):
        _descend_case(seed, chunks, cs)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_index_mass_exact_under_interleaved_store_ops(seed):
        rng = np.random.default_rng(1000 + seed)
        _index_mass_case(seed, int(rng.integers(2, 13)),
                         int(rng.integers(1, 25)), int(rng.integers(1, 7)))

    @pytest.mark.parametrize("seed", range(12))
    def test_descend_matches_searchsorted(seed):
        rng = np.random.default_rng(2000 + seed)
        _descend_case(seed, int(rng.integers(1, 41)),
                      int(rng.integers(1, 17)))


def test_indexed_sample_matches_flat_multinomial():
    """The full index draw (descent + within-chunk stage-2) equals the
    flat searchsorted draw over the same integer table, row for row."""
    rng = np.random.default_rng(7)
    n, cs = 96, 8
    table = rng.integers(0, 9, n).astype(np.float32)
    table[rng.choice(n, 20, replace=False)] = 0.0      # dead rows
    index = build_index(jnp.asarray(table), cs)
    key = jax.random.key(3)
    idx = np.asarray(indexed_sample(key, jnp.asarray(table), index, cs, 512))
    total = float(np.asarray(total_mass(index)))
    u = np.asarray(jax.random.uniform(key, (512,), jnp.float32)) * total
    ref = np.searchsorted(np.cumsum(table), u, side="right")
    np.testing.assert_array_equal(idx, np.clip(ref, 0, n - 1))
    assert (table[idx] > 0).all()                      # support respected


def test_chunk_masses_matches_chunk_proposal_mass_bitwise():
    """chunk_masses IS the reduction chunk_proposal_mass performs —
    including on a trailing partial chunk."""
    w = jax.random.uniform(jax.random.key(0), (100,), jnp.float32)
    for cs in (1, 7, 10, 100, 128):
        assert np.array_equal(np.asarray(chunk_masses(w, cs)),
                              np.asarray(chunk_proposal_mass(w, cs))), cs


# ------------------------------------------------- draw bitwise equivalence

def test_tree_draws_bitwise_equal_dense_single_device():
    """Feeding block_masses back as block_sums reproduces the dense
    two-stage draws bit for bit, for every W decomposition."""
    w = jax.random.uniform(jax.random.key(5), (256,), jnp.float32) + 1e-3
    for w_loc in (1, 4, 8, 16):
        for s in range(3):
            key = jax.random.key(100 + s)
            dense = two_stage_sample(key, w, 64, shards_per_device=w_loc)
            tree = two_stage_sample(key, w, 64, shards_per_device=w_loc,
                                    block_sums=block_masses(w, w_loc))
            assert np.array_equal(np.asarray(dense), np.asarray(tree)), \
                (w_loc, s)
    with pytest.raises(ValueError, match="block_sums"):
        two_stage_sample(jax.random.key(0), w, 8, shards_per_device=4,
                         block_sums=jnp.ones((3,)))


def test_tree_draws_bitwise_equal_dense_mesh4():
    """Same pin under shard_map on a 4-device mesh: the externally
    maintained stage-1 masses reproduce the sharded draws bitwise."""
    out = run_mesh_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.mass_index import block_masses
        from repro.core.sampler import two_stage_sample
        from repro.dist import shard_map

        w = jax.random.uniform(jax.random.key(5), (256,), jnp.float32) + 1e-3
        key = jax.random.key(9)

        def body(use_tree):
            def f(key, wl):
                bs = block_masses(wl, 2) if use_tree else None
                return two_stage_sample(key, wl, 64, axes=('data',),
                                        shards_per_device=2, block_sums=bs)
            return shard_map(f, mesh=mesh, in_specs=(P(), P('data')),
                             out_specs=P())

        dense = np.asarray(body(False)(key, w))
        tree = np.asarray(body(True)(key, w))
        assert np.array_equal(dense, tree)
        print('mesh4 bitwise ok')
    """, dp=4)
    assert "mesh4 bitwise ok" in out


@pytest.mark.parametrize("dp,mp", [(1, 1), (2, 2)])
def test_tree_mode_bitwise_equals_dense_all_modes(dp, mp):
    """index="tree" ≡ index="dense" — same sampled indices, losses, and
    final state bit for bit — across relaxed / fused / async / streamed,
    through the production sharded builders."""
    out = run_mesh_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state
        from repro.core import distributed as D
        from repro.core.async_pipeline import AsyncPipeline, init_async_state
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like
        from repro.models.mlp import (MLPConfig, init_mlp_classifier,
                                      per_example_loss,
                                      per_example_loss_and_score)
        from repro.optim import sgd

        from repro.models.mlp import mlp_specs

        cfg = MLPConfig(input_dim=16, hidden=(32,), num_classes=4)
        train, _ = make_svhn_like(jax.random.key(0), n=256, dim=16, classes=4)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        n = train.size
        data_host = train.arrays
        dense = ISSGDConfig(batch_size=16, score_batch_size=64,
                            mode="relaxed", is_cfg=ISConfig(smoothing=0.1),
                            score_shards=4)
        tree = dataclasses.replace(dense, index="tree")
        MAXES = ('model',) if MP > 1 else ()
        specs = mlp_specs(cfg)
        PK = dict(param_specs=specs, params_template=params)
        pel = lambda p, b: per_example_loss(p, b, cfg, model_axes=MAXES)
        sc = make_mlp_scorer(cfg, 'ghost', model_axes=MAXES)
        fs = lambda p, b: per_example_loss_and_score(p, b, cfg,
                                                     model_axes=MAXES)

        def bitwise(a, b, tag):
            a = a._replace(rng=jax.random.key_data(a.rng))
            b = b._replace(rng=jax.random.key_data(b.rng))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                assert np.array_equal(np.asarray(x), np.asarray(y)), tag

        dm = D.shard_dataset(data_host, mesh)

        for mode in ('relaxed', 'fused'):
            states = {}
            for name, tc in (('dense', dense), ('tree', tree)):
                tc = dataclasses.replace(tc, mode=mode)
                fk = dict(fused_score=fs) if mode == 'fused' else {}
                step, _ = D.make_sharded_train_step(pel, sc, opt, tc, n,
                                                    mesh, data_host, **fk,
                                                    **PK)
                step = jax.jit(step)
                s = D.shard_train_state(init_train_state(params, opt, n),
                                        mesh, param_specs=specs)
                for i in range(6):
                    s, m = step(s, dm)
                    states.setdefault(name, []).append(
                        np.asarray(m.sample_indices))
                states[name + '_final'] = s
            for a, b in zip(states['dense'], states['tree']):
                assert np.array_equal(a, b), mode
            bitwise(states['dense_final'], states['tree_final'], mode)
            print(mode, 'ok')

        # ---- async (swap cadence 2) ----
        finals = {}
        for name, tc in (('dense', dense), ('tree', tree)):
            s_step, m_step, _ = D.make_sharded_async_steps(
                pel, sc, opt, tc, n, mesh, data_host, **PK)
            pipe = AsyncPipeline(s_step, m_step, swap_every=2)
            a = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                    param_specs=specs)
            for i in range(6):
                a, m = pipe.step(a, dm)
            finals[name] = (a, np.asarray(m.sample_indices))
        assert np.array_equal(finals['dense'][1], finals['tree'][1])
        bitwise(finals['dense'][0], finals['tree'][0], 'async')
        print('async ok')

        # ---- streamed ----
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 64)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        finals = {}
        for name, tc in (('dense', dense), ('tree', tree)):
            plane = StreamingDataPlane(store, 2, mesh=mesh)
            ss, smp, ms, _ = D.make_sharded_streamed_steps(
                pel, sc, opt, tc, n, mesh, template, chunk_size=64, **PK)
            sp = StreamedISSGD(plane, ss, smp, ms, tc, n)
            s = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                    param_specs=specs)
            for i in range(6):
                s, m = sp.step(s)
            finals[name] = (s, np.asarray(m.sample_indices))
        assert np.array_equal(finals['dense'][1], finals['tree'][1])
        bitwise(finals['dense'][0], finals['tree'][0], 'streamed')
        print('streamed ok')
    """, dp=dp, mp=mp)
    for tag in ("relaxed ok", "fused ok", "async ok", "streamed ok"):
        assert tag in out, out[-1000:]


# ----------------------------------------------------------- the off path

def test_default_cfg_is_hlo_identical_to_explicit_off():
    """The default step must not contain one HLO byte of the new
    machinery: explicit off values (dense / f32 / ttl 0) lower to the
    same text as a config that never names them."""
    pel, scorer, opt, tcfg, params, train = _setup_step()
    state = init_train_state(params, opt, train.size, seed=0)

    def lowered(tc):
        step = make_train_step(pel, scorer, opt, tc, train.size)
        return jax.jit(step).lower(state, train.arrays).as_text()

    base = lowered(tcfg)
    off = dataclasses.replace(tcfg, index="dense", table_dtype="f32",
                              score_ttl=0, index_chunk_size=0)
    assert lowered(off) == base


def test_score_ttl_zero_reads_hlo_identical_to_plain_proposal():
    """read_sampling_proposal with score_ttl=0 is byte-identical HLO to
    read_proposal — the decay path adds nothing when disabled."""
    cfg = ISSGDConfig(score_ttl=0)
    store = init_store(64)
    on = jax.jit(lambda s: read_sampling_proposal(s, 5, cfg, 16)).lower(
        store).as_text()
    ref = jax.jit(lambda s: read_proposal(s, 5, cfg.is_cfg)).lower(
        store).as_text()
    assert on == ref


# ------------------------------------------------------------------ TTL decay

def test_decay_matches_bruteforce_reference():
    """decay_proposal == per-row numpy reference of the documented rule
    q' = u + 2^(-age_c/ttl)·(q - u)."""
    rng = np.random.default_rng(11)
    n, cs, step, ttl = 50, 8, 20, 4
    cfg = ISConfig(smoothing=0.1)
    prop = rng.uniform(0.1, 3.0, n).astype(np.float32)
    scored = rng.integers(-1, step, n).astype(np.int32)
    scored[rng.choice(n, 8, replace=False)] = EMPTY
    got = np.asarray(decay_proposal(jnp.asarray(prop), jnp.asarray(scored),
                                    step, ttl, cfg, cs))
    u = max(cfg.smoothing, cfg.floor)
    chunks = -(-n // cs)
    ref = np.empty_like(prop)
    for c in range(chunks):
        rows = slice(c * cs, min((c + 1) * cs, n))
        fresh = scored[rows].max()
        age = max(step - fresh, 0) if fresh >= 0 else 0
        d = np.float32(2.0 ** (-age / ttl))
        ref[rows] = np.float32(u) + d * (prop[rows] - np.float32(u))
    ref[scored <= EMPTY] = 0.0
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-7)
    # support survives: every live row keeps q' ≥ min(q, floor) > 0
    live = scored > EMPTY
    assert (got[live] >= np.minimum(prop[live], cfg.floor) - 1e-7).all()
    with pytest.raises(ValueError, match="ttl"):
        decay_proposal(jnp.asarray(prop), jnp.asarray(scored), step, 0,
                       cfg, cs)


def test_ttl_decay_changes_draws_but_not_support():
    """A decayed proposal flattens toward uniform (ESS grows) without
    ever resurrecting EMPTY rows."""
    cfg = ISConfig(smoothing=0.1)
    n, cs = 64, 8
    store = init_store(n)
    store = write_scores_global(store, jnp.arange(8),
                                jnp.full((8,), 50.0), step=0)
    store = reserve_tail(store, 48)
    prop = read_proposal(store, 40, cfg)
    dec = decay_proposal(prop, store.scored_at, 40, 4, cfg, cs)
    ess = lambda q: float(jnp.square(jnp.sum(q)) / jnp.sum(jnp.square(q)))
    assert ess(dec) > ess(prop)
    assert np.all(np.asarray(dec)[48:] == 0.0)


def test_monitors_observe_decayed_proposal():
    """With score_ttl on, the ess monitor is computed from the decayed
    proposal the sampler actually draws from, while staleness still
    reads the raw scored_at lag (PR 8 consistency)."""
    from repro.telemetry import MonitorSet

    pel, scorer, opt, tcfg, params, train = _setup_step(
        score_ttl=4, index_chunk_size=32)
    step = jax.jit(make_train_step(
        pel, scorer, opt, tcfg, train.size,
        monitors=MonitorSet(("ess", "staleness"))))
    state = init_train_state(params, opt, train.size, seed=0)
    for _ in range(3):
        state, _, mon = step(state, train.arrays)
    # the sync step's master reads the store AFTER its own scoring writes
    # (lag 0), at the pre-increment step counter — recompute from there
    prev = state
    state, _, mon = step(prev, train.arrays)
    prop = read_sampling_proposal(state.store, prev.step, tcfg, 64)
    n = train.size
    ess_ref = float(jnp.square(jnp.sum(prop)) / jnp.sum(jnp.square(prop)) / n)
    np.testing.assert_allclose(float(mon["ess"]), ess_ref, rtol=1e-6)
    stale_ref = int(prev.step) - int(jnp.max(state.store.scored_at))
    assert int(mon["staleness"]) == stale_ref


# -------------------------------------------- trailing-partial-chunk fixes

def test_chunk_proposal_mass_partial_tail():
    """The fix: a trailing partial chunk contributes exactly its partial
    mass instead of raising."""
    w = jnp.arange(10, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(chunk_proposal_mass(w, 4)),
                               [6.0, 22.0, 17.0])
    np.testing.assert_allclose(np.asarray(chunk_masses(w, 4)),
                               [6.0, 22.0, 17.0])


def test_index_to_chunk_routes_tail_rows():
    c, o = index_to_chunk(np.asarray([0, 3, 8, 9]), 4)
    np.testing.assert_array_equal(c, [0, 0, 2, 2])
    np.testing.assert_array_equal(o, [0, 3, 0, 1])


def test_streaming_plane_still_requires_exact_multiples():
    """The host store's fixed-size chunks are a separate, pinned
    assumption: from_arrays rejects a non-dividing chunk_size (the
    padding fix lives in the mass arithmetic, not the data plane)."""
    from repro.data.store import ChunkedExampleStore
    arrays = {"x": np.zeros((10, 2), np.float32)}
    with pytest.raises(ValueError, match="divide"):
        ChunkedExampleStore.from_arrays(arrays, 4)
    ChunkedExampleStore.from_arrays(arrays, 5)          # exact: fine

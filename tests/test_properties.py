"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # CI installs it; skip cleanly where absent
from hypothesis import given, settings, strategies as st

from repro.core.importance import ISConfig, is_loss_scale, smooth_weights
from repro.core.sampler import sample_indices
from repro.core.variance import trace_sigma, trace_sigma_ideal
from repro.core.weight_store import (init_store, read_proposal, write_scores)


# ----------------------------------------------------------------- sampler
@given(st.integers(0, 2**31 - 1), st.integers(8, 200), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_sampler_indices_in_range(seed, n, m):
    w = jax.random.uniform(jax.random.key(seed), (n,)) + 1e-3
    idx = np.asarray(sample_indices(jax.random.key(seed + 1), w, m))
    assert idx.shape == (m,)
    assert (idx >= 0).all() and (idx < n).all()


@given(st.integers(0, 2**31 - 1), st.integers(4, 64))
@settings(max_examples=25, deadline=None)
def test_sampler_respects_support(seed, n):
    """Zero-weight examples are never drawn — q > 0 only where w > 0."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, n)
    dead = rng.choice(n, size=max(1, n // 3), replace=False)
    w[dead] = 0.0
    idx = np.asarray(sample_indices(jax.random.key(seed), jnp.asarray(w),
                                    512))
    assert not np.isin(idx, dead).any()


# ------------------------------------------------------------- weight store
@given(st.integers(0, 2**31 - 1), st.integers(8, 64), st.integers(1, 20))
@settings(max_examples=30, deadline=None)
def test_store_write_read_consistency(seed, n, k):
    """A write is visible exactly at the written indices; everything else
    keeps the neutral/previous value."""
    rng = np.random.default_rng(seed)
    store = init_store(n)
    idx = jnp.asarray(rng.choice(n, size=min(k, n), replace=False))
    vals = jnp.asarray(rng.uniform(0.5, 5.0, size=len(idx)), dtype=jnp.float32)
    store = write_scores(store, idx, vals, step=3)
    cfg = ISConfig(smoothing=0.0, floor=1e-8)
    prop = np.asarray(read_proposal(store, step=4, cfg=cfg))
    np.testing.assert_allclose(prop[np.asarray(idx)], np.asarray(vals),
                               rtol=1e-6)
    others = np.setdiff1d(np.arange(n), np.asarray(idx))
    if len(others):
        np.testing.assert_allclose(prop[others], cfg.floor)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_scale_times_probability_is_constant(seed):
    """ω_n · scale_n = mean(ω̃)/N · N — the IS identity that guarantees
    unbiasedness: E_q[scale · f] = Σ q_n · scale_n · f_n = mean over n."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0.1, 10.0, 32), dtype=jnp.float32)
    q = w / jnp.sum(w)
    scale = is_loss_scale(w, jnp.mean(w))
    prod = np.asarray(q * scale)
    np.testing.assert_allclose(prod, np.full(32, 1 / 32), rtol=1e-5)


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 100.0))
@settings(max_examples=30, deadline=None)
def test_smoothing_interpolates_variance_monotonically(seed, c):
    """Tr(Σ) under smoothed weights lies between ideal and uniform and
    moves toward uniform as c grows."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.uniform(0.1, 5.0, 48), dtype=jnp.float32)
    cfg0 = ISConfig(smoothing=c)
    cfg1 = ISConfig(smoothing=c + 10.0)
    t0 = float(trace_sigma(g, smooth_weights(g, cfg0)))
    t1 = float(trace_sigma(g, smooth_weights(g, cfg1)))
    ideal = float(trace_sigma_ideal(g))
    unif = float(trace_sigma(g, jnp.ones_like(g)))
    assert ideal - 1e-5 <= t0 <= unif + 1e-5
    assert t0 <= t1 + 1e-5 <= unif + 1e-4 * max(1, abs(unif))


# ---------------------------------------------------------------- ghost ops
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 24),
       st.integers(2, 24))
@settings(max_examples=20, deadline=None)
def test_ghost_norm_nonnegative_and_scale_quadratic(seed, b, s, d):
    """||X^T D||²_F ≥ 0 and scales quartically under joint scaling."""
    from repro.kernels.ref import ghost_norm_ref
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.random.normal(ks[0], (b, s, d))
    dd = jax.random.normal(ks[1], (b, s, d))
    g1 = np.asarray(ghost_norm_ref(x, dd))
    assert (g1 >= -1e-6).all()
    g2 = np.asarray(ghost_norm_ref(2.0 * x, 2.0 * dd))
    np.testing.assert_allclose(g2, 16.0 * g1, rtol=1e-4)

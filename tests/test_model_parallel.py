"""Model-parallel params in the ISSGD step (ISSUE 4 battery, marker `mp`).

Pins the tentpole's three claims:

  (a) dp×mp ≡ dp-only same-seed equivalence — identical sampled indices,
      losses/params equal to float tolerance — on meshes 1×2, 2×2, 4×1
      for every execution mode (relaxed / fused / async / streamed);
  (b) the HLO gate: with model > 1 no scoring or master program contains
      a full-parameter-sized tensor or an all-gather whose output is
      parameter-shaped — params stay column shards end to end, mirroring
      the no-full-table gate for the f32[N] weight table;
  (c) the model-axis psum'd proposal equals the single-device proposal
      (the scorer's partial per-example sq-norms reduce to the exact
      grad norms — chi-squared distributional leg in
      tests/test_sampler_stats.py).

Multi-device tests run in subprocesses because the XLA host-device count
is fixed at first jax init (the main pytest process keeps 1 device).
"""
import pytest

from _helpers import dp_mp_grid, run_mesh_py

pytestmark = pytest.mark.mp

# MLP dims chosen so no activation shape collides with a full parameter
# shape (batch dims 16/64 vs param dims 24/48/10): the HLO gate can grep
# for the full 2-D weight shapes without false positives.
_SETUP = """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
        from repro.core import distributed as D
        from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                               make_async_steps)
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like
        from repro.models.mlp import (MLPConfig, init_mlp_classifier, mlp_specs,
                                      per_example_loss,
                                      per_example_loss_and_score)
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=24, hidden=(48,), num_classes=10)
        train, _ = make_svhn_like(jax.random.key(0), n=512, dim=24)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        specs = mlp_specs(cfg)
        base = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        n = train.size
        data_host = train.arrays
        MAXES = ('model',) if MP > 1 else ()

        # the dp-only reference: the single-device axes=() step
        pel1 = lambda p, b: per_example_loss(p, b, cfg)
        sc1 = make_mlp_scorer(cfg, 'ghost')
        fs1 = lambda p, b: per_example_loss_and_score(p, b, cfg)
        # the dp×mp run under test: model-axis-aware loss/scorer closures
        pel = lambda p, b: per_example_loss(p, b, cfg, model_axes=MAXES)
        sc = make_mlp_scorer(cfg, 'ghost', model_axes=MAXES)
        fs = lambda p, b: per_example_loss_and_score(p, b, cfg, model_axes=MAXES)
        PK = dict(param_specs=specs, params_template=params)

        def check(m1, m, tag):
            assert np.array_equal(np.asarray(m1.sample_indices),
                                  np.asarray(m.sample_indices)), tag
            np.testing.assert_allclose(float(m1.loss), float(m.loss),
                                       rtol=1e-5, atol=1e-6, err_msg=tag)
            np.testing.assert_allclose(float(m1.grad_norm), float(m.grad_norm),
                                       rtol=1e-4, atol=1e-6, err_msg=tag)

        def check_params(p1, p, tag):
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6, err_msg=tag)
"""


@dp_mp_grid
def test_dpmp_equivalent_to_dp_only_all_modes(dp, mp):
    """(a) the tentpole equivalence: one subprocess per mesh shape runs
    relaxed, fused, async (swap 2), and streamed against the same-seed
    single-device reference."""
    out = run_mesh_py(_SETUP + """
        # ---- relaxed + fused (the sync train step) ----
        for mode in ('relaxed', 'fused'):
            tc = dataclasses.replace(base, mode=mode)
            fk1 = dict(fused_score=fs1) if mode == 'fused' else {}
            fk = dict(fused_score=fs) if mode == 'fused' else {}
            step1 = jax.jit(make_train_step(pel1, sc1, opt, tc, n, **fk1))
            stepm, _ = D.make_sharded_train_step(
                pel, sc, opt, tc, n, mesh, data_host, **fk, **PK)
            stepm = jax.jit(stepm)
            s1 = init_train_state(params, opt, n)
            sm = D.shard_train_state(init_train_state(params, opt, n),
                                     mesh, param_specs=specs)
            dm = D.shard_dataset(data_host, mesh)
            for i in range(10):
                s1, m1 = step1(s1, data_host)
                sm, m = stepm(sm, dm)
                check(m1, m, f'{mode}/{i}')
            check_params(s1.params, sm.params, mode)
            print(mode, 'ok')

        # ---- async (swap cadence 2) ----
        s_step1, m_step1 = make_async_steps(pel1, sc1, opt, base, n)
        pipe1 = AsyncPipeline(s_step1, m_step1, swap_every=2)
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host, **PK)
        pipem = AsyncPipeline(s_step, m_step, swap_every=2)
        a1 = init_async_state(params, opt, n)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(8):
            a1, m1 = pipe1.step(a1, data_host)
            am, m = pipem.step(am, dm)
            check(m1, m, f'async/{i}')
        check_params(a1.params, am.params, 'async')
        print('async ok')

        # ---- streamed ----
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 64)
        plane = StreamingDataPlane(store, 2, mesh=mesh)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        ss, smp, ms, _ = D.make_sharded_streamed_steps(
            pel, sc, opt, base, n, mesh, template, chunk_size=64, **PK)
        sp = StreamedISSGD(plane, ss, smp, ms, base, n)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        s1 = init_train_state(params, opt, n)
        for i in range(8):
            s1, m1 = step1(s1, data_host)
            st, m = sp.step(st)
            check(m1, m, f'streamed/{i}')
        check_params(s1.params, st.params, 'streamed')
        print('streamed ok')
    """, dp=dp, mp=mp)
    for tag in ("relaxed ok", "fused ok", "async ok", "streamed ok"):
        assert tag in out, out[-1000:]


def test_params_stay_sharded_and_hlo_has_no_full_param_tensor():
    """(b) the HLO gate on a 2×2 mesh: the fused train step, the async
    scoring/master programs, and the streamed scoring/sample/master
    programs never materialize a full-parameter-sized tensor, and no
    all-gather output is parameter-shaped; the step's output params keep
    their model-axis shards."""
    out = run_mesh_py(_SETUP + """
        import re
        from jax.sharding import PartitionSpec as P

        # full 2-D weight shapes (fwd + transposed-grad orientation);
        # none may appear in any program once model > 1
        FULL = ['f32[24,48]', 'f32[48,24]', 'f32[48,10]', 'f32[10,48]']

        def gate(hlo, tag):
            for s in FULL:
                assert s not in hlo, f'{tag}: full param tensor {s}'
            for line in hlo.splitlines():
                if 'all-gather' not in line:
                    continue
                for s in FULL:
                    assert s not in line, f'{tag}: all-gather of params'

        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)

        # sync (relaxed) train step
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        jitted = jax.jit(stepm)
        new_state, _ = jitted(sm, dm)
        w = new_state.params['fc0']['w']
        assert 'model' in tuple(w.sharding.spec), w.sharding.spec
        shapes = {s.data.shape for s in w.addressable_shards}
        assert shapes == {(24, 24)}, shapes
        gate(jitted.lower(sm, dm).compile().as_text(), 'train')

        # async scoring + master
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host,
            monitor_traces=False, **PK)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        bs = am.store
        gate(jax.jit(s_step).lower(am.stale_params, bs.write_buf, am.step,
                                   dm).compile().as_text(), 'async scoring')
        gate(jax.jit(m_step).lower(am.params, am.opt_state, am.stale_params,
                                   bs.read_buf, am.step, am.rng,
                                   dm).compile().as_text(), 'async master')

        # streamed scoring / sample / master
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 64)
        plane = StreamingDataPlane(store, 2, mesh=mesh)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        ss, smp, ms, _ = D.make_sharded_streamed_steps(
            pel, sc, opt, base, n, mesh, template, chunk_size=64, **PK)
        sp = StreamedISSGD(plane, ss, smp, ms, base, n, jit=False)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        rows = plane.fetch_sharded(sp._score_indices(0))
        store_s, fresh, stale, _ = jax.jit(ss)(st.stale_params, st.store,
                                               st.step, rows)
        gate(jax.jit(ss).lower(st.stale_params, st.store, st.step,
                               rows).compile().as_text(), 'streamed scoring')
        gate(jax.jit(smp).lower(store_s, st.step,
                                st.rng).compile().as_text(), 'sample')
        idx, _ = jax.jit(smp)(store_s, st.step, st.rng)
        batch = plane.gather_global(np.asarray(idx))
        gate(jax.jit(ms).lower(st.params, st.opt_state, st.stale_params,
                               store_s, st.step, st.rng, batch, fresh,
                               stale).compile().as_text(), 'streamed master')
        print('hlo gates ok')
    """, dp=2, mp=2)
    assert "hlo gates ok" in out


@dp_mp_grid
def test_model_axis_proposal_matches_single_device(dp, mp):
    """(c) the psum'd proposal invariant: after identical scoring sweeps,
    the dp×mp store holds the same ω̃ table as the single-device run —
    the model-axis partial sq-norms reduce to the exact grad norms."""
    out = run_mesh_py(_SETUP + """
        from repro.core.weight_store import read_proposal

        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(8):    # 8 steps x 64 rows = the whole 512-row table
            s1, _ = step1(s1, data_host)
            sm, _ = stepm(sm, dm)
        w1 = np.asarray(s1.store.weights)
        wm = np.asarray(sm.store.weights)
        assert (np.asarray(s1.store.scored_at) >= 0).all()
        np.testing.assert_allclose(wm, w1, rtol=1e-4, atol=1e-6)
        p1 = np.asarray(read_proposal(s1.store, 8, base.is_cfg))
        pm = np.asarray(read_proposal(
            jax.tree.map(np.asarray, sm.store), 8, base.is_cfg))
        np.testing.assert_allclose(pm / pm.sum(), p1 / p1.sum(),
                                   rtol=1e-4, atol=1e-8)
        print('proposal exact')
    """, dp=dp, mp=mp)
    assert "proposal exact" in out


def test_grad_clip_uses_model_global_norm():
    """grad_clip under mp clips by the TRUE global norm (psum over model
    of partial square-sums), matching the single-device trajectory."""
    out = run_mesh_py(_SETUP + """
        tc = dataclasses.replace(base, grad_clip=0.05)
        step1 = jax.jit(make_train_step(pel1, sc1, opt, tc, n))
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, tc, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(6):
            s1, m1 = step1(s1, data_host)
            sm, m = stepm(sm, dm)
            check(m1, m, f'clip/{i}')
        check_params(s1.params, sm.params, 'clip')
        print('clip ok')
    """, dp=1, mp=2)
    assert "clip ok" in out


def test_checkpoint_roundtrip_sharded_params():
    """Sharded save (gather-free per-shard layout) → restore → re-place →
    the restored dp×mp run continues bitwise-equal to the uninterrupted
    one; the npz holds shard entries, never a full param array."""
    out = run_mesh_py(_SETUP + """
        import numpy as np, tempfile, os
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for _ in range(5):
            sm, _ = stepm(sm, dm)
        path = os.path.join(tempfile.mkdtemp(), 'ck.npz')
        save_checkpoint(path, sm, step=5, gather=False)

        with np.load(path) as z:
            keys = list(z.files)
        assert any('params/fc0/w::shard' in k for k in keys), keys[:10]
        assert not any(k == 'params/fc0/w' for k in keys)

        template = init_train_state(params, opt, n)
        restored, ck = restore_checkpoint(path, template)
        assert ck == 5
        rm = D.shard_train_state(restored, mesh, param_specs=specs)
        w = rm.params['fc0']['w']
        assert {s.data.shape for s in w.addressable_shards} == {(24, 24)}

        cont, _ = stepm(sm, dm)
        resd, _ = stepm(rm, dm)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(resd.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print('sharded checkpoint roundtrip ok')
    """, dp=2, mp=2)
    assert "sharded checkpoint roundtrip ok" in out


@pytest.mark.slow
def test_train_cli_smoke_mp():
    """End-to-end CLI gate: --model-parallel 2 --mesh 2 runs green with
    the devices forced by train.py itself."""
    import os
    import subprocess
    import sys

    from _helpers import REPO
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--mesh", "2", "--model-parallel", "2", "--steps", "8",
         "--examples", "1024"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "mesh: (2, 2)" in r.stdout, r.stdout[-1000:]

"""Model-parallel params in the ISSGD step (ISSUE 4 battery, marker `mp`)
plus the transformer-under-shard_map battery of ISSUE 5 (second half of
this file: every architecture family crosses the model axis with the
same dp×mp ≡ dp-only guarantee, sequence-parallel norm segments, and an
extended HLO gate).

Pins the tentpole's three claims:

  (a) dp×mp ≡ dp-only same-seed equivalence — identical sampled indices,
      losses/params equal to float tolerance — on meshes 1×2, 2×2, 4×1
      for every execution mode (relaxed / fused / async / streamed);
  (b) the HLO gate: with model > 1 no scoring or master program contains
      a full-parameter-sized tensor or an all-gather whose output is
      parameter-shaped — params stay column shards end to end, mirroring
      the no-full-table gate for the f32[N] weight table;
  (c) the model-axis psum'd proposal equals the single-device proposal
      (the scorer's partial per-example sq-norms reduce to the exact
      grad norms — chi-squared distributional leg in
      tests/test_sampler_stats.py).

Multi-device tests run in subprocesses because the XLA host-device count
is fixed at first jax init (the main pytest process keeps 1 device).
"""
import pytest

from _helpers import dp_mp_grid, run_mesh_py

pytestmark = pytest.mark.mp

# MLP dims chosen so no activation shape collides with a full parameter
# shape (batch dims 16/64 vs param dims 24/48/10): the HLO gate can grep
# for the full 2-D weight shapes without false positives.
_SETUP = """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
        from repro.core import distributed as D
        from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                               make_async_steps)
        from repro.core.scorer import make_mlp_scorer
        from repro.data import make_svhn_like
        from repro.models.mlp import (MLPConfig, init_mlp_classifier, mlp_specs,
                                      per_example_loss,
                                      per_example_loss_and_score)
        from repro.optim import sgd

        cfg = MLPConfig(input_dim=24, hidden=(48,), num_classes=10)
        train, _ = make_svhn_like(jax.random.key(0), n=512, dim=24)
        params = init_mlp_classifier(jax.random.key(1), cfg)
        opt = sgd(0.05)
        specs = mlp_specs(cfg)
        base = ISSGDConfig(batch_size=16, score_batch_size=64, mode="relaxed",
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        n = train.size
        data_host = train.arrays
        MAXES = ('model',) if MP > 1 else ()

        # the dp-only reference: the single-device axes=() step
        pel1 = lambda p, b: per_example_loss(p, b, cfg)
        sc1 = make_mlp_scorer(cfg, 'ghost')
        fs1 = lambda p, b: per_example_loss_and_score(p, b, cfg)
        # the dp×mp run under test: model-axis-aware loss/scorer closures
        pel = lambda p, b: per_example_loss(p, b, cfg, model_axes=MAXES)
        sc = make_mlp_scorer(cfg, 'ghost', model_axes=MAXES)
        fs = lambda p, b: per_example_loss_and_score(p, b, cfg, model_axes=MAXES)
        PK = dict(param_specs=specs, params_template=params)

        def check(m1, m, tag):
            assert np.array_equal(np.asarray(m1.sample_indices),
                                  np.asarray(m.sample_indices)), tag
            np.testing.assert_allclose(float(m1.loss), float(m.loss),
                                       rtol=1e-5, atol=1e-6, err_msg=tag)
            np.testing.assert_allclose(float(m1.grad_norm), float(m.grad_norm),
                                       rtol=1e-4, atol=1e-6, err_msg=tag)

        def check_params(p1, p, tag):
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6, err_msg=tag)
"""


@dp_mp_grid
def test_dpmp_equivalent_to_dp_only_all_modes(dp, mp):
    """(a) the tentpole equivalence: one subprocess per mesh shape runs
    relaxed, fused, async (swap 2), and streamed against the same-seed
    single-device reference."""
    out = run_mesh_py(_SETUP + """
        # ---- relaxed + fused (the sync train step) ----
        for mode in ('relaxed', 'fused'):
            tc = dataclasses.replace(base, mode=mode)
            fk1 = dict(fused_score=fs1) if mode == 'fused' else {}
            fk = dict(fused_score=fs) if mode == 'fused' else {}
            step1 = jax.jit(make_train_step(pel1, sc1, opt, tc, n, **fk1))
            stepm, _ = D.make_sharded_train_step(
                pel, sc, opt, tc, n, mesh, data_host, **fk, **PK)
            stepm = jax.jit(stepm)
            s1 = init_train_state(params, opt, n)
            sm = D.shard_train_state(init_train_state(params, opt, n),
                                     mesh, param_specs=specs)
            dm = D.shard_dataset(data_host, mesh)
            for i in range(10):
                s1, m1 = step1(s1, data_host)
                sm, m = stepm(sm, dm)
                check(m1, m, f'{mode}/{i}')
            check_params(s1.params, sm.params, mode)
            print(mode, 'ok')

        # ---- async (swap cadence 2) ----
        s_step1, m_step1 = make_async_steps(pel1, sc1, opt, base, n)
        pipe1 = AsyncPipeline(s_step1, m_step1, swap_every=2)
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host, **PK)
        pipem = AsyncPipeline(s_step, m_step, swap_every=2)
        a1 = init_async_state(params, opt, n)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(8):
            a1, m1 = pipe1.step(a1, data_host)
            am, m = pipem.step(am, dm)
            check(m1, m, f'async/{i}')
        check_params(a1.params, am.params, 'async')
        print('async ok')

        # ---- streamed ----
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 64)
        plane = StreamingDataPlane(store, 2, mesh=mesh)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        ss, smp, ms, _ = D.make_sharded_streamed_steps(
            pel, sc, opt, base, n, mesh, template, chunk_size=64, **PK)
        sp = StreamedISSGD(plane, ss, smp, ms, base, n)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        s1 = init_train_state(params, opt, n)
        for i in range(8):
            s1, m1 = step1(s1, data_host)
            st, m = sp.step(st)
            check(m1, m, f'streamed/{i}')
        check_params(s1.params, st.params, 'streamed')
        print('streamed ok')
    """, dp=dp, mp=mp)
    for tag in ("relaxed ok", "fused ok", "async ok", "streamed ok"):
        assert tag in out, out[-1000:]


def test_params_stay_sharded_and_hlo_has_no_full_param_tensor():
    """(b) the HLO gate on a 2×2 mesh: the fused train step, the async
    scoring/master programs, and the streamed scoring/sample/master
    programs never materialize a full-parameter-sized tensor, and no
    all-gather output is parameter-shaped; the step's output params keep
    their model-axis shards."""
    out = run_mesh_py(_SETUP + """
        import re
        from jax.sharding import PartitionSpec as P

        # full 2-D weight shapes (fwd + transposed-grad orientation);
        # none may appear in any program once model > 1
        FULL = ['f32[24,48]', 'f32[48,24]', 'f32[48,10]', 'f32[10,48]']

        def gate(hlo, tag):
            for s in FULL:
                assert s not in hlo, f'{tag}: full param tensor {s}'
            for line in hlo.splitlines():
                if 'all-gather' not in line:
                    continue
                for s in FULL:
                    assert s not in line, f'{tag}: all-gather of params'

        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)

        # sync (relaxed) train step
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        jitted = jax.jit(stepm)
        new_state, _ = jitted(sm, dm)
        w = new_state.params['fc0']['w']
        assert 'model' in tuple(w.sharding.spec), w.sharding.spec
        shapes = {s.data.shape for s in w.addressable_shards}
        assert shapes == {(24, 24)}, shapes
        gate(jitted.lower(sm, dm).compile().as_text(), 'train')

        # async scoring + master
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host,
            monitor_traces=False, **PK)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        bs = am.store
        gate(jax.jit(s_step).lower(am.stale_params, bs.write_buf, am.step,
                                   dm).compile().as_text(), 'async scoring')
        gate(jax.jit(m_step).lower(am.params, am.opt_state, am.stale_params,
                                   bs.read_buf, am.step, am.rng,
                                   dm).compile().as_text(), 'async master')

        # streamed scoring / sample / master
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 64)
        plane = StreamingDataPlane(store, 2, mesh=mesh)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        ss, smp, ms, _ = D.make_sharded_streamed_steps(
            pel, sc, opt, base, n, mesh, template, chunk_size=64, **PK)
        sp = StreamedISSGD(plane, ss, smp, ms, base, n, jit=False)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        rows = plane.fetch_sharded(sp._score_indices(0))
        store_s, fresh, stale, _ = jax.jit(ss)(st.stale_params, st.store,
                                               st.step, rows)
        gate(jax.jit(ss).lower(st.stale_params, st.store, st.step,
                               rows).compile().as_text(), 'streamed scoring')
        gate(jax.jit(smp).lower(store_s, st.step,
                                st.rng).compile().as_text(), 'sample')
        idx, _ = jax.jit(smp)(store_s, st.step, st.rng)
        batch = plane.gather_global(np.asarray(idx))
        gate(jax.jit(ms).lower(st.params, st.opt_state, st.stale_params,
                               store_s, st.step, st.rng, batch, fresh,
                               stale).compile().as_text(), 'streamed master')
        print('hlo gates ok')
    """, dp=2, mp=2)
    assert "hlo gates ok" in out


@dp_mp_grid
def test_model_axis_proposal_matches_single_device(dp, mp):
    """(c) the psum'd proposal invariant: after identical scoring sweeps,
    the dp×mp store holds the same ω̃ table as the single-device run —
    the model-axis partial sq-norms reduce to the exact grad norms."""
    out = run_mesh_py(_SETUP + """
        from repro.core.weight_store import read_proposal

        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(8):    # 8 steps x 64 rows = the whole 512-row table
            s1, _ = step1(s1, data_host)
            sm, _ = stepm(sm, dm)
        w1 = np.asarray(s1.store.weights)
        wm = np.asarray(sm.store.weights)
        assert (np.asarray(s1.store.scored_at) >= 0).all()
        np.testing.assert_allclose(wm, w1, rtol=1e-4, atol=1e-6)
        p1 = np.asarray(read_proposal(s1.store, 8, base.is_cfg))
        pm = np.asarray(read_proposal(
            jax.tree.map(np.asarray, sm.store), 8, base.is_cfg))
        np.testing.assert_allclose(pm / pm.sum(), p1 / p1.sum(),
                                   rtol=1e-4, atol=1e-8)
        print('proposal exact')
    """, dp=dp, mp=mp)
    assert "proposal exact" in out


def test_grad_clip_uses_model_global_norm():
    """grad_clip under mp clips by the TRUE global norm (psum over model
    of partial square-sums), matching the single-device trajectory."""
    out = run_mesh_py(_SETUP + """
        tc = dataclasses.replace(base, grad_clip=0.05)
        step1 = jax.jit(make_train_step(pel1, sc1, opt, tc, n))
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, tc, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(6):
            s1, m1 = step1(s1, data_host)
            sm, m = stepm(sm, dm)
            check(m1, m, f'clip/{i}')
        check_params(s1.params, sm.params, 'clip')
        print('clip ok')
    """, dp=1, mp=2)
    assert "clip ok" in out


def test_checkpoint_roundtrip_sharded_params():
    """Sharded save (gather-free per-shard layout) → restore → re-place →
    the restored dp×mp run continues bitwise-equal to the uninterrupted
    one; the npz holds shard entries, never a full param array."""
    out = run_mesh_py(_SETUP + """
        import numpy as np, tempfile, os
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for _ in range(5):
            sm, _ = stepm(sm, dm)
        path = os.path.join(tempfile.mkdtemp(), 'ck.npz')
        save_checkpoint(path, sm, step=5, gather=False)

        with np.load(path) as z:
            keys = list(z.files)
        assert any('params/fc0/w::shard' in k for k in keys), keys[:10]
        assert not any(k == 'params/fc0/w' for k in keys)

        template = init_train_state(params, opt, n)
        restored, ck = restore_checkpoint(path, template)
        assert ck == 5
        rm = D.shard_train_state(restored, mesh, param_specs=specs)
        w = rm.params['fc0']['w']
        assert {s.data.shape for s in w.addressable_shards} == {(24, 24)}

        cont, _ = stepm(sm, dm)
        resd, _ = stepm(rm, dm)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(resd.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print('sharded checkpoint roundtrip ok')
    """, dp=2, mp=2)
    assert "sharded checkpoint roundtrip ok" in out


@pytest.mark.slow
def test_train_cli_smoke_mp():
    """End-to-end CLI gate: --model-parallel 2 --mesh 2 runs green with
    the devices forced by train.py itself."""
    import os
    import subprocess
    import sys

    from _helpers import REPO
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--mesh", "2", "--model-parallel", "2", "--steps", "8",
         "--examples", "1024"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "mesh: (2, 2)" in r.stdout, r.stdout[-1000:]


# ======================================================================
# Transformer under shard_map (ISSUE 5): the forward itself is model-
# axis-aware — head-sharded attention, ffn-sharded MLP/MoE experts,
# channel-parallel mamba, vocab-parallel embed/unembed, sequence-parallel
# RMSNorm segments — and the ghost scorer psums partial per-example
# squared norms over `model`, so the same dp×mp ≡ dp-only battery that
# pins the MLP path holds for every transformer family.
# ======================================================================

# Dense dims are chosen so that under mp=2 no FULL parameter shape
# collides with any LOCAL shard or activation shape (the HLO gate greps
# shape strings): d_model=24, heads 4 = kv 4 x hd 6 (wq/wk/wv full 24x24,
# local 24x12 — kv=heads/2 would make full wk equal local wq), d_ff=80
# and vocab=80 (full 24x80/80x24, halves 24x40/40x24 match nothing).
# The GQA rep>1 grouping under mp is covered by the moe/hybrid legs
# (heads 4, kv 2); batch dims are 8/16 and seq is 16.
_TCONFIGS = {
    "dense": "dict(num_heads=4, num_kv_heads=4, d_ff=80)",
    "moe": ("dict(num_heads=4, num_kv_heads=2, d_ff=48, num_experts=4,"
            " num_experts_per_tok=2, moe_every=1)"),
    "ssm": ("dict(num_heads=4, num_kv_heads=4, d_ff=0, ssm_state=4,"
            " attention='none', d_inner=48)"),
    "mla": ("dict(num_heads=4, num_kv_heads=4, d_ff=48, attention='mla',"
            " q_lora_rank=16, kv_lora_rank=12, qk_nope_dim=8,"
            " qk_rope_dim=4, v_head_dim=8)"),
    "hybrid": ("dict(num_heads=4, num_kv_heads=2, d_ff=48, ssm_state=4,"
               " attn_every=2, attn_offset=1, d_inner=48)"),
}

_TSETUP_TEMPLATE = """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import ISConfig
        from repro.core.issgd import ISSGDConfig, init_train_state, make_train_step
        from repro.core import distributed as D
        from repro.core.async_pipeline import (AsyncPipeline, init_async_state,
                                               make_async_steps)
        from repro.core.scorer import make_lm_scorer
        from repro.data import make_token_dataset
        from repro.models.config import ModelConfig
        from repro.models.transformer import (init_transformer,
                                              per_example_loss,
                                              per_example_loss_and_score,
                                              transformer_specs)
        from repro.optim import sgd

        cfg = ModelConfig(name='t', arch_type='t', num_layers=2, d_model=24,
                          vocab_size=80, dtype='float32', remat=False,
                          **__KW__)
        train = make_token_dataset(jax.random.key(0), n=128, seq=17,
                                   vocab=cfg.vocab_size)
        params = init_transformer(jax.random.key(1), cfg)
        opt = sgd(0.05)
        specs = transformer_specs(cfg)
        base = ISSGDConfig(batch_size=8, score_batch_size=32, mode='relaxed',
                           is_cfg=ISConfig(smoothing=0.1), score_shards=4)
        n = train.size
        data_host = train.arrays
        MAXES = ('model',) if MP > 1 else ()
        SP = __SP__

        # the dp-only reference: the single-device axes=() step
        pel1 = lambda p, b: per_example_loss(p, cfg, b)[0]
        sc1 = make_lm_scorer(cfg, 'ghost')
        fs1 = lambda p, b: per_example_loss_and_score(p, cfg, b)
        # the dp x mp run under test: model-axis-aware loss/scorer closures
        pel = lambda p, b: per_example_loss(p, cfg, b, model_axes=MAXES,
                                            seq_shard=SP)[0]
        sc = make_lm_scorer(cfg, 'ghost', model_axes=MAXES, seq_shard=SP)
        fs = lambda p, b: per_example_loss_and_score(p, cfg, b,
                                                     model_axes=MAXES,
                                                     seq_shard=SP)
        PK = dict(param_specs=specs, params_template=params)

        def check(m1, m, tag):
            assert np.array_equal(np.asarray(m1.sample_indices),
                                  np.asarray(m.sample_indices)), tag
            np.testing.assert_allclose(float(m1.loss), float(m.loss),
                                       rtol=1e-5, atol=1e-6, err_msg=tag)
            np.testing.assert_allclose(float(m1.grad_norm), float(m.grad_norm),
                                       rtol=1e-4, atol=1e-6, err_msg=tag)

        def check_params(p1, p, tag):
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-5, err_msg=tag)
"""


def _tsetup(variant: str, sp: bool = True) -> str:
    return (_TSETUP_TEMPLATE
            .replace("__KW__", _TCONFIGS[variant])
            .replace("__SP__", repr(sp)))


@dp_mp_grid
def test_transformer_dpmp_equivalent_to_dp_only_all_modes(dp, mp):
    """The ISSUE 5 tentpole equivalence: a dense transformer (GQA
    attention + SwiGLU MLP) trained dp×mp — with sequence-parallel norm
    segments on — matches the same-seed single-device run in relaxed,
    fused, async, and streamed modes."""
    out = run_mesh_py(_tsetup("dense") + """
        # ---- relaxed + fused (the sync train step) ----
        for mode in ('relaxed', 'fused'):
            tc = dataclasses.replace(base, mode=mode)
            fk1 = dict(fused_score=fs1) if mode == 'fused' else {}
            fk = dict(fused_score=fs) if mode == 'fused' else {}
            step1 = jax.jit(make_train_step(pel1, sc1, opt, tc, n, **fk1))
            stepm, _ = D.make_sharded_train_step(
                pel, sc, opt, tc, n, mesh, data_host, **fk, **PK)
            stepm = jax.jit(stepm)
            s1 = init_train_state(params, opt, n)
            sm = D.shard_train_state(init_train_state(params, opt, n),
                                     mesh, param_specs=specs)
            dm = D.shard_dataset(data_host, mesh)
            for i in range(6):
                s1, m1 = step1(s1, data_host)
                sm, m = stepm(sm, dm)
                check(m1, m, f'{mode}/{i}')
            check_params(s1.params, sm.params, mode)
            print(mode, 'ok')

        # ---- async (swap cadence 2) ----
        s_step1, m_step1 = make_async_steps(pel1, sc1, opt, base, n)
        pipe1 = AsyncPipeline(s_step1, m_step1, swap_every=2)
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host, **PK)
        pipem = AsyncPipeline(s_step, m_step, swap_every=2)
        a1 = init_async_state(params, opt, n)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(6):
            a1, m1 = pipe1.step(a1, data_host)
            am, m = pipem.step(am, dm)
            check(m1, m, f'async/{i}')
        check_params(a1.params, am.params, 'async')
        print('async ok')

        # ---- streamed ----
        from repro.data.store import ChunkedExampleStore
        from repro.data.streaming import StreamedISSGD, StreamingDataPlane
        store = ChunkedExampleStore.from_arrays(data_host, 16)
        plane = StreamingDataPlane(store, 2, mesh=mesh)
        template = {k: np.empty((0,) + store.row_shape(k), store.dtype(k))
                    for k in store.keys}
        ss, smp, ms, _ = D.make_sharded_streamed_steps(
            pel, sc, opt, base, n, mesh, template, chunk_size=16, **PK)
        sp = StreamedISSGD(plane, ss, smp, ms, base, n)
        st = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        s1 = init_train_state(params, opt, n)
        for i in range(6):
            s1, m1 = step1(s1, data_host)
            st, m = sp.step(st)
            check(m1, m, f'streamed/{i}')
        check_params(s1.params, st.params, 'streamed')
        print('streamed ok')
    """, dp=dp, mp=mp)
    for tag in ("relaxed ok", "fused ok", "async ok", "streamed ok"):
        assert tag in out, out[-1000:]


@pytest.mark.parametrize("variant,sp", [
    ("moe", True), ("ssm", True), ("mla", True), ("hybrid", False),
])
def test_transformer_arch_variants_dpmp_equivalent(variant, sp):
    """Every architecture family crosses the model axis: MoE (ffn-sharded
    experts + replicated router), pure-SSM (channel-parallel selective
    scan), MLA (head-sharded latent expansions), and the jamba-style
    hybrid — relaxed mode on a 1×2 mesh (the hybrid leg also covers the
    no-sequence-parallel path)."""
    out = run_mesh_py(_tsetup(variant, sp=sp) + """
        step1 = jax.jit(make_train_step(pel1, sc1, opt, base, n))
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        s1 = init_train_state(params, opt, n)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for i in range(4):
            s1, m1 = step1(s1, data_host)
            sm, m = stepm(sm, dm)
            check(m1, m, f'step/{i}')
        check_params(s1.params, sm.params, 'params')
        print('variant ok')
    """, dp=1, mp=2)
    assert "variant ok" in out


def test_transformer_hlo_no_full_param_and_seq_parallel_norms():
    """The ISSUE 5 HLO gate on a 2×2 mesh: the dense-transformer scoring
    and master programs never materialize a full-parameter tensor (plain
    or period-stacked) and no all-gather output is parameter-shaped;
    with sequence parallelism on, the sliced (B, S/M, D) norm-segment
    activations are present — the full-sequence norm compute is gone —
    and the output params keep their model-axis shards."""
    out = run_mesh_py(_tsetup("dense") + """
        from jax.sharding import PartitionSpec as P

        # full param shapes, fwd + transposed-grad orientation, plain and
        # period-stacked (P=2); none may appear once model > 1
        FULL = ['f32[24,24]', 'f32[24,80]', 'f32[80,24]',
                'f32[2,24,24]', 'f32[2,24,80]', 'f32[2,80,24]']

        def gate(hlo, tag):
            for s in FULL:
                assert s not in hlo, f'{tag}: full param tensor {s}'
            for line in hlo.splitlines():
                if 'all-gather' not in line:
                    continue
                for s in FULL:
                    assert s not in line, f'{tag}: all-gather of params'

        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        jitted = jax.jit(stepm)
        new_state, _ = jitted(sm, dm)
        wq = new_state.params['layers']['l0']['mixer']['wq']
        assert 'model' in tuple(wq.sharding.spec), wq.sharding.spec
        shapes = {s.data.shape for s in wq.addressable_shards}
        assert shapes == {(2, 24, 12)}, shapes
        hlo = jitted.lower(sm, dm).compile().as_text()
        gate(hlo, 'train')
        # sequence-parallel witness: the norm segments run on the
        # (B, S/M, D) slice — scoring slice 16 rows/device, minibatch 8,
        # seq 16 halved over the model axis
        assert 'f32[8,8,24]' in hlo or 'f32[16,8,24]' in hlo, \\
            'no sequence-parallel norm slice in the train program'

        # async scoring + master programs
        s_step, m_step, _ = D.make_sharded_async_steps(
            pel, sc, opt, base, n, mesh, data_host,
            monitor_traces=False, **PK)
        am = D.shard_train_state(init_async_state(params, opt, n), mesh,
                                 param_specs=specs)
        bs = am.store
        gate(jax.jit(s_step).lower(am.stale_params, bs.write_buf, am.step,
                                   dm).compile().as_text(), 'async scoring')
        gate(jax.jit(m_step).lower(am.params, am.opt_state, am.stale_params,
                                   bs.read_buf, am.step, am.rng,
                                   dm).compile().as_text(), 'async master')
        print('transformer hlo gates ok')
    """, dp=2, mp=2)
    assert "transformer hlo gates ok" in out


def test_moe_hlo_no_full_expert_tensor():
    """The HLO gate for the MoE path on a 1×2 model mesh: the train
    program (scoring + master) and the standalone probe/scoring program
    never materialize a full expert tensor (plain or period-stacked) and
    no all-gather output is expert-shaped — expert ffn shards stay local
    end to end.  d_ff=96 keeps the capacity-dispatch buffers (4,80,24)/
    (4,40,24) from colliding with full expert shape strings."""
    setup = (_TSETUP_TEMPLATE
             .replace("__KW__", "dict(num_heads=4, num_kv_heads=4, d_ff=96,"
                      " num_experts=4, num_experts_per_tok=1, moe_every=1)")
             .replace("__SP__", "True"))
    out = run_mesh_py(setup + """
        FULL = ['f32[4,24,96]', 'f32[4,96,24]', 'f32[24,24]',
                'f32[2,4,24,96]', 'f32[2,4,96,24]', 'f32[2,24,24]']

        def gate(hlo, tag):
            for s in FULL:
                assert s not in hlo, f'{tag}: full tensor {s}'
            for line in hlo.splitlines():
                if 'all-gather' not in line:
                    continue
                for s in FULL:
                    assert s not in line, f'{tag}: all-gather of params'

        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        stepm, tcfg = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                                data_host, **PK)
        jitted = jax.jit(stepm)
        jitted(sm, dm)
        gate(jitted.lower(sm, dm).compile().as_text(), 'moe train')

        probe = jax.jit(D.make_sharded_score_step(
            sc, base, n, mesh, data_host, optimizer=opt, **PK))
        gate(probe.lower(sm, dm).compile().as_text(), 'moe scoring')
        print('moe hlo gates ok')
    """, dp=1, mp=2)
    assert "moe hlo gates ok" in out


def test_transformer_mp_checkpoint_roundtrip():
    """Sharded transformer checkpoints stay gather-free (`::shard<i>`
    entries, no full param array) and the restored dp×mp run continues
    bitwise-equal to the uninterrupted one."""
    out = run_mesh_py(_tsetup("dense") + """
        import numpy as np, tempfile, os
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        stepm, _ = D.make_sharded_train_step(pel, sc, opt, base, n, mesh,
                                             data_host, **PK)
        stepm = jax.jit(stepm)
        sm = D.shard_train_state(init_train_state(params, opt, n), mesh,
                                 param_specs=specs)
        dm = D.shard_dataset(data_host, mesh)
        for _ in range(3):
            sm, _ = stepm(sm, dm)
        path = os.path.join(tempfile.mkdtemp(), 'ck.npz')
        save_checkpoint(path, sm, step=3, gather=False)

        with np.load(path) as z:
            keys = list(z.files)
        assert any('params/layers/l0/mixer/wq::shard' in k for k in keys), \\
            keys[:10]
        assert not any(k == 'params/layers/l0/mixer/wq' for k in keys)

        template = init_train_state(params, opt, n)
        restored, ck = restore_checkpoint(path, template)
        assert ck == 3
        rm = D.shard_train_state(restored, mesh, param_specs=specs)
        cont, _ = stepm(sm, dm)
        resd, _ = stepm(rm, dm)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(resd.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print('transformer checkpoint roundtrip ok')
    """, dp=1, mp=2)
    assert "transformer checkpoint roundtrip ok" in out


def test_train_cli_validates_transformer_mp_flags():
    """Flag validation fires up front with the config field named,
    instead of failing inside shard_map."""
    import os
    import subprocess
    import sys

    from _helpers import REPO
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "glm4-9b",
         "--smoke", "--model-parallel", "3", "--steps", "1"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert r.returncode != 0
    assert "num_heads" in r.stderr, r.stderr[-1000:]

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "glm4-9b",
         "--smoke", "--model-parallel", "4", "--steps", "1"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert r.returncode != 0
    assert "num_kv_heads" in r.stderr, r.stderr[-1000:]

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mlp_svhn",
         "--smoke", "--async-scoring", "--mode", "fused", "--steps", "1"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert r.returncode != 0
    assert "relaxed|uniform" in r.stderr, r.stderr[-1000:]


@pytest.mark.slow
def test_train_cli_smoke_transformer_mp():
    """End-to-end CLI gate: a transformer arch composes --mesh 2
    --model-parallel 2 with ghost scoring, devices forced by train.py."""
    import os
    import subprocess
    import sys

    from _helpers import REPO
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "glm4-9b",
         "--smoke", "--mesh", "2", "--model-parallel", "2", "--steps", "4",
         "--seq", "32", "--batch", "8", "--score-batch", "32",
         "--examples", "256", "--strategy", "ghost"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "mesh: (2, 2)" in r.stdout, r.stdout[-1000:]

"""Continuous batching: staggered multi-tenant decode == isolated decode,
plus the seed-era regressions — freed-slot freeze, per-bucket (not
per-length) prefill compilation, MLA ring discipline, and the decode_step
signature/dtype fixes."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import forward, init_transformer
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.engine import (ServeState, decode_step, generate, prefill)


def test_batched_requests_match_isolated_generation():
    cfg = get_smoke_config("glm4-9b")
    params = init_transformer(jax.random.key(0), cfg)

    prompts = [
        jax.random.randint(jax.random.key(i + 1), (6 + i,), 0,
                           cfg.vocab_size)
        for i in range(3)
    ]
    want = {
        i: generate(params, cfg, p[None], steps=5, max_len=32)[0].tolist()
        for i, p in enumerate(prompts)
    }

    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    got = batcher.run(reqs)

    assert set(got) == {0, 1, 2}
    for uid in got:
        assert got[uid] == want[uid], (uid, got[uid], want[uid])


def test_more_requests_than_slots_all_finish():
    cfg = get_smoke_config("falcon-mamba-7b")
    params = init_transformer(jax.random.key(0), cfg)
    reqs = [Request(uid=i,
                    prompt=jax.random.randint(jax.random.key(i), (4,), 0,
                                              cfg.vocab_size),
                    max_new_tokens=3)
            for i in range(5)]
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=16)
    got = batcher.run(reqs)
    assert set(got) == set(range(5))
    assert all(len(v) == 3 for v in got.values())


def _glm4():
    cfg = get_smoke_config("glm4-9b")
    return cfg, init_transformer(jax.random.key(0), cfg)


def _prompt(key, cfg, n):
    return jax.random.randint(jax.random.key(key), (n,), 0, cfg.vocab_size)


# --------------------------------------------------------------- churn
def test_evict_readmit_same_slot_matches_isolated_generate():
    """A slot that finished one request and admits another produces the
    second request's tokens bitwise equal to an isolated generate — the
    freed slot's dead cache rows leak nothing into the next tenant."""
    cfg, params = _glm4()
    a, b = _prompt(1, cfg, 8), _prompt(2, cfg, 8)
    want_a = generate(params, cfg, a[None], steps=4, max_len=32)[0].tolist()
    want_b = generate(params, cfg, b[None], steps=4, max_len=32)[0].tolist()

    batcher = ContinuousBatcher(params, cfg, num_slots=1, max_len=32)
    got = batcher.run([Request(uid=0, prompt=a, max_new_tokens=4),
                       Request(uid=1, prompt=b, max_new_tokens=4)])
    assert got[0] == want_a
    assert got[1] == want_b


def test_eos_evicts_early():
    cfg, params = _glm4()
    p = _prompt(3, cfg, 8)
    gen = generate(params, cfg, p[None], steps=6, max_len=32)[0].tolist()
    eos = gen[2]
    stop = gen.index(eos)  # first occurrence (may be < 2)
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32)
    got = batcher.run([Request(uid=0, prompt=p, max_new_tokens=6,
                               eos_id=eos)])
    assert got[0] == gen[:stop + 1]


def test_admission_blocks_when_full_then_succeeds():
    cfg, params = _glm4()
    batcher = ContinuousBatcher(params, cfg, num_slots=1, max_len=32)
    assert batcher.try_insert(Request(uid=0, prompt=_prompt(4, cfg, 8),
                                      max_new_tokens=2))
    late = Request(uid=1, prompt=_prompt(5, cfg, 8), max_new_tokens=2)
    assert not batcher.try_insert(late)
    while batcher.step():
        pass
    assert 0 in batcher.finished
    assert batcher.try_insert(late)


def test_more_slots_than_requests_steady_state():
    cfg, params = _glm4()
    batcher = ContinuousBatcher(params, cfg, num_slots=4, max_len=32)
    got = batcher.run([Request(uid=i, prompt=_prompt(6 + i, cfg, 8),
                               max_new_tokens=3) for i in range(2)])
    assert set(got) == {0, 1}
    # never-used slots stayed frozen at length 0
    assert np.asarray(batcher.state.lengths).tolist() == [0, 0, 0, 0]


# -------------------------------------------- seed-era regressions
def test_freed_slot_stays_frozen():
    """Regression: decode_step used to do `lengths + 1` for every row, so
    an evicted slot's length crept back up and its dead cache rows kept
    being written.  The active mask freezes both."""
    cfg, params = _glm4()
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32)
    assert batcher.try_insert(Request(uid=0, prompt=_prompt(8, cfg, 8),
                                      max_new_tokens=8))
    assert batcher.try_insert(Request(uid=1, prompt=_prompt(9, cfg, 8),
                                      max_new_tokens=2))
    while 1 not in batcher.finished:
        batcher.step()
    assert int(batcher.state.lengths[1]) == 0
    dead = {k: np.asarray(v[:, 1]) for k, v in batcher.state.caches.items()}
    for _ in range(3):
        batcher.step()   # slot 0 still decoding
    assert int(batcher.state.lengths[1]) == 0, "freed-slot length crept"
    for k, v in batcher.state.caches.items():
        assert np.array_equal(np.asarray(v[:, 1]), dead[k]), \
            f"freed slot cache {k} was written"


def test_prefill_compiles_per_bucket_not_per_length():
    """Regression: every distinct prompt length used to retrace the
    prefill jit.  Buckets pin the trace count to the bucket count."""
    cfg, params = _glm4()
    lengths = [3, 4, 5, 6, 7, 9]
    batcher = ContinuousBatcher(params, cfg, num_slots=6, max_len=32,
                                min_bucket=4)
    for i, n in enumerate(lengths):
        assert batcher.try_insert(Request(uid=i, prompt=_prompt(10 + i, cfg, n),
                                          max_new_tokens=2))
    # buckets: 3,4 -> 4; 5,6,7 -> 8; 9 -> 16
    assert batcher.prefill_traces == 3

    unbucketed = ContinuousBatcher(params, cfg, num_slots=6, max_len=32,
                                   prefill_buckets=False)
    for i, n in enumerate(lengths):
        assert unbucketed.try_insert(
            Request(uid=i, prompt=_prompt(10 + i, cfg, n), max_new_tokens=2))
    assert unbucketed.prefill_traces == len(set(lengths))


def test_mla_decode_ring_past_capacity():
    """Regression: the MLA decode cache write was `slot = pos` with no
    ring — once pos reached capacity the scatter clamped onto the last
    row and the validity mask ran past the buffer.  MLA now gets the GQA
    window discipline end to end: `cfg.sliding_window` bounds the cache,
    decode rings over it, and teacher-forced decode past the wrap matches
    the full-sequence forward under the same window mask."""
    import dataclasses

    cfg = get_smoke_config("minicpm3-4b")
    assert cfg.attention == "mla"
    w = 8
    cfg = dataclasses.replace(cfg, sliding_window=w)
    params = init_transformer(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(42), (1, 14), 0, cfg.vocab_size)

    _, st = prefill(params, cfg, toks[:, :4], max_len=16)
    assert st.caches["l0.attn.latent"].shape[2] == w  # window-bounded cache
    logits = None
    for pos in range(4, 14):   # teacher-force; ring wraps at pos >= 8
        logits, st = decode_step(params, cfg, toks[:, pos], st)
    ref, _ = forward(params, cfg, toks)
    err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
    assert err < 2e-4, err


def test_decode_step_signature_and_per_buffer_dtype():
    """Regression: decode_step carried a dead `max_len` parameter, and the
    MLA persist cast through `next(iter(caches.values())).dtype` — wrong
    whenever dict order puts a different-precision buffer first.  Each
    write now casts to its own target buffer, so results are invariant to
    cache-dict ordering."""
    assert "max_len" not in inspect.signature(decode_step).parameters

    cfg = get_smoke_config("minicpm3-4b")
    params = init_transformer(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(7), (1, 5), 0, cfg.vocab_size)
    _, st = prefill(params, cfg, toks[:, :4], max_len=8)

    def mixed(caches, order):
        out = {}
        for k in order:
            v = caches[k]
            out[k] = v.astype(jnp.bfloat16) if "rope" in k else v
        return out

    keys = list(st.caches.keys())
    st_fwd = ServeState(caches=mixed(st.caches, keys), lengths=st.lengths)
    st_rev = ServeState(caches=mixed(st.caches, keys[::-1]),
                        lengths=st.lengths)
    lg_f, out_f = decode_step(params, cfg, toks[:, 4], st_fwd)
    lg_r, out_r = decode_step(params, cfg, toks[:, 4], st_rev)
    assert np.array_equal(np.asarray(lg_f), np.asarray(lg_r))
    for k in keys:
        assert out_f.caches[k].dtype == st_fwd.caches[k].dtype, k
        assert np.array_equal(np.asarray(out_f.caches[k], dtype=np.float32),
                              np.asarray(out_r.caches[k], dtype=np.float32)), k

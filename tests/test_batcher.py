"""Continuous batching: staggered multi-tenant decode == isolated decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import init_transformer
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.engine import generate


def test_batched_requests_match_isolated_generation():
    cfg = get_smoke_config("glm4-9b")
    params = init_transformer(jax.random.key(0), cfg)

    prompts = [
        jax.random.randint(jax.random.key(i + 1), (6 + i,), 0,
                           cfg.vocab_size)
        for i in range(3)
    ]
    want = {
        i: generate(params, cfg, p[None], steps=5, max_len=32)[0].tolist()
        for i, p in enumerate(prompts)
    }

    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    got = batcher.run(reqs)

    assert set(got) == {0, 1, 2}
    for uid in got:
        assert got[uid] == want[uid], (uid, got[uid], want[uid])


def test_more_requests_than_slots_all_finish():
    cfg = get_smoke_config("falcon-mamba-7b")
    params = init_transformer(jax.random.key(0), cfg)
    reqs = [Request(uid=i,
                    prompt=jax.random.randint(jax.random.key(i), (4,), 0,
                                              cfg.vocab_size),
                    max_new_tokens=3)
            for i in range(5)]
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=16)
    got = batcher.run(reqs)
    assert set(got) == set(range(5))
    assert all(len(v) == 3 for v in got.values())

"""Distribution-layer tests: sharding rules + SPMD sampler + smoke dry-run.

Multi-device tests run in subprocesses because the XLA host-device count is
fixed at first jax init (the main pytest process keeps 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_shard_sampler_matches_host_distribution():
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.sampler import make_distributed_sampler, sample_indices
        mesh = jax.make_mesh((8,), ('data',))
        N = 2048
        w = (jnp.arange(N, dtype=jnp.float32) % 37) + 0.5
        ws = jax.device_put(w, NamedSharding(mesh, P('data')))
        s = make_distributed_sampler(mesh, ('data',))
        idx = np.asarray(s(jax.random.key(3), ws, 200_000))
        h = np.bincount(idx, minlength=N) / len(idx)
        p = np.asarray(w / w.sum())
        tv = 0.5 * np.abs(h - p).sum()
        assert tv < 0.05, tv
        print('TV', tv)
    """)
    assert "TV" in out


def test_param_pspecs_cover_tree():
    out = _run_py("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.dist.sharding import param_pspecs
        from repro.models.transformer import init_transformer, transformer_specs
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg = get_smoke_config('jamba-v0.1-52b')
        params = jax.eval_shape(lambda k: init_transformer(k, cfg),
                                jax.random.key(0))
        specs = param_pspecs(transformer_specs(cfg), params, mesh)
        # every param leaf has a matching pspec leaf
        pl = jax.tree.leaves(params)
        sl = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(pl) == len(sl), (len(pl), len(sl))
        # stacked layer params have a leading None
        wq = specs['layers']['l1']['mixer']['wq']
        assert wq[0] is None and 'model' in wq
        print('leaves', len(pl))
    """)
    assert "leaves" in out


def test_uneven_vocab_falls_back_to_replication():
    out = _run_py("""
        import jax
        from repro.dist.sharding import logical_to_pspec
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        # an odd vocab is not divisible by model=4 -> replicated dim
        ps = logical_to_pspec(('embed', 'vocab'), (64, 73449), mesh)
        assert ps[1] is None, ps
        ps2 = logical_to_pspec(('embed', 'vocab'), (64, 73448), mesh)
        assert ps2[1] == 'model'
        print('ok')
    """)
    assert "ok" in out


def test_divisibility_fallback_warns_once_naming_param_and_axis():
    """The silent-replication fallback is no longer silent: a dim that
    fails divisibility warns exactly once, naming the parameter and the
    mesh axis — a broken mp config can't masquerade as a working one.
    Rule-level replication (logical axis mapped to None) stays quiet."""
    out = _run_py("""
        import warnings
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import logical_to_pspec, param_pspecs
        mesh = jax.make_mesh((2, 4), ('data', 'model'))

        specs = {'head': {'w': ('embed', 'vocab')}}
        params = {'head': {'w': jax.ShapeDtypeStruct((64, 73449),
                                                     'float32')}}
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter('always')
            ps = param_pspecs(specs, params, mesh)
        assert jax.tree.leaves(ps, is_leaf=lambda x: isinstance(x, P)) \\
            == [P(None, None)]
        msgs = [str(w.message) for w in rec]
        assert len(msgs) == 1, msgs
        assert "['head']['w']" in msgs[0], msgs[0]      # names the param
        assert "'model'" in msgs[0], msgs[0]            # names the axis
        assert 'vocab' in msgs[0] and '73449' in msgs[0], msgs[0]

        # one-time: the same (param, axis, size, dim) never warns again
        with warnings.catch_warnings(record=True) as rec2:
            warnings.simplefilter('always')
            param_pspecs(specs, params, mesh)
        assert not rec2, [str(w.message) for w in rec2]

        # but a DIFFERENT (still non-dividing) mesh size warns afresh —
        # retrying with model=2 must not stay deduped under model=4
        mesh2 = jax.make_mesh((4, 2), ('data', 'model'))
        with warnings.catch_warnings(record=True) as rec2b:
            warnings.simplefilter('always')
            param_pspecs(specs, params, mesh2)
        assert len(rec2b) == 1 and 'size 2' in str(rec2b[0].message), \\
            [str(w.message) for w in rec2b]

        # rule-level replication (embed -> None) is by design, not a
        # divisibility failure: no warning even for an odd dim
        with warnings.catch_warnings(record=True) as rec3:
            warnings.simplefilter('always')
            logical_to_pspec(('embed',), (6151,), mesh, name='x')
        assert not rec3, [str(w.message) for w in rec3]
        print('fallback warning ok')
    """)
    assert "fallback warning ok" in out


@pytest.mark.slow
def test_dryrun_smoke_production_mesh():
    """Two smoke combos lower+compile on the 16x16 and 2x16x16 meshes."""
    out = _run_py("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
        from pathlib import Path
        from repro.launch.dryrun import run_one
        r1 = run_one('glm4-9b', 'train_4k', False, Path('/tmp/drs'), smoke=True)
        r2 = run_one('jamba-v0.1-52b', 'decode_32k', True, Path('/tmp/drs'),
                     smoke=True)
        assert r1['ok'] and r2['ok']
        assert r1['flops_per_device'] > 0
        print('compiled both')
    """, devices=512)
    assert "compiled both" in out


def test_sharded_decode_attention_exact():
    """Seq-sharded flash-decode (logsumexp psum merge) == the dense oracle."""
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.serving.sharded_decode import sharded_decode_attention
        from repro.kernels.ref import decode_attention_ref
        mesh = jax.make_mesh((8,), ('data',))
        B, W, H, Hkv, hd = 2, 256, 8, 2, 32
        ks = jax.random.split(jax.random.key(0), 4)
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, W, Hkv, hd))
        v = jax.random.normal(ks[2], (B, W, Hkv, hd))
        lengths = jnp.asarray([100, 256], jnp.int32)
        ksh = jax.device_put(k, NamedSharding(mesh, P(None, 'data')))
        vsh = jax.device_put(v, NamedSharding(mesh, P(None, 'data')))
        got = sharded_decode_attention(q, ksh, vsh, lengths, mesh)
        want = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)
        print('sharded decode exact')
    """)
    assert "sharded decode exact" in out

"""Unit tests for the loop-aware HLO cost walker (roofline source)."""
import textwrap

import numpy as np

from repro.launch.hlo_cost import analyze, parse_computations

SYNTHETIC = textwrap.dedent("""\
    HloModule jit_step, entry_computation_layout={()->f32[]}

    %loop_cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%iv, %c), direction=LT
    }

    %loop_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant(0)
      %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[8,64]{1,0} all-gather(%y), dimensions={1}
      %iv = s32[] get-tuple-element(%p), index=0
      %one = s32[] constant(1)
      %nv = s32[] add(%iv, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%nv, %y)
    }

    ENTRY %main () -> f32[] {
      %init = (s32[], f32[8,16]) tuple()
      %w2 = f32[4,8]{1,0} constant(0)
      %x2 = f32[2,4]{1,0} constant(0)
      %d2 = f32[2,8]{1,0} dot(%x2, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %wl = (s32[], f32[8,16]) while(%init), condition=%loop_cond, body=%loop_body
      ROOT %r = f32[] constant(0)
    }
""")


def test_parse_computations():
    comps = parse_computations(SYNTHETIC)
    assert set(comps) == {"loop_cond", "loop_body", "main"}


def test_loop_scaled_flops_and_collectives():
    c = analyze(SYNTHETIC)
    # entry dot: 2*2*8*4 = 128; body dot: 2*8*16*16 = 4096, ×10 trips
    assert c.flops == 128 + 10 * 4096, c.flops
    # all-gather output f32[8,64] = 2048 B, ×10 trips
    assert c.collective_bytes == 10 * 8 * 64 * 4, c.collective_bytes
    assert c.collective_by_op == {"all-gather": 10 * 2048}


def test_walker_matches_analytic_on_real_model():
    """End-to-end: walker FLOPs ≈ analytic 2·N·D for a pure forward pass."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.transformer import forward, init_transformer

    cfg = get_smoke_config("deepseek-7b")
    params = init_transformer(jax.random.key(0), cfg)
    b, s = 4, 64
    toks = jnp.zeros((b, s), jnp.int32)
    compiled = jax.jit(
        lambda p, t: forward(p, cfg, t)[0]).lower(params, toks).compile()
    got = analyze(compiled.as_text()).flops
    # analytic: 2·active-params·tokens (+attention, small here)
    want = 2.0 * cfg.active_param_count() * b * s
    assert 0.5 * want < got < 3.0 * want, (got, want)

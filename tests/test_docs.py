"""Doc-coverage gate: the contract-bearing packages (`core`, `data`,
`dist`, `kernels`, `serving`) must keep module + public-API docstrings at
100% — docs/ARCHITECTURE.md and docs/KERNELS.md point into these modules
for the sharding, replication, and kernel-parity contracts, so an
undocumented public definition is a missing contract.  The same check
runs as its own CI leg via ``python tools/check_docstrings.py``."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))


def test_doc_coverage_contract_packages():
    from check_docstrings import DEFAULT_PACKAGES, check_packages
    assert "src/repro/kernels" in DEFAULT_PACKAGES
    assert "src/repro/serving" in DEFAULT_PACKAGES
    assert "src/repro/telemetry" in DEFAULT_PACKAGES
    missing = check_packages(root=REPO)
    assert not missing, "undocumented public definitions:\n" + "\n".join(
        f"  {p}:{ln}: {name}" for p, ln, name in missing)


def test_architecture_doc_exists_and_is_linked():
    """docs/ARCHITECTURE.md exists and README links to it (ISSUE 5
    acceptance criterion)."""
    arch = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    assert os.path.exists(arch), "docs/ARCHITECTURE.md missing"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README does not link docs/ARCHITECTURE.md"
    with open(arch) as f:
        text = f.read()
    # the doc stays anchored to the modules it maps
    for anchor in ("core/issgd.py", "core/scorer.py", "data/streaming.py",
                   "dist/sharding.py", "::shard", "relaxed", "fused",
                   "async", "stream"):
        assert anchor in text, f"ARCHITECTURE.md lost its {anchor!r} anchor"


def test_serving_loop_docs_anchored():
    """The ISSUE 7 serving docs: ARCHITECTURE.md keeps its serving-loop
    section and README its "Serving loop" walkthrough, both anchored to
    the modules and invariants they describe."""
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    for anchor in ("serving loop", "serving/engine.py", "serving/loop.py",
                   "ContinuousBatcher", "ServeLoop", "TrafficIngest",
                   "PublishedParams", "ring-or-reject", "mark_live",
                   "decode_cache_pspecs", "tests/test_serving_loop.py"):
        assert anchor in arch, f"ARCHITECTURE.md lost its {anchor!r} anchor"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for anchor in ("## Serving loop", "--serve-loop", "--serve-reserve-chunks",
                   "PublishedParams", "ContinuousBatcher", "TrafficIngest",
                   "tests/test_serving_loop.py"):
        assert anchor in readme, f"README lost its {anchor!r} anchor"


def test_telemetry_docs_anchored():
    """The ISSUE 8 observability docs: ARCHITECTURE.md keeps its
    telemetry section and README its "Observability" walkthrough, both
    anchored to the event schema, span taxonomy, monitors, and the gates
    that keep telemetry non-invasive."""
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    for anchor in ("## 8. Telemetry", "telemetry/monitors.py",
                   "telemetry/events.py", "MonitorSet", "EventSink",
                   "NullSink", "staleness", "max_weight_frac",
                   "empty_rows", "scoring.dispatch", "master.dispatch",
                   "non-blocking", "--metrics-jsonl",
                   "tools/metrics_report.py", "tests/test_telemetry.py",
                   "test_monitors_off_is_hlo_identical",
                   "test_monitors_on_is_bitwise_noninvasive"):
        assert anchor in arch, f"ARCHITECTURE.md lost its {anchor!r} anchor"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for anchor in ("## Observability", "--metrics-jsonl", "--monitors",
                   "--profile-dir", '"kind": "monitors"', "staleness",
                   "tools/metrics_report.py", "tests/test_telemetry.py"):
        assert anchor in readme, f"README lost its {anchor!r} anchor"


def test_kernels_doc_exists_and_is_linked():
    """docs/KERNELS.md exists, is linked from README and the
    ARCHITECTURE module table, and keeps its per-kernel anchors."""
    kdoc = os.path.join(REPO, "docs", "KERNELS.md")
    assert os.path.exists(kdoc), "docs/KERNELS.md missing"
    for linker in ("README.md", os.path.join("docs", "ARCHITECTURE.md")):
        with open(os.path.join(REPO, linker)) as f:
            assert "KERNELS.md" in f.read(), \
                f"{linker} does not link docs/KERNELS.md"
    with open(kdoc) as f:
        text = f.read()
    # the doc stays anchored to the kernels (and contracts) it documents
    for anchor in ("flash_attention_bwd", "per_example_sqnorm",
                   "ghost_norm", "with_scores", "ref.py", "VMEM",
                   "bitwise", "GQA", "attn_score_sweep",
                   "per_example_sqnorm_multi"):
        assert anchor in text, f"KERNELS.md lost its {anchor!r} anchor"


def test_controller_docs_anchored():
    """The ISSUE 9 adaptive-control docs: ARCHITECTURE.md keeps its
    strategy-zoo/controller section and README its "Adaptive proposal
    control" walkthrough, both anchored to the modules, flags, event
    kinds, and bitwise/HLO invariants they describe."""
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    for anchor in ("## 9. Adaptive proposal control", "core/controller.py",
                   "core/strategies.py", "controller.decision",
                   "replay_decisions", "var_margin", "use_is",
                   "upper_bound", "bandit_mixed",
                   "tests/test_controller.py"):
        assert anchor in arch, f"ARCHITECTURE.md lost its {anchor!r} anchor"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for anchor in ("## Adaptive proposal control", "--proposal-strategy",
                   "--adaptive-is", "--adapt-every",
                   '"kind": "controller.decision"',
                   "tests/test_controller.py"):
        assert anchor in readme, f"README lost its {anchor!r} anchor"


def test_sampling_structures_docs_anchored():
    """The ISSUE 10 sampling-structures docs: ARCHITECTURE.md keeps its
    §10 and README its walkthrough, both anchored to the index module,
    the quantization bound, the TTL rule, and the tests that pin them."""
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    for anchor in ("## 10. Sampling structures", "core/mass_index.py",
                   "refresh_chunks", "build_index", "sample_chunks",
                   "block_masses", "chunk_proposal_mass", "qscale",
                   "quantization_tv_bound", "decay_proposal",
                   "--index", "--table-dtype", "--score-ttl",
                   "--index-chunk-size", "benchmarks/sampling_scale.py",
                   "test_index_mass_exact_under_interleaved_store_ops",
                   "test_tree_mode_bitwise_equals_dense_all_modes",
                   "test_default_cfg_is_hlo_identical_to_explicit_off",
                   "test_quantized_proposal_tv_under_analytic_bound"):
        assert anchor in arch, f"ARCHITECTURE.md lost its {anchor!r} anchor"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for anchor in ("## Sampling structures at scale", "--index tree",
                   "--table-dtype", "--score-ttl", "--index-chunk-size",
                   "core/mass_index.py", "quantization_tv_bound",
                   "tests/test_mass_index.py",
                   "benchmarks/sampling_scale.py"):
        assert anchor in readme, f"README lost its {anchor!r} anchor"

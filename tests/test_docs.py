"""Doc-coverage gate (ISSUE 5 satellite): the contract-bearing packages
(`core`, `data`, `dist`) must keep module + public-API docstrings at 100%
— docs/ARCHITECTURE.md points into these modules for the sharding and
replication contracts, so an undocumented public definition is a missing
contract.  The same check runs as its own CI leg via
``python tools/check_docstrings.py``."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))


def test_doc_coverage_core_data_dist():
    from check_docstrings import check_packages
    missing = check_packages(root=REPO)
    assert not missing, "undocumented public definitions:\n" + "\n".join(
        f"  {p}:{ln}: {name}" for p, ln, name in missing)


def test_architecture_doc_exists_and_is_linked():
    """docs/ARCHITECTURE.md exists and README links to it (ISSUE 5
    acceptance criterion)."""
    arch = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    assert os.path.exists(arch), "docs/ARCHITECTURE.md missing"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README does not link docs/ARCHITECTURE.md"
    with open(arch) as f:
        text = f.read()
    # the doc stays anchored to the modules it maps
    for anchor in ("core/issgd.py", "core/scorer.py", "data/streaming.py",
                   "dist/sharding.py", "::shard", "relaxed", "fused",
                   "async", "stream"):
        assert anchor in text, f"ARCHITECTURE.md lost its {anchor!r} anchor"

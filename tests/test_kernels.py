"""Per-kernel correctness: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes — including the scoring kernels with their
cotangent operand arriving model-axis-sharded under shard_map (the
model-parallel scorer path: partial per-example sq-norms psum to the
exact value)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import run_mesh_py
from repro.kernels import ops, ref
from repro.kernels.ghost_norm import ghost_norm as ghost_kernel
from repro.kernels.per_example_sqnorm import per_example_sqnorm as pesn_kernel
from repro.kernels.per_example_sqnorm import (per_example_sqnorm_multi
                                              as pesn_multi)
from repro.kernels.selective_scan import selective_scan as scan_kernel
from repro.kernels.decode_attention import decode_attention as dattn_kernel


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------- per_example_sqnorm
@pytest.mark.parametrize("b,din,dout", [
    (4, 32, 32), (8, 300, 100), (16, 1024, 7), (3, 2048, 4096), (128, 512, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [True, False])
def test_per_example_sqnorm(b, din, dout, dtype, with_bias):
    k1, k2 = jax.random.split(jax.random.key(b * din + dout))
    x, d = _rand(k1, (b, din), dtype), _rand(k2, (b, dout), dtype)
    got = pesn_kernel(x, d, with_bias=with_bias, block_b=8, block_k=64,
                      interpret=True)
    want = ref.per_example_sqnorm_ref(x, d, with_bias=with_bias)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol)


# --------------------------------------------------------------- ghost_norm
@pytest.mark.parametrize("b,s,din,dout", [
    (2, 16, 32, 32), (3, 100, 64, 24), (2, 128, 300, 500), (1, 64, 1024, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("symmetric", [False, True])
def test_ghost_norm_kernel(b, s, din, dout, dtype, symmetric):
    k1, k2 = jax.random.split(jax.random.key(s + din))
    x, d = _rand(k1, (b, s, din), dtype), _rand(k2, (b, s, dout), dtype)
    got = ghost_kernel(x, d, block_s=32, block_k=64, symmetric=symmetric,
                       interpret=True)
    want = ref.ghost_norm_ref(x, d)
    rtol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol)


def test_ghost_oracles_agree():
    """The two reference formulations compute the same quantity."""
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (4, 33, 48))
    d = jax.random.normal(k2, (4, 33, 16))
    np.testing.assert_allclose(
        np.asarray(ref.ghost_norm_ref(x, d)),
        np.asarray(ref.ghost_norm_direct_ref(x, d)), rtol=1e-4)


def test_ghost_norm_equals_true_per_example_grad():
    """End-to-end: ghost norm == ||∂L_n/∂W||²_F from real autodiff."""
    key = jax.random.key(3)
    k1, k2, k3 = jax.random.split(key, 3)
    bsz, s, din, dout = 3, 8, 10, 6
    x = jax.random.normal(k1, (bsz, s, din))
    w = jax.random.normal(k2, (din, dout)) * 0.3
    tgt = jax.random.normal(k3, (bsz, s, dout))

    def loss_n(w, x_n, t_n):
        y = x_n @ w
        return jnp.sum((y - t_n) ** 2)

    per_ex_grads = jax.vmap(jax.grad(loss_n), in_axes=(None, 0, 0))(w, x, tgt)
    want = jnp.sum(per_ex_grads ** 2, axis=(1, 2))

    # deltas dL/dY for the summed loss
    def loss(w):
        return jnp.sum((jnp.einsum("bsi,io->bso", x, w) - tgt) ** 2)
    y = jnp.einsum("bsi,io->bso", x, w)
    dy = 2 * (y - tgt)
    got = ops.ghost_norm(x, dy, force="gram")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)
    got2 = ops.ghost_norm(x, dy, force="direct")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), rtol=1e-4)


# ----------------------------------- model-axis-sharded operand parity
@pytest.mark.parametrize("with_bias", [True, False])
def test_per_example_sqnorm_model_sharded_operands(with_bias):
    """per_example_sqnorm under shard_map with the cotangent column-
    sharded over `model`: the per-device partial sums psum to the ref.py
    oracle on the full arrays (the model-parallel ghost-scorer contract:
    ||h||²·||dy||² is additive over dy's column shards)."""
    out = run_mesh_py(f"""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import shard_map
        from repro.kernels import ref
        from repro.kernels.per_example_sqnorm import per_example_sqnorm

        B, DIN, DOUT = 8, 32, 24
        k1, k2 = jax.random.split(jax.random.key(3))
        x = jax.random.normal(k1, (B, DIN))
        d = jax.random.normal(k2, (B, DOUT))

        def body(x, d_local):
            part = per_example_sqnorm(x, d_local, with_bias={with_bias},
                                      block_b=4, block_k=16, interpret=True)
            return jax.lax.psum(part, 'model')

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P(), P(None, 'model')),
                              out_specs=P()))
        got = f(x, jax.device_put(d, NamedSharding(mesh, P(None, 'model'))))
        want = ref.per_example_sqnorm_ref(x, d, with_bias={with_bias})
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-6)
        print('pesn sharded parity ok')
    """, dp=1, mp=2)
    assert "pesn sharded parity ok" in out


def test_ghost_norm_model_sharded_operands():
    """ghost_norm with model-axis-sharded dY columns: the gram-trick
    quantity Σ_{s,s'} (x_s·x_s')(d_s·d_s') is additive over the out-dim,
    so the psum over `model` of the per-shard kernels equals ref.py."""
    out = run_mesh_py("""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import shard_map
        from repro.kernels import ref
        from repro.kernels.ghost_norm import ghost_norm

        B, S, DIN, DOUT = 3, 12, 16, 20
        k1, k2 = jax.random.split(jax.random.key(5))
        x = jax.random.normal(k1, (B, S, DIN))
        d = jax.random.normal(k2, (B, S, DOUT))

        def body(x, d_local):
            part = ghost_norm(x, d_local, block_s=4, block_k=8,
                              interpret=True)
            return jax.lax.psum(part, 'model')

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P(), P(None, None, 'model')),
                              out_specs=P()))
        got = f(x, jax.device_put(
            d, NamedSharding(mesh, P(None, None, 'model'))))
        want = ref.ghost_norm_ref(x, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        print('ghost sharded parity ok')
    """, dp=1, mp=2)
    assert "ghost sharded parity ok" in out


def test_ghost_norm_transformer_tap_operands_model_sharded():
    """Transformer scoring-kernel parity: REAL attention-tap operands —
    the recorded layer input and the vjp cotangent dY of a GQA wq tap —
    with dY column-sharded over `model` the way the head-sharded forward
    taps it.  The psum over `model` of the per-shard ghost_norm kernels
    equals kernels/ref.py on the full operands (and, transposed, the
    row-sharded wo pairing: local input rows, full dY)."""
    out = run_mesh_py("""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import shard_map
        from repro.kernels import ref
        from repro.kernels.ghost_norm import ghost_norm
        from repro.models.config import ModelConfig
        from repro.models.attention import attn, init_attn
        from repro.models.layers import Tape

        cfg = ModelConfig(name='t', arch_type='t', num_heads=4,
                          num_kv_heads=2, d_model=32, d_ff=64,
                          dtype='float32')
        params = init_attn(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (3, 12, 32))
        pos = jnp.broadcast_to(jnp.arange(12)[None], (3, 12))
        tgt = jax.random.normal(jax.random.key(2), (3, 12, 32))

        # tap cotangents dY for wq/wo via the tap trick on the real layer
        shapes = {}
        jax.eval_shape(lambda x: attn(params, x, cfg, pos,
                                      Tape(tap_shapes=shapes)), x)
        taps0 = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}

        def f(taps):
            tape = Tape(taps=taps, records={})
            y = attn(params, x, cfg, pos, tape)
            return jnp.sum((y - tgt) ** 2), tape.records

        _, pull, records = jax.vjp(f, taps0, has_aux=True)
        (dtaps,) = pull(jnp.ones(()))

        for name, spec in [('attn.wq', P(None, None, 'model')),
                           ('attn.wo', None)]:
            rec, dy = records[name], dtaps[name]
            want = ref.ghost_norm_ref(rec, dy)
            if spec is not None:     # column-parallel: dY sharded
                op, op_spec = dy, spec
                def body(x_full, op_l, _rec=rec):
                    part = ghost_norm(_rec, op_l, block_s=4, block_k=8,
                                      interpret=True)
                    return jax.lax.psum(part, 'model')
            else:                    # row-parallel: the INPUT is sharded
                op, op_spec = rec, P(None, None, 'model')
                def body(x_full, op_l, _dy=dy):
                    part = ghost_norm(op_l, _dy, block_s=4, block_k=8,
                                      interpret=True)
                    return jax.lax.psum(part, 'model')
            g = jax.jit(shard_map(body, mesh=mesh,
                                  in_specs=(P(), op_spec), out_specs=P()))
            got = g(x, jax.device_put(op, NamedSharding(mesh, op_spec)))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-5, err_msg=name)
        print('transformer tap parity ok')
    """, dp=1, mp=2)
    assert "transformer tap parity ok" in out


def test_transformer_mp_ghost_scorer_matches_single_device():
    """End-to-end transformer scorer parity: the model-axis ghost scorer
    (partial per-example sq-norms from local dY slices, psum'd over
    `model`) equals the single-device ghost scorer on the same params."""
    out = run_mesh_py("""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import shard_map, param_pspecs
        from repro.core.scorer import make_lm_scorer
        from repro.models.config import ModelConfig
        from repro.models.transformer import (init_transformer,
                                              transformer_specs)

        cfg = ModelConfig(name='t', arch_type='t', num_layers=2,
                          d_model=32, num_heads=4, num_kv_heads=2,
                          d_ff=64, vocab_size=64, dtype='float32',
                          remat=False)
        params = init_transformer(jax.random.key(1), cfg)
        batch = {'tokens': jax.random.randint(jax.random.key(2), (4, 13),
                                              0, 64)}
        want = make_lm_scorer(cfg, 'ghost')(params, batch)

        pp = param_pspecs(transformer_specs(cfg), params, mesh)
        sc = make_lm_scorer(cfg, 'ghost', model_axes=('model',))
        f = jax.jit(shard_map(sc, mesh=mesh, in_specs=(pp, P()),
                              out_specs=P()))
        pm = jax.tree.map(lambda x, s: jax.device_put(
                              x, NamedSharding(mesh, s)),
                          params, pp, is_leaf=lambda x: isinstance(x, P))
        got = f(pm, batch)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        print('transformer scorer parity ok')
    """, dp=1, mp=2)
    assert "transformer scorer parity ok" in out


def test_prop1_equals_true_per_example_grad():
    """Paper Prop. 1 against autodiff for an MLP layer (incl. bias)."""
    key = jax.random.key(5)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    bsz, din, dout = 5, 12, 7
    x = jax.random.normal(k1, (bsz, din))
    w = jax.random.normal(k2, (din, dout)) * 0.4
    bvec = jax.random.normal(k3, (dout,)) * 0.1
    tgt = jax.random.normal(k4, (bsz, dout))

    def loss_n(params, x_n, t_n):
        w, bvec = params
        return jnp.sum((x_n @ w + bvec - t_n) ** 2)

    gw, gb = jax.vmap(jax.grad(loss_n), in_axes=(None, 0, 0))((w, bvec), x, tgt)
    want = jnp.sum(gw ** 2, axis=(1, 2)) + jnp.sum(gb ** 2, axis=1)

    dy = 2 * (x @ w + bvec - tgt)
    got = ops.per_example_sqnorm(x, dy, with_bias=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ----------------------------------------------------------- selective scan
@pytest.mark.parametrize("b,s,di,ds", [
    (2, 16, 32, 4), (1, 64, 48, 16), (2, 100, 30, 8), (3, 128, 256, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan(b, s, di, ds, dtype):
    keys = jax.random.split(jax.random.key(s * di), 6)
    u = _rand(keys[0], (b, s, di), dtype)
    delta = jax.nn.softplus(_rand(keys[1], (b, s, di), jnp.float32)).astype(dtype)
    a = -jnp.exp(jax.random.normal(keys[2], (di, ds)) * 0.5)
    bm = _rand(keys[3], (b, s, ds), dtype)
    c = _rand(keys[4], (b, s, ds), dtype)
    d = jax.random.normal(keys[5], (di,))
    got = ops.selective_scan(u, delta, a, bm, c, d, chunk=32, block_d=16)
    want = ref.selective_scan_ref(u, delta, a, bm, c, d)
    rtol, atol = (8e-2, 1e-2) if dtype == jnp.bfloat16 else (2e-4, 1e-5)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32), rtol=rtol, atol=atol)


def test_selective_scan_matches_stepwise_decode():
    """Chunked train-time scan and one-token decode recurrence agree."""
    keys = jax.random.split(jax.random.key(11), 6)
    b, s, di, ds = 2, 24, 16, 4
    u = jax.random.normal(keys[0], (b, s, di))
    delta = jax.nn.softplus(jax.random.normal(keys[1], (b, s, di)))
    a = -jnp.exp(jax.random.normal(keys[2], (di, ds)) * 0.3)
    bm = jax.random.normal(keys[3], (b, s, ds))
    c = jax.random.normal(keys[4], (b, s, ds))
    d = jax.random.normal(keys[5], (di,))
    y_scan = ref.selective_scan_ref(u, delta, a, bm, c, d)
    h = jnp.zeros((b, di, ds))
    ys = []
    for t in range(s):
        h, y_t = ref.selective_scan_step_ref(h, u[:, t], delta[:, t], a,
                                             bm[:, t], c[:, t], d)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_scan), rtol=1e-4, atol=1e-5)


# -------------------------------------------------------- decode attention
@pytest.mark.parametrize("b,s,h,hkv,hd", [
    (2, 64, 4, 4, 32), (2, 128, 8, 2, 64), (1, 100, 6, 1, 16), (3, 256, 16, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, s, h, hkv, hd, dtype):
    keys = jax.random.split(jax.random.key(s + h), 4)
    q = _rand(keys[0], (b, h, hd), dtype)
    k = _rand(keys[1], (b, s, hkv, hd), dtype)
    v = _rand(keys[2], (b, s, hkv, hd), dtype)
    lengths = jax.random.randint(keys[3], (b,), 1, s + 1)
    got = dattn_kernel(q, k, v, lengths, block_s=32, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    rtol, atol = (3e-2, 3e-2) if dtype == jnp.bfloat16 else (2e-5, 2e-6)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32), rtol=rtol, atol=atol)


def test_decode_attention_length_zero_safe():
    q = jnp.ones((1, 2, 8))
    k = jnp.ones((1, 16, 2, 8))
    v = jnp.ones((1, 16, 2, 8))
    out = dattn_kernel(q, k, v, jnp.asarray([0]), block_s=8, interpret=True)
    assert not bool(jnp.any(jnp.isnan(out)))


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,s,h,hkv,hd,win", [
    (2, 64, 4, 2, 32, 0), (1, 100, 8, 8, 16, 0), (2, 128, 4, 1, 32, 24),
    (1, 96, 6, 3, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, hkv, hd, win, dtype):
    from repro.kernels.flash_attention import flash_attention
    ks = jax.random.split(jax.random.key(s + h), 3)
    q = _rand(ks[0], (b, s, h, hd), dtype)
    k = _rand(ks[1], (b, s, hkv, hd), dtype)
    v = _rand(ks[2], (b, s, hkv, hd), dtype)
    got = flash_attention(q, k, v, window=win, block_q=32, block_k=16,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=win)
    rtol, atol = (4e-2, 2e-2) if dtype == jnp.bfloat16 else (2e-5, 2e-6)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=rtol, atol=atol)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's chunked-jnp attention end to end."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import _chunked_attention
    b, s, hkv, rep, hd = 2, 48, 2, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, hkv, rep, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    want = _chunked_attention(q, k, v, pos, pos, 0, 16)
    got = flash_attention(q.reshape(b, s, hkv * rep, hd), k, v,
                          block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want.reshape(b, s, hkv * rep, hd)),
        rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("b,s,h,hkv,hd,win", [
    (2, 48, 4, 2, 16, 0), (1, 64, 4, 4, 32, 0), (2, 64, 4, 1, 16, 24),
])
def test_flash_attention_backward(b, s, h, hkv, hd, win):
    """FlashAttention-2-style backward kernels == autodiff of the oracle
    (dq/dk/dv, incl. GQA head accumulation and sliding windows)."""
    ks = jax.random.split(jax.random.key(s + h + win), 4)
    q = jax.random.normal(ks[0], (b, s, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, s, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, hkv, hd)) * 0.5
    tgt = jax.random.normal(ks[3], (b, s, h, hd))
    fa = ops.make_flash_attention_trainable(window=win, block_q=16,
                                            block_k=16)

    def loss_fa(q, k, v):
        return jnp.sum((fa(q, k, v) - tgt) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum((ref.flash_attention_ref(q, k, v, window=win)
                        - tgt) ** 2)

    g1 = jax.grad(loss_fa, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b2 in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2),
                                   rtol=1e-4, atol=1e-5)


# ------------------------------------------------- fused score epilogue
@pytest.mark.parametrize("b,s,h,hkv,hd,win", [
    (2, 48, 4, 2, 16, 0),    # causal, GQA rep=2, aligned seq
    (2, 50, 4, 2, 16, 0),    # padded seq (50 % 16 != 0)
    (2, 64, 4, 1, 16, 24),   # sliding window, MQA
])
def test_flash_attention_fused_scores(b, s, h, hkv, hd, win):
    """`with_scores=True` epilogue: (a) dq/dk/dv BITWISE-equal the plain
    3-arg op's grads, (b) the score-tap cotangent equals the oracle
    ||dQ||²+||dK||²+||dV||² (allclose) and is BITWISE-equal to both the
    separate-pass probe and the standalone `attn_grad_sqnorm` sweep."""
    ks = jax.random.split(jax.random.key(s + h + win), 4)
    q = jax.random.normal(ks[0], (b, s, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, s, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, hkv, hd)) * 0.5
    tgt = jax.random.normal(ks[3], (b, s, h, hd))
    tap = jnp.zeros((b,), jnp.float32)
    fa3 = ops.make_flash_attention_trainable(window=win, block_q=16,
                                             block_k=16)
    fas = ops.make_flash_attention_trainable(window=win, block_q=16,
                                             block_k=16, with_scores=True)
    probe = ops.make_qkv_score_probe(block_q=16, block_k=16)

    def loss3(q, k, v):
        return jnp.sum((fa3(q, k, v) - tgt) ** 2)

    def loss_s(q, k, v, tap):
        return jnp.sum((fas(q, k, v, tap) - tgt) ** 2)

    def loss_p(q, k, v, tap):
        qq, kk, vv = probe(q, k, v, tap)
        return jnp.sum((fa3(qq, kk, vv) - tgt) ** 2)

    g3 = jax.grad(loss3, argnums=(0, 1, 2))(q, k, v)
    gs = jax.grad(loss_s, argnums=(0, 1, 2, 3))(q, k, v, tap)
    gp = jax.grad(loss_p, argnums=(0, 1, 2, 3))(q, k, v, tap)
    for a, b2 in zip(gs[:3], g3):    # scores ride along at zero grad cost
        assert np.array_equal(np.asarray(a), np.asarray(b2))
    want = ref.attn_grad_sqnorm_ref(*g3)
    np.testing.assert_allclose(np.asarray(gs[3]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert np.array_equal(np.asarray(gs[3]), np.asarray(gp[3])), \
        "fused epilogue != separate-pass probe (bitwise)"
    sweep = ops.attn_grad_sqnorm(*g3, block_q=16, block_k=16)
    assert np.array_equal(np.asarray(gs[3]), np.asarray(sweep)), \
        "fused epilogue != attn_score_sweep (bitwise)"


def test_attn_score_sweep_model_sharded_dy():
    """Head-sharded dQ/dK/dV under shard_map: local sweeps are model-axis
    partial scores; psum over `model` recovers the full-gradient score."""
    out = run_mesh_py("""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import shard_map
        from repro.kernels import ref
        from repro.kernels.flash_attention_bwd import attn_score_sweep

        b, s, h, hkv, hd = 2, 20, 4, 2, 8
        ks = jax.random.split(jax.random.key(0), 3)
        dq = jax.random.normal(ks[0], (b, s, h, hd))
        dk = jax.random.normal(ks[1], (b, s, hkv, hd))
        dv = jax.random.normal(ks[2], (b, s, hkv, hd))
        want = ref.attn_grad_sqnorm_ref(dq, dk, dv)

        def body(dql, dkl, dvl):
            part = attn_score_sweep(dql, dkl, dvl, block_q=8, block_k=8,
                                    interpret=True)
            return jax.lax.psum(part, 'model')

        spec = P(None, None, 'model', None)
        g = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(spec, spec, spec), out_specs=P()))
        args = [jax.device_put(a, NamedSharding(mesh, spec))
                for a in (dq, dk, dv)]
        got = g(*args)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        print('sharded dY sweep ok')
    """, dp=1, mp=2)
    assert "sharded dY sweep ok" in out


# ------------------------------------------------- fused multi-tap sqnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [True, False])
def test_per_example_sqnorm_multi(dtype, with_bias):
    """One-sweep multi-tap kernel == chained single-tap launches BITWISE
    (heterogeneous tap widths, padded batch) and == the jnp ref."""
    b = 37
    dims = [(48, 40), (16, 72), (33, 9)]
    ks = jax.random.split(jax.random.key(7), 2 * len(dims))
    xs = tuple(jax.random.normal(ks[2 * i], (b, din)).astype(dtype)
               for i, (din, _) in enumerate(dims))
    ds = tuple(jax.random.normal(ks[2 * i + 1], (b, dout)).astype(dtype)
               for i, (_, dout) in enumerate(dims))
    kw = dict(with_bias=with_bias, block_b=16, block_k=32, interpret=True)
    multi = pesn_multi(xs, ds, **kw)
    chained = pesn_kernel(xs[0], ds[0], **kw)
    for x, d in zip(xs[1:], ds[1:]):
        chained = chained + pesn_kernel(x, d, **kw)
    assert np.array_equal(np.asarray(multi), np.asarray(chained)), \
        "multi-tap sweep != chained single-tap launches (bitwise)"
    want = ref.per_example_sqnorm_multi_ref(xs, ds, with_bias=with_bias)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(multi), np.asarray(want),
                               rtol=tol, atol=tol)


def test_per_example_sqnorm_multi_single_tap_degenerate():
    """T=1 multi-tap == the single-tap kernel bitwise."""
    x = jax.random.normal(jax.random.key(0), (19, 45))
    d = jax.random.normal(jax.random.key(1), (19, 23))
    kw = dict(block_b=8, block_k=16, interpret=True)
    got = pesn_multi((x,), (d,), **kw)
    want = pesn_kernel(x, d, **kw)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------- scorer-level fused parity
def _tiny_attn_cfg(**kw):
    from repro.models.config import ModelConfig
    base = dict(name="d", arch_type="dense", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=50,
                dtype="float32", remat=False)
    base.update(kw)
    return ModelConfig(**base)


def test_ghost_attn_scores_fused_equals_separate():
    """ISSUE 6 acceptance: the ghost strategy with the fused `with_scores`
    kernels is BITWISE-equal to the separate-pass probe path, for both
    scan directions (f32 model; see docs/KERNELS.md for the bf16 caveat)."""
    from repro.core.scorer import make_lm_scorer
    from repro.models.transformer import init_transformer
    cfg = _tiny_attn_cfg()
    params = init_transformer(jax.random.key(3), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(4), (4, 12),
                                          0, 50)}
    for strat in ("ghost", "ghost_rev"):
        fused = make_lm_scorer(cfg, strat, attn_impl="flash",
                               attn_scores="fused")(params, batch)
        sep = make_lm_scorer(cfg, strat, attn_impl="flash",
                             attn_scores="separate")(params, batch)
        assert np.array_equal(np.asarray(fused), np.asarray(sep)), \
            f"{strat}: fused != separate (bitwise)"


def test_ghost_flash_matches_ghost_ref():
    """Plain flash ghost (no attn_scores) keeps the exact estimator:
    it matches the ref-attention ghost scorer to flash tolerance."""
    from repro.core.scorer import make_lm_scorer
    from repro.models.transformer import init_transformer
    cfg = _tiny_attn_cfg()
    params = init_transformer(jax.random.key(3), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(4), (4, 12),
                                          0, 50)}
    want = make_lm_scorer(cfg, "ghost")(params, batch)
    got = make_lm_scorer(cfg, "ghost", attn_impl="flash")(params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=1e-6)


def test_attn_scores_validation():
    """attn_scores is rejected without the flash kernel, with unknown
    modes, and with strategies that have no ghost-tap walk."""
    from repro.core.scorer import make_lm_scorer
    cfg = _tiny_attn_cfg()
    with pytest.raises(ValueError):
        make_lm_scorer(cfg, "ghost", attn_scores="fused")
    with pytest.raises(ValueError):
        make_lm_scorer(cfg, "ghost", attn_impl="flash",
                       attn_scores="bogus")
    with pytest.raises(ValueError):
        make_lm_scorer(cfg, "loss", attn_impl="flash",
                       attn_scores="fused")
